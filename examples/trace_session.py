"""Observability end-to-end: telemetry series + a loadable Perfetto trace.

One `telemetry=True` switch turns a heterogeneous, streaming session into
an observable one:

  * every `run()` returns a per-superstep `TelemetrySeries` — active
    jobs, tile loads, global-queue occupancy, per-family residuals, the
    dirty-block spike after a live update batch — even for
    `TwoLevel(backend="device", steps_per_sync=inf)`, which still syncs
    exactly ONCE (the series rides the device scan carry);
  * `sess.trace` collects the discrete story — submits, detaches, run
    and superstep spans, `apply_updates` batches — and exports standard
    Chrome trace-event JSON.

Run it, then drag the output file into https://ui.perfetto.dev (or
chrome://tracing):

  PYTHONPATH=src python examples/trace_session.py [out.json]
"""

import math
import sys

import numpy as np

from repro.algorithms import PageRank, PersonalizedPageRank, SSSP
from repro.core import GraphSession, TwoLevel
from repro.graph import mutation_stream, uniform_graph


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "trace_session.json"
    csr = uniform_graph(1200, 8, seed=0)
    print(f"shared CSR: {csr.n} vertices, {csr.nnz} edges")

    # mixed-semiring session (plus-times + min-plus views share staging),
    # observability on
    sess = GraphSession(csr, block_size=64, capacity=2, seed=0,
                        telemetry=True)
    sess.submit(PageRank())
    sess.submit(PersonalizedPageRank(source=31))
    h_ss = sess.submit(SSSP(source=0))

    # phase 1: host backend — per-superstep spans land on the trace
    m = sess.run(TwoLevel())
    tel = m.telemetry
    print(f"host run: {m.supersteps} supersteps in {m.wall_time_s:.3f}s; "
          f"series covers {len(tel)} supersteps, "
          f"gq occupancy p50={int(np.median(tel.gq_occupancy))}, "
          f"groups={['/'.join(k[:1]) for k in tel.view_keys]}")
    # the series decomposes the run totals exactly
    assert int(tel.tile_loads.sum()) == m.tile_loads

    # phase 2: live updates — watch the dirty-block spike re-ignite work
    for batch in mutation_stream(csr, 2, inserts_per_batch=10,
                                 deletes_per_batch=5, seed=1):
        sess.apply_updates(batch)
        m = sess.run(TwoLevel())
        print(f"update batch: dirty spike "
              f"{int(m.telemetry.dirty_blocks[0])} blocks -> reconverged "
              f"in {m.supersteps} supersteps")

    # phase 3: a late arrival driven by the 1-sync device path — the full
    # series still comes back despite a single host round-trip
    sess.detach(h_ss)
    sess.submit(SSSP(source=17))
    m = sess.run(TwoLevel(backend="device", steps_per_sync=math.inf))
    print(f"device inf run: {m.supersteps} supersteps at "
          f"{m.host_syncs} host sync; series rows={len(m.telemetry)}")
    assert m.host_syncs == 1 and len(m.telemetry) == m.supersteps

    path = sess.trace.export(out)
    print(f"wrote {path} ({len(sess.trace.events)} events) — load it in "
          f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
