"""Didi-style concurrent route queries: many SSSP jobs on one road network.

The paper's motivating workload (9B route plans/day = thousands of
concurrent shortest-path queries on the same graph).  Demonstrates:
  * min-plus semiring jobs sharing one weighted-graph view
  * the Pallas multi-job kernel path (use_pallas=True, interpret on CPU)
  * the fused on-device scheduler (beyond-paper) vs the faithful host one

  PYTHONPATH=src python examples/concurrent_route_queries.py
"""

import time

from repro.algorithms import SSSP
from repro.core import ConcurrentEngine, make_run
from repro.graph import grid_graph


def main():
    side = 40
    csr = grid_graph(side, weighted=True, w_max=5.0, seed=2)
    sources = [0, 39, 40 * 39, 40 * 40 - 1, 820, 1234]  # corners + interior
    algs = [SSSP(source=s) for s in sources]
    print(f"road grid {side}x{side}: {csr.n} vertices, {csr.nnz} edges; "
          f"{len(algs)} concurrent route queries")

    for name, kwargs, runner in (
            ("faithful host scheduler", {}, "run_two_level"),
            ("pallas multi-job kernel", {"use_pallas": True}, "run_two_level"),
            ("fused on-device (beyond-paper)", {}, "run_fused"),
    ):
        run = make_run(algs, csr, block_size=64)
        eng = ConcurrentEngine(run, seed=0, **kwargs)
        t0 = time.time()
        m = getattr(eng, runner)(max_supersteps=50000)
        dt = time.time() - t0
        res = eng.results()
        assert m.converged
        print(f"{name:32s} supersteps={m.supersteps:5d} "
              f"tile_loads={m.tile_loads:6d} wall={dt:6.2f}s "
              f"dist(corner->corner)={res[0][csr.n - 1]:.2f}")


if __name__ == "__main__":
    main()
