"""Heterogeneous sessions: mixed-semiring jobs sharing one staged tile.

The paper's CAJS promise is that ARBITRARY concurrent jobs touching the
same graph data are served by one cache staging.  A GraphSession now keeps
a registry of graph views — one per `(semiring, fill, normalize,
symmetrize)` key, each built lazily from the shared CSR and block-aligned,
so block id b names the same vertex range in every view.  One scheduling
decision per superstep then stages each selected block ONCE and dispatches
it through the plus-times push (PageRank/Katz) AND the min-plus push
(SSSP/BFS) for whichever jobs are unconverged on it:

  * `RunMetrics.tile_loads` counts that shared staging once, so the
    cross-family saving is measurable — compare against running the two
    families in separate sessions;
  * every job still reaches its solo-session fixpoint (exactly for
    min-plus, within tolerance for plus-times);
  * works under every policy (`TwoLevel`, `Fused`, `Independent`,
    `AllBlocks`) and composes with `mesh=` job-axis sharding per view.

  PYTHONPATH=src python examples/hetero_session.py
"""

import numpy as np

from repro.algorithms import BFS, Katz, PageRank, SSSP
from repro.core import GraphSession, TwoLevel
from repro.graph import uniform_graph


def main():
    # uniform degree keeps Katz contractive (alpha * rho(A) < 1)
    csr = uniform_graph(1200, 8, seed=0)
    print(f"shared CSR: {csr.n} vertices, {csr.nnz} edges")

    # one heterogeneous session absorbs a mixed arrival stream
    sess = GraphSession(csr, block_size=64, capacity=4, seed=0)
    policy = TwoLevel()
    arrivals = [PageRank(), SSSP(source=0), Katz(alpha=0.02),
                BFS(source=77), SSSP(source=501)]
    handles, hetero_loads = [], 0
    for alg in arrivals:
        handles.append(sess.submit(alg))
        print(f"submit {alg.name:8s} -> view {len(sess.groups)} views, "
              f"{sess.num_active} active jobs")
        hetero_loads += sess.run(policy, max_supersteps=10).tile_loads
    m = sess.run(policy)
    assert m.converged
    hetero_loads += m.tile_loads

    dist = sess.result(handles[1])                    # the SSSP job
    rank = sess.result(handles[0])                    # the PageRank job
    print(f"SSSP reaches {int(np.isfinite(dist).sum())}/{csr.n} vertices; "
          f"PageRank mass {rank.sum():.1f}")

    # same arrival schedule, one session per semiring family (created on
    # its family's first arrival; both live through every global gap)
    split_loads = 0
    sessions = {}
    for alg in arrivals:
        if alg.semiring not in sessions:
            sessions[alg.semiring] = GraphSession(csr, 64, capacity=4,
                                                  seed=0)
        sessions[alg.semiring].submit(alg)
        for s in sessions.values():                   # shared arrival clock
            split_loads += s.run(policy, max_supersteps=10).tile_loads
    for s in sessions.values():
        mf = s.run(policy)
        assert mf.converged
        split_loads += mf.tile_loads

    print(f"tile loads: heterogeneous session {hetero_loads}, "
          f"two per-family sessions {split_loads} "
          f"({split_loads / max(hetero_loads, 1):.2f}x more stagings)")


if __name__ == "__main__":
    main()
