"""Quickstart: the paper's two-level scheduling on concurrent graph jobs.

Runs 6 concurrent jobs (1 global PageRank + 5 personalized PageRanks) over
one shared RMAT graph and compares the paper's schedule (CAJS+MPDS) against
the independent-scheduling baseline (the paper's Fig. 3 "current mode").

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.algorithms import PageRank, PersonalizedPageRank
from repro.core import ConcurrentEngine, make_run
from repro.graph import rmat_graph


def main():
    csr = rmat_graph(2000, 8, seed=1)
    algs = [PageRank()] + [PersonalizedPageRank(source=s)
                           for s in (3, 77, 500, 999, 1500)]
    print(f"graph: {csr.n} vertices, {csr.nnz} edges; "
          f"{len(algs)} concurrent jobs share it")

    # the paper's schedule: per-job DO queues -> global queue -> one VMEM
    # staging of each selected block serves every job (CAJS)
    run = make_run(algs, csr, block_size=64)
    eng = ConcurrentEngine(run, seed=0)
    m2 = eng.run_two_level(max_supersteps=50000)
    res = eng.results()

    # baseline: each job schedules and stages blocks independently
    run_i = make_run(algs, csr, block_size=64)
    mi = ConcurrentEngine(run_i, seed=0).run_independent(max_supersteps=50000)

    print(f"two-level : supersteps={m2.supersteps:5d} "
          f"tile_loads={m2.tile_loads:7d} converged={m2.converged}")
    print(f"independent: supersteps={mi.supersteps:5d} "
          f"tile_loads={mi.tile_loads:7d} converged={mi.converged}")
    print(f"memory-access-redundancy saving: "
          f"{mi.tile_loads / max(m2.tile_loads, 1):.2f}x fewer stagings")

    top = np.argsort(-res[0])[:5]
    print("global PageRank top-5 vertices:", top.tolist())
    for j, s in enumerate((3, 77, 500, 999, 1500), start=1):
        assert res[j][s] >= np.median(res[j]), "PPR mass should favor source"
    print("all jobs converged to sane fixpoints: OK")


if __name__ == "__main__":
    main()
