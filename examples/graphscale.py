"""Shard the graph, not just the jobs: a 2D (jobs x blocks) mesh.

Job-axis sharding (`make_job_mesh`) replicates every adjacency tile on
every device — fine while the graph fits one device, a hard wall the
moment it does not.  The second mesh axis partitions the sparse
BlockPairs stream by destination block-row: each block shard holds 1/S
of the tiles and the matching destination rows of EVERY job's
values/deltas, and the shards exchange only the staged frontier deltas
inside the jitted superstep (optionally int8 error-feedback compressed).
`Fused()` stays one host sync per `run()`; min-plus fixpoints stay
bit-identical to the single-device engine.

Run with a few forced host devices to see it locally:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/graphscale.py
"""

import jax
import numpy as np

from repro.algorithms import PageRank, SSSP
from repro.core import Fused, GraphSession, TwoLevel
from repro.dist.graph import shard_session
from repro.dist.mesh2d import make_mesh2d
from repro.graph import rmat_graph


def build(csr):
    sess = GraphSession(csr, block_size=32, capacity=2, seed=0)
    hs = [sess.submit(PageRank()), sess.submit(PageRank(damping=0.7)),
          sess.submit(SSSP(source=0)), sess.submit(SSSP(source=17))]
    return sess, hs


def main():
    n_dev = len(jax.devices())
    if n_dev < 4:
        print(f"only {n_dev} device(s) visible — run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4")
        return
    csr = rmat_graph(1024, 6, seed=20)
    print(f"shared CSR: {csr.n} vertices, {csr.nnz} edges")

    # single-device reference
    ref, href = build(csr)
    m0 = ref.run(TwoLevel())
    res = [ref.result(h) for h in href]
    tile_mb = sum(np.prod(ref._pair_data(g).tiles.shape) * 4
                  for g in ref.view_groups()) / 1e6
    print(f"solo: {m0.supersteps} supersteps, {tile_mb:.1f} MB of pair "
          "tiles resident on ONE device")

    # 1 x 4: pure block sharding — each shard holds ~1/4 of the tiles
    sess, hs = build(csr)
    m = sess.run(Fused(), mesh=make_mesh2d(jobs=1, blocks=4))
    per_shard_mb = sum(np.prod(sess._pair_shards(g).tiles.shape[1:]) * 4
                       for g in sess.view_groups()) / 1e6
    assert np.array_equal(sess.result(hs[2]), res[2])   # min-plus bitwise
    print(f"1x4 blocks: {m.supersteps} supersteps, {per_shard_mb:.1f} MB "
          f"per shard, halo {m.halo_bytes / m.supersteps / 1e3:.1f} KB "
          "per superstep (frontier deltas, not tiles), min-plus bitwise")

    # 2 x 2: jobs x blocks composed; same fixpoints
    sess2, hs2 = build(csr)
    m2 = sess2.run(Fused(), mesh=make_mesh2d(jobs=2, blocks=2))
    assert np.array_equal(sess2.result(hs2[2]), res[2])
    print(f"2x2 jobs x blocks: {m2.supersteps} supersteps, halo "
          f"{m2.halo_bytes / 1e3:.0f} KB total, still one host sync")

    # int8 error-feedback halo: plus-times payload shrinks, min-plus is
    # never quantized (exactness first)
    sess3, hs3 = build(csr)
    shard_session(make_mesh2d(jobs=2, blocks=2), sess3,
                  axes=("jobs", "blocks"), compress_halo=True)
    m3 = sess3.run(Fused())
    assert np.array_equal(sess3.result(hs3[2]), res[2])
    np.testing.assert_allclose(sess3.result(hs3[0]), res[0],
                               rtol=5e-3, atol=5e-4)
    print(f"2x2 + int8 halo: {m3.halo_bytes / 1e3:.0f} KB total "
          f"({m2.halo_bytes / max(m3.halo_bytes, 1):.1f}x smaller), "
          "min-plus still bitwise")


if __name__ == "__main__":
    main()
