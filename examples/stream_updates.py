"""Evolving graphs: live edge updates while concurrent jobs run.

The paper's jobs arrive continuously against a shared graph — in the real
scene the GRAPH mutates too.  `GraphSession.apply_updates(batch)` absorbs
edge insert/delete/reweight batches at any superstep:

  * most edits land in the dense base tiles in place; inserts that create
    a NEW block pair go to a bounded per-block delta-COO overlay staged
    alongside the tile (a full overlay row compacts: the BlockedGraph is
    rebuilt from the updated CSR, bit-identical to a from-scratch build);
  * plus-times jobs get an EXACT delta correction (the push loop
    conserves v + (I-A)^{-1} d, so d += (A'-A)v retargets the new
    fixpoint); min-plus insertions just re-activate the source (monotone
    fast path), deletions re-seed the support-tested affected set;
  * the affected blocks enter every job's DO queue with injected priority
    on the next superstep — dirty blocks are just blocks with boosted
    priority, so the existing two-level scheduler steers ALL concurrent
    jobs at the update region first.

The payoff measured below (and in `benchmarks/run.py fig_stream`):
incremental reconvergence touches a fraction of the tiles a
restart-per-batch world reloads, at bitwise-identical min-plus answers.

  PYTHONPATH=src python examples/stream_updates.py
"""

import numpy as np

from repro.algorithms import PageRank, SSSP
from repro.core import GraphSession, TwoLevel
from repro.graph import mutation_stream, uniform_graph
from repro.stream import apply_to_csr


def main():
    csr = uniform_graph(1200, 8, seed=0)
    print(f"shared CSR: {csr.n} vertices, {csr.nnz} edges")

    sess = GraphSession(csr, block_size=64, capacity=2, seed=0)
    h_pr = sess.submit(PageRank())
    h_ss = sess.submit(SSSP(source=0))
    m = sess.run(TwoLevel())
    assert m.converged
    print(f"initial convergence: {m.supersteps} supersteps, "
          f"{m.tile_loads} tile loads")

    # a live stream: preferential-attachment inserts + uniform deletes
    batches = mutation_stream(csr, 4, inserts_per_batch=12,
                              deletes_per_batch=6, seed=1)
    inc_loads = inc_steps = 0
    csr_now = csr
    for i, batch in enumerate(batches):
        stats = sess.apply_updates(batch)
        m = sess.run(TwoLevel())
        assert m.converged
        inc_loads += m.tile_loads
        inc_steps += m.supersteps
        csr_now = apply_to_csr(csr_now, batch)
        print(f"batch {i}: {stats.updates_applied} ops, "
              f"{stats.dirty_blocks} dirty blocks, "
              f"reseed {stats.reseed_fraction:.1%} -> reconverged in "
              f"{m.supersteps} supersteps / {m.tile_loads} tile loads")

    # the restart world pays full convergence per batch
    restart = GraphSession(csr_now, 64, capacity=2, seed=0)
    r_pr, r_ss = restart.submit(PageRank()), restart.submit(SSSP(source=0))
    mr = restart.run(TwoLevel())
    assert mr.converged
    print(f"one restart on the final graph alone: {mr.supersteps} "
          f"supersteps / {mr.tile_loads} tile loads "
          f"(x{len(batches)} batches for restart-per-batch)")

    # incremental answers == fresh-session answers on the final graph
    np.testing.assert_array_equal(sess.result(h_ss), restart.result(r_ss))
    np.testing.assert_allclose(sess.result(h_pr), restart.result(r_pr),
                               rtol=1e-3, atol=1e-5)
    print(f"fixpoints match the rebuilt graph (SSSP bitwise); incremental "
          f"total: {inc_steps} supersteps / {inc_loads} tile loads")


if __name__ == "__main__":
    main()
