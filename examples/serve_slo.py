"""SLO observability for the serve front, end to end.

The paper serves CONCURRENT graph queries; an operator running that serve
front needs to know whether it is inside its latency targets under real
traffic.  This example wires the whole observability stack together:

  * `repro.obs.loadgen` generates a seeded open-loop arrival schedule
    (Poisson base rate, diurnal bursts, tenants pinned to algorithm
    families) and `OpenLoopHarness` drives a shared `GraphSession` +
    `ConcurrentServeScheduler` through it, interleaving live graph
    updates;
  * `SLOTracker` judges sliding-window p50/p99 latency, throughput and
    per-request deadlines against declared `SLOTarget`s;
  * `MetricsRegistry` snapshots every source to schema-validated JSON
    and Prometheus text exposition.

Everything is deterministic under the seeds: rerun it and the admission
and completion sequences are bit-identical — which is exactly what lets
`benchmarks/run.py fig_serve` commit this trajectory and
`python -m repro.obs.regress` gate PRs against it.

  PYTHONPATH=src python examples/serve_slo.py
"""

from repro.core import GraphSession
from repro.graph import rmat_graph
from repro.obs import (LoadgenConfig, MetricsRegistry, OpenLoopHarness,
                       SLOTarget, SLOTracker, validate_registry_snapshot)
from repro.serve.concurrent import ConcurrentServeScheduler


def main():
    csr = rmat_graph(512, 6, seed=1)
    block = 64
    n_blocks = -(-csr.n // block)
    print(f"graph: {csr.n} vertices, {csr.nnz} edges, {n_blocks} blocks")

    # 1. declare objectives: sssp is latency-critical, everything else
    #    just has a loose deadline
    slo = SLOTracker(targets=[
        SLOTarget(family="sssp", p99_latency_steps=400,
                  deadline_steps=600),
        SLOTarget(family="*", deadline_steps=1200),
    ], window=256)

    sess = GraphSession(csr, block, capacity=6, seed=0)
    sched = ConcurrentServeScheduler(n_blocks, batch_budget=6, seed=5,
                                     slo=slo)

    # 2. open-loop traffic: ~0.4 req/tick with diurnal bursts across 60
    #    tenants, one UpdateBatch of live edge mutations every 80 ticks
    cfg = LoadgenConfig(seed=17, ticks=400, base_rate=0.4,
                        burst_amplitude=0.6, n_tenants=60,
                        update_every=80)
    harness = OpenLoopHarness(sess, sched, cfg, max_running=6)
    summary = harness.run()
    print(f"\n{summary['arrivals']} arrivals -> "
          f"{summary['completed']} completed in {summary['ticks']} ticks "
          f"({summary['supersteps']} shared supersteps, "
          f"{summary['updates_applied']} update batches)")
    lat = summary["latency_ticks"]
    print(f"latency (ticks): p50={lat['p50']:.0f} p99={lat['p99']:.0f}")

    # 3. the SLO verdicts
    report = slo.report()
    print(f"\nwindowed throughput: {report['throughput_per_step']} "
          f"completions/step; deadline violations: "
          f"{report['deadline_violations_total']}")
    for fam, entry in sorted(report["families"].items()):
        verdict = entry.get("slo")
        state = ("n/a" if verdict is None
                 else "OK" if verdict["ok"] else "VIOLATED")
        print(f"  {fam:10s} p50={entry['latency_steps']['p50']:7.1f} "
              f"p99={entry['latency_steps']['p99']:7.1f} "
              f"deadline_miss={entry['deadline_violations']:3d}  "
              f"SLO {state}")

    # 4. one registry snapshot over every source
    reg = MetricsRegistry()
    reg.register("serve", sched.metrics)    # cumulative view
    reg.register("slo", slo)                # sliding-window view
    reg.register("loadgen", summary)        # the harness record
    doc = reg.snapshot()
    n = validate_registry_snapshot(doc)
    print(f"\nregistry snapshot: {n} sources, schema {doc['schema']!r}")
    prom = reg.to_prometheus()
    sample = [ln for ln in prom.splitlines()
              if ln.startswith("repro_slo_throughput")]
    print("prometheus exposition sample:")
    for ln in sample[:2]:
        print(f"  {ln}")


if __name__ == "__main__":
    main()
