"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on synthetic data, with checkpoints, a simulated preemption,
and automatic restart (the full fault-tolerant loop).

  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to a quick 60-step run; pass --steps 300 for the full demo)
"""

import argparse
import dataclasses
import shutil
import time

import jax
import numpy as np

from repro import configs
from repro.models import LM
from repro.data.pipeline import SyntheticTokens
from repro.dist.fault import RestartManager
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    # ~100M-param member of the qwen3 family (same code path as the 32B cell)
    cfg = dataclasses.replace(
        configs.get("qwen3-32b"), name="qwen3-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32000, q_chunk=64, kv_chunk=64)
    model = LM(cfg)
    n_params = cfg.n_params()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                          total_steps=args.steps)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    step_jit = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))

    data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq_len, seed=1)
    losses = []

    def step_fn(state, batch):
        state, metrics = step_jit(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 20 == 0:
            print(f"  step {len(losses):4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        return state, metrics

    # simulated preemption mid-run; RestartManager resumes from checkpoint
    fail_at = {args.steps // 2}

    def failure_hook(step):
        if step in fail_at:
            fail_at.remove(step)
            print(f"  !! simulated preemption at step {step}")
            raise RuntimeError("preempted")

    mgr = RestartManager(args.ckpt, save_every=20)
    t0 = time.time()
    state, steps, restarts = mgr.run(state, step_fn, data, args.steps,
                                     failure_hook=failure_hook)
    dt = time.time() - t0
    print(f"done: {steps} steps, {restarts} restart(s), "
          f"{args.steps * args.batch * args.seq_len / dt:.0f} tok/s wall")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f}")
    assert last < first, "training failed to reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
