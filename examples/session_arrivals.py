"""Jobs that arrive and leave: the GraphSession lifecycle API.

The paper's motivating workload is a stream of concurrent queries hitting
one shared graph (Didi: 9B route plans/day).  The legacy API
(`make_run` + `ConcurrentEngine`) declares a fixed job set up-front; this
example drives the redesigned surface instead:

  * route queries (SSSP jobs) ARRIVE while earlier ones are still running
    — `session.submit` at any superstep, no restart, no re-tracing
    (the padded job axis keeps jitted push shapes stable);
  * finished queries LEAVE — `session.detach` frees the slot and the next
    arrival reuses it;
  * the schedule is a pluggable policy object (`TwoLevel` here; swap in
    `Fused`, `Independent`, or `AllBlocks` — or `mesh=` for multi-device).

  PYTHONPATH=src python examples/session_arrivals.py
"""

import numpy as np

from repro.algorithms import SSSP
from repro.core import GraphSession, TwoLevel
from repro.graph import grid_graph


def main():
    side = 30
    csr = grid_graph(side, weighted=True, w_max=5.0, seed=2)
    print(f"road grid {side}x{side}: {csr.n} vertices, {csr.nnz} edges")

    sess = GraphSession(csr, block_size=64, capacity=2, seed=0)
    policy = TwoLevel()
    arrivals = [0, 29, 30 * 29, 30 * 30 - 1, 435, 617]  # corners + interior

    total_steps = 0
    pending = {}
    for t, src in enumerate(arrivals):
        handle = pending[src] = sess.submit(SSSP(source=src))
        print(f"t={t}: query from vertex {src} arrives "
              f"(slot {handle.slot}, {sess.num_active} active, "
              f"capacity {sess.capacity})")
        m = sess.run(policy, max_supersteps=8)       # advance the mix a bit
        total_steps += m.supersteps
        counts = sess.unconverged_counts()           # one reduction, all slots
        for src_done in [s for s, h in pending.items()
                         if counts[h.slot] == 0]:
            dist = sess.detach(pending.pop(src_done))
            reach = int(np.isfinite(dist).sum())
            print(f"     query {src_done} done -> slot freed "
                  f"({reach}/{csr.n} vertices reached)")

    m = sess.run(policy, max_supersteps=50000)       # drain the stragglers
    assert m.converged
    total_steps += m.supersteps
    for src, h in sorted(pending.items()):
        dist = sess.detach(h)
        print(f"drain: query {src} -> "
              f"median finite distance {np.median(dist[np.isfinite(dist)]):.2f}")
    print(f"all {len(arrivals)} arrivals served in {total_steps} shared "
          f"supersteps; final capacity {sess.capacity} slots")


if __name__ == "__main__":
    main()
