"""Benchmark harness — one function per paper table/figure.

  fig4_5_memory_redundancy : Cache-miss proxy (Figs. 4-5) — adjacency-tile
                             stagings, shared (CAJS) vs independent, as the
                             number of concurrent jobs grows.
  fig_convergence          : prioritized iteration (MPDS) vs synchronous
                             all-blocks engine — supersteps + work to
                             convergence (PrIter-style claim).
  fig_throughput           : end-to-end concurrent-job throughput, two-level
                             vs independent vs fused (beyond-paper).
  tab_do_cost              : Function 2 (sampling) vs full-sort selection
                             cost, O(B_N) claim of §4.2.2.
  tab_kernel               : mj_spmm Pallas kernel vs jnp reference
                             (interpret mode on CPU: correctness-grade
                             timing; real speed is a TPU property).
  fig_scaling              : job-sharded two-level engine (repro.dist.graph)
                             — tile loads + supersteps vs device count.
                             Meaningful with several devices, e.g.
                             XLA_FLAGS=--xla_force_host_platform_device_count=4
  fig_arrival              : staggered job arrivals into ONE long-lived
                             GraphSession (submit mid-run, shared staging
                             continues) vs restarting a static engine on
                             every arrival — tile loads and makespan.
  fig_hetero               : MIXED-SEMIRING arrivals (PageRank + SSSP +
                             Katz + BFS) into ONE heterogeneous session —
                             each selected block staged once per superstep
                             serves both the plus-times and the min-plus
                             push — vs the same arrival schedule split into
                             two per-family sessions.  Under TwoLevel and
                             Fused; adds a jobs-mesh variant when several
                             devices are visible.
  fig_sync                 : host-sync amortization of the device-resident
                             scheduler — TwoLevel(backend="device") sweeps
                             steps_per_sync in {1, 2, 8, inf}; the schedule
                             (supersteps, tile_loads, tile_pair_loads) is
                             invariant while host round-trips drop ~K-fold.
                             Warm-timed: a cold run per cadence pays the
                             compile, then detach-all/resubmit and time.
  fig_stream               : EVOLVING graphs (repro.stream) — a session
                             absorbs edge insert/delete batches with
                             incremental apply_updates (tile/overlay
                             edits, exact plus-times delta correction,
                             support-test min-plus reseed, dirty-block
                             priority injection) vs restarting a fresh
                             session per batch.  Under TwoLevel(host) and
                             TwoLevel(device, steps_per_sync=inf), with a
                             jobs-mesh variant when several devices are
                             visible; ends with the compaction invariant
                             (rebuilt tiles bitwise equal to from-scratch,
                             min-plus fixpoints bitwise equal).

  fig_graphscale           : graph-capacity scaling over the 2D
                             (jobs x blocks) mesh (repro.dist.mesh2d) —
                             sweeps graph sizes past a simulated
                             single-device adjacency budget.  Over-budget
                             graphs fall back to out-of-core staging on
                             one device (host-driven supersteps + tile
                             refill) but stay resident once block-sharded
                             S ways; asserts >= 1.5x tile-throughput from
                             1 -> S block shards at fixed job count with
                             bitwise min-plus fixpoints, and reports the
                             compressed-halo traffic.  Needs >= 2 devices,
                             e.g.
                             XLA_FLAGS=--xla_force_host_platform_device_count=4
                             FIG_GRAPHSCALE_SMOKE=1 shrinks the sweep
                             (CI fast job).
  fig_trace                : observability overhead (repro.obs) — the same
                             hetero + streaming workload with telemetry off
                             vs on, host and device_inf backends; asserts
                             the device 1-sync path stays 1-sync with a
                             full per-superstep series at < 10% overhead,
                             and exports a schema-validated Chrome/Perfetto
                             trace alongside the JSON records.
  fig_serve                : serve-front SLO curves (repro.obs.loadgen) —
                             >= 1k deterministic open-loop arrivals
                             (seeded Poisson + diurnal bursts, hundreds of
                             tenants, mixed algorithm families, interleaved
                             update batches) drive a long-lived
                             GraphSession + ConcurrentServeScheduler pair;
                             sweeps the inter-job parallelism knob and
                             reports per-family p50/p99 job latency and
                             throughput-vs-parallelism (Hauck et al.'s
                             trade-off), exporting a schema-validated
                             metrics-registry snapshot.  FIG_SERVE_SMOKE=1
                             shrinks the sweep (CI fast job).

Prints ``name,us_per_call,derived`` CSV rows.  Modes are selectable:
``python benchmarks/run.py [mode ...]`` (default: all).  ``--json [DIR]``
additionally writes each mode's rows as machine-readable records to
``DIR/BENCH_<mode>.json`` (``row()`` keyword fields serialize directly —
no string parsing); with no DIR it defaults to the REPO ROOT, where the
committed ``BENCH_*.json`` records persist the perf trajectory PR over PR
(CI archives the same files as artifacts).  Every record uniformly carries
``host_syncs`` and the stream counters ``updates_applied`` /
``dirty_blocks`` / ``reseed_fraction`` (0 for modes that run no session).
"""

import argparse
import json
import math
import os
import time

import numpy as np

from repro.algorithms import PageRank, PersonalizedPageRank
from repro.core import ConcurrentEngine, make_run
from repro.core.do_select import do_select
from repro.core.priority import cbp_key_sort
from repro.graph import rmat_graph, uniform_graph

ROWS = []
RECORDS = {}          # mode -> [ {name, us_per_call, **fields} ]
_CURRENT_MODE = None  # set by main() around each mode call
_JSON_DIR = None      # --json destination; side artifacts (traces) land here

# every JSON record carries these, 0 when the mode runs no session
UNIFORM_COUNTERS = ("host_syncs", "updates_applied", "dirty_blocks",
                    "reseed_fraction")


def row(name: str, us: float, **fields):
    """One benchmark row: CSV to stdout + a typed JSON record.

    Field values go into the JSON record as-is (pass ints/floats, or a
    pre-formatted string like "1.54x" where the suffix is the point)."""
    derived = ";".join(f"{k}={v}" for k, v in fields.items())
    ROWS.append(f"{name},{us:.1f},{derived}")
    print(ROWS[-1], flush=True)
    rec = {"name": name, "us_per_call": round(us, 1), **fields}
    for k in UNIFORM_COUNTERS:
        rec.setdefault(k, 0)
    RECORDS.setdefault(_CURRENT_MODE, []).append(rec)


def _counters(*ms):
    """The uniform RunMetrics counters of the run(s) a row measures,
    summed over runs (reseed_fraction: mean)."""
    n = max(len(ms), 1)
    return {"host_syncs": sum(m.host_syncs for m in ms),
            "updates_applied": sum(m.updates_applied for m in ms),
            "dirty_blocks": sum(m.dirty_blocks for m in ms),
            "reseed_fraction": round(
                sum(m.reseed_fraction for m in ms) / n, 6)}


def _jobs(n):
    return [PageRank()] + [PersonalizedPageRank(source=7 * i + 1)
                           for i in range(n - 1)]


def fig4_5_memory_redundancy():
    csr = rmat_graph(1500, 8, seed=3)
    for n in (2, 4, 8, 16):
        t0 = time.perf_counter()
        m_s = ConcurrentEngine(make_run(_jobs(n), csr, 64),
                               seed=0).run_two_level(50000)
        t_s = time.perf_counter() - t0
        m_i = ConcurrentEngine(make_run(_jobs(n), csr, 64),
                               seed=0).run_independent(50000)
        assert m_s.converged and m_i.converged
        row(f"fig4_redundancy_j{n}", t_s * 1e6 / max(m_s.supersteps, 1),
            shared_loads=m_s.tile_loads, indep_loads=m_i.tile_loads,
            saving=f"{m_i.tile_loads / max(m_s.tile_loads, 1):.2f}x",
            **_counters(m_s))


def fig_convergence():
    csr = rmat_graph(1500, 8, seed=4)
    for n in (4, 8):
        t0 = time.perf_counter()
        m_p = ConcurrentEngine(make_run(_jobs(n), csr, 64),
                               seed=0).run_two_level(50000)
        t_p = time.perf_counter() - t0
        m_a = ConcurrentEngine(make_run(_jobs(n), csr, 64),
                               seed=0).run_all_blocks(50000)
        assert m_p.converged and m_a.converged
        row(f"fig_convergence_j{n}", t_p * 1e6 / max(m_p.supersteps, 1),
            prio_pushes=m_p.job_block_pushes,
            sync_pushes=m_a.job_block_pushes,
            work_saving=(f"{m_a.job_block_pushes / max(m_p.job_block_pushes, 1):.2f}x"),
            **_counters(m_p))


def fig_throughput():
    csr = rmat_graph(1000, 8, seed=5)
    n = 8
    for name, kwargs, runner in (
            ("two_level", {}, "run_two_level"),
            ("independent", {}, "run_independent"),
            ("fused", {}, "run_fused")):
        eng = ConcurrentEngine(make_run(_jobs(n), csr, 64), seed=0, **kwargs)
        t0 = time.perf_counter()
        m = getattr(eng, runner)(50000)
        dt = time.perf_counter() - t0
        assert m.converged
        row(f"fig_throughput_{name}", dt * 1e6 / n,
            jobs_per_s=f"{n / dt:.2f}", supersteps=m.supersteps,
            **_counters(m))


def tab_do_cost():
    rng = np.random.default_rng(0)
    for bn in (1000, 10000, 100000):
        node_un = rng.integers(0, 50, bn).astype(np.float64)
        p_mean = np.where(node_un > 0, rng.uniform(0.1, 5.0, bn), 0.0)
        q = max(1, int(100 * bn / np.sqrt(bn * 64)))
        t0 = time.perf_counter()
        sel = do_select(node_un, p_mean, q, np.random.default_rng(1))
        t_do = time.perf_counter() - t0
        t0 = time.perf_counter()
        live = np.nonzero(node_un > 0)[0]
        full = live[cbp_key_sort(node_un[live], p_mean[live])][:q]
        t_full = time.perf_counter() - t0
        overlap = len(set(sel.tolist()) & set(full.tolist())) / max(len(full), 1)
        row(f"tab_do_cost_B{bn}", t_do * 1e6,
            full_sort_us=round(t_full * 1e6),
            speedup=f"{t_full / max(t_do, 1e-9):.1f}x",
            top_q_overlap=round(overlap, 2))


def tab_kernel():
    import jax.numpy as jnp
    from repro.kernels.mj_spmm.ops import mj_spmm
    from repro.kernels.mj_spmm.ref import mj_spmm_ref
    rng = np.random.default_rng(0)
    q, k, j, vb = 4, 4, 8, 128
    d = jnp.asarray(rng.standard_normal((q, j, vb)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((q, k, vb, vb)), jnp.float32)
    for name, fn in (("pallas_interp",
                      lambda: mj_spmm(d, t, "plus_times", interpret=True)),
                     ("jnp_ref", lambda: mj_spmm_ref(d, t, "plus_times"))):
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn()
            out.block_until_ready()
        dt = (time.perf_counter() - t0) / 3
        row(f"tab_kernel_{name}", dt * 1e6,
            shape=f"q{q}k{k}j{j}vb{vb}", note="interpret-mode-correctness")
    err = float(jnp.max(jnp.abs(
        mj_spmm(d, t, "plus_times", interpret=True)
        - mj_spmm_ref(d, t, "plus_times"))))
    row("tab_kernel_allclose", 0.0, max_abs_err=f"{err:.2e}")


def fig_scaling():
    """Multi-device concurrent jobs: shard the job axis over 1..D devices.
    Supersteps and tile loads are schedule-invariants (the sharded run is
    bit-identical to single-device; tiles are REPLICATED, so each device
    still stages every selected block once per superstep).  What the job
    axis divides is the per-device PUSH work — each device runs the
    (job, block) processing events of only its local J/d jobs against its
    locally staged tiles (per-device CAJS)."""
    import jax
    from repro.dist.graph import make_job_mesh

    csr = rmat_graph(1000, 8, seed=6)
    n_jobs, n_dev = 8, len(jax.devices())
    ref = None
    for d in sorted({1, 2, n_dev} | {n_dev // 2 or 1}):
        if d < 1 or n_dev % d or n_jobs % d:
            continue
        eng = ConcurrentEngine(make_run(_jobs(n_jobs), csr, 64), seed=0)
        t0 = time.perf_counter()
        m = eng.run_two_level(50000, mesh=make_job_mesh(d))
        dt = time.perf_counter() - t0
        assert m.converged
        if ref is None:
            ref = eng.results()
        else:
            np.testing.assert_array_equal(eng.results(), ref)
        row(f"fig_scaling_d{d}", dt * 1e6 / max(m.supersteps, 1),
            devices=d, jobs=n_jobs, supersteps=m.supersteps,
            tile_loads_per_device=m.tile_loads,
            job_pushes_per_device=round(m.job_block_pushes / d),
            **_counters(m))


def fig_arrival():
    """The api_redesign claim: a long-lived session absorbs arrivals without
    restarting.  `session_*` submits each job into the running GraphSession
    every `gap` supersteps; `restart_*` models the static API — every
    arrival rebuilds the whole job set and re-runs it from scratch."""
    from repro.core import GraphSession, TwoLevel

    csr = rmat_graph(800, 8, seed=7)
    n_arrivals, gap = 4, 10
    algs = _jobs(n_arrivals)

    t0 = time.perf_counter()
    sess = GraphSession(csr, 64, capacity=n_arrivals, seed=0)
    policy = TwoLevel()
    handles, s_loads, s_steps, s_ms = [], 0, 0, []
    for alg in algs:
        handles.append(sess.submit(alg))
        m = sess.run(policy, max_supersteps=gap)
        s_loads += m.tile_loads
        s_steps += m.supersteps
        s_ms.append(m)
    m = sess.run(policy, 50000)
    assert m.converged
    s_loads += m.tile_loads
    s_steps += m.supersteps
    s_ms.append(m)
    t_sess = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_loads = r_steps = 0
    for k in range(1, n_arrivals + 1):
        eng = ConcurrentEngine(make_run(algs[:k], csr, 64), seed=0)
        mk = eng.run_two_level(50000)
        assert mk.converged
        r_loads += mk.tile_loads
        r_steps += mk.supersteps
    t_restart = time.perf_counter() - t0

    row("fig_arrival", t_sess * 1e6 / max(s_steps, 1),
        session_tile_loads=s_loads, restart_tile_loads=r_loads,
        session_supersteps=s_steps, restart_supersteps=r_steps,
        session_makespan_s=round(t_sess, 2),
        restart_makespan_s=round(t_restart, 2),
        load_saving=f"{r_loads / max(s_loads, 1):.2f}x",
        **_counters(*s_ms))


def fig_hetero():
    """Cross-family CAJS: a heterogeneous session stages each selected
    block ONCE per superstep and dispatches it through the plus-times AND
    the min-plus push, so its tile loads sit strictly below the sum of two
    per-family sessions absorbing the same arrival schedule.  All worlds
    see the same global arrival clock (a session whose family has nothing
    pending simply contributes converged 0-load supersteps)."""
    import jax
    from repro.algorithms import Katz, SSSP, BFS
    from repro.core import GraphSession, TwoLevel, Fused
    from repro.dist.graph import make_job_mesh

    # uniform degree keeps Katz contractive (alpha * rho(A) < 1; rmat hubs
    # would diverge it) and gives exact PageRank row sums.  The long-lived
    # plus-times trio arrives first; min-plus pairs keep arriving at the
    # pair's own convergence cadence, so BOTH families stay hot over the
    # same span — the regime the cross-family sharing targets.
    csr = uniform_graph(900, 8, seed=9)
    gap = 7
    rng = np.random.default_rng(0)
    waves = [[PageRank(), PersonalizedPageRank(source=44),
              Katz(alpha=0.02)]]
    waves += [[SSSP(source=int(rng.integers(900))),
               BFS(source=int(rng.integers(900)))] for _ in range(12)]

    def drive(split: bool, policy_cls, mesh=None):
        """One arrival timeline; split=True routes each family to its own
        session (both sessions still live through every global gap)."""
        sessions = {}
        loads = steps = 0
        ms = []
        t0 = time.perf_counter()
        for wave in waves:
            for alg in wave:
                key = alg.semiring if split else "shared"
                if key not in sessions:
                    sessions[key] = GraphSession(csr, 64, capacity=4,
                                                 seed=0)
                sessions[key].submit(alg)
            for s in sessions.values():
                m = s.run(policy_cls(), max_supersteps=gap, mesh=mesh)
                loads += m.tile_loads
                steps += m.supersteps
                ms.append(m)
        for s in sessions.values():
            m = s.run(policy_cls(), 50000, mesh=mesh)
            assert m.converged
            loads += m.tile_loads
            steps += m.supersteps
            ms.append(m)
        return loads, steps, time.perf_counter() - t0, ms

    meshes = [("", None)]
    if len(jax.devices()) > 1:
        meshes.append((f"_mesh{len(jax.devices())}",
                       make_job_mesh(len(jax.devices()))))
    for policy_cls, pname in ((TwoLevel, "two_level"), (Fused, "fused")):
        for tag, mesh in meshes:
            h_loads, h_steps, h_t, h_ms = drive(False, policy_cls, mesh)
            s_loads, s_steps, s_t, _ = drive(True, policy_cls, mesh)
            assert h_loads < s_loads, (h_loads, s_loads)
            row(f"fig_hetero_{pname}{tag}", h_t * 1e6 / max(h_steps, 1),
                hetero_tile_loads=h_loads, split_tile_loads=s_loads,
                hetero_supersteps=h_steps, split_supersteps=s_steps,
                saving=f"{s_loads / max(h_loads, 1):.2f}x", target="1.5x",
                **_counters(*h_ms))


def fig_sync():
    """Host-sync amortization (device-resident two-level scheduling): the
    SAME schedule — identical per-step sampling keys fold_in(seed, step),
    so identical supersteps and tile_loads — at every sync cadence, while
    host round-trips drop ~K-fold.  steps_per_sync=inf is `Fused`: one
    while_loop, one sync.

    Timing excludes compile: each cadence runs once cold on its session
    (jit warm-up), then detaches every job, resubmits the same algorithms
    and times the warm rerun — the warm superstep count is identical
    across cadences, so the fold_in key stream (and with it the staging
    invariant asserted below) is preserved.  `tile_pair_loads` is the
    real-byte staging unit: nonzero (src, dst) block pairs moved (the
    sparse BlockPairs accounting), invariant across cadences like
    tile_loads."""
    from repro.core import GraphSession, TwoLevel

    csr = rmat_graph(1200, 8, seed=8)
    algs = _jobs(8)
    base = None
    for k in (1, 2, 8, math.inf):
        sess = GraphSession(csr, 64, capacity=len(algs), seed=0)
        policy = TwoLevel(backend="device", steps_per_sync=k)
        handles = [sess.submit(alg) for alg in algs]
        warm = sess.run(policy, 50000)           # compile warm-up
        assert warm.converged
        for h in handles:
            sess.detach(h)
        for alg in algs:
            sess.submit(alg)
        t0 = time.perf_counter()
        m = sess.run(policy, 50000)
        dt = time.perf_counter() - t0
        assert m.converged
        if base is None:
            base = m
        else:   # the acceptance invariant: amortization changes NO staging
            assert m.tile_loads == base.tile_loads, (m.tile_loads,
                                                     base.tile_loads)
            assert m.supersteps == base.supersteps
            assert m.tile_pair_loads == base.tile_pair_loads
        tag = "inf" if k == math.inf else str(k)
        row(f"fig_sync_k{tag}", dt * 1e6 / max(m.supersteps, 1),
            steps_per_sync=tag, supersteps=m.supersteps,
            tile_loads=m.tile_loads, tile_pair_loads=m.tile_pair_loads,
            wall_s=round(dt, 3),
            sync_reduction=(f"{base.host_syncs / max(m.host_syncs, 1):.2f}x"),
            **_counters(m))


def fig_stream():
    """The evolving-graph claim: incremental `apply_updates` converges
    with >=2x fewer tile loads (and no more supersteps) than restarting a
    fresh session per batch — the warm job state plus the dirty-block
    priority injection confine each batch's work to the affected region.
    Min-plus fixpoints stay bitwise exact; after compaction the rebuilt
    tiles are bitwise identical to a from-scratch build on the final CSR.

    Timing excludes compile on BOTH legs (the fig_sync recipe).
    Incremental: the overlay is pre-sized so batches never grow it
    mid-loop (capacity growth is a retrace), and a warm-up batch from a
    DISJOINT mutation stream compiles the apply/dirty-boost path before
    detach-all + resubmit + re-converge; only then does the real stream
    start the clock, from the warmed base graph csr0 + warm-up batch.
    Restart: every per-batch fresh session runs once cold, detaches all,
    resubmits, and only the warm rerun is timed."""
    import jax
    from repro.algorithms import SSSP
    from repro.core import GraphSession, TwoLevel
    from repro.dist.graph import make_job_mesh
    from repro.graph import mutation_stream
    from repro.stream import apply_to_csr

    csr_raw = uniform_graph(800, 6, seed=10)
    algs = [PageRank(), PersonalizedPageRank(source=31),
            SSSP(source=0), SSSP(source=17)]
    warm_batch = mutation_stream(csr_raw, 1, inserts_per_batch=10,
                                 deletes_per_batch=5, seed=77)[0]
    csr0 = apply_to_csr(csr_raw, warm_batch)   # the timed base graph
    batches = mutation_stream(csr0, 5, inserts_per_batch=10,
                              deletes_per_batch=5, seed=11)
    csr_fin = csr0
    for b in batches:
        csr_fin = apply_to_csr(csr_fin, b)

    variants = [("host", dict(), None),
                ("device_inf", dict(backend="device",
                                    steps_per_sync=math.inf), None)]
    if len(jax.devices()) > 1:
        mesh = make_job_mesh(len(jax.devices()))
        variants += [(f"host_mesh{len(jax.devices())}", dict(), mesh),
                     (f"device_inf_mesh{len(jax.devices())}",
                      dict(backend="device", steps_per_sync=math.inf), mesh)]

    last_sess = last_handles = None
    for tag, kw, mesh in variants:
        # warm the whole incremental path: base superstep compiles on the
        # raw graph, the warm-up batch compiles apply/dirty-boost at the
        # pre-sized overlay capacity, then detach-all + resubmit resets
        # the job state without touching any compiled shape
        sess = GraphSession(csr_raw, 64, capacity=2, seed=0,
                            overlay_capacity=64)
        handles = [sess.submit(a) for a in algs]
        assert sess.run(TwoLevel(**kw), 50000, mesh=mesh).converged
        sess.apply_updates(warm_batch)
        assert sess.run(TwoLevel(**kw), 50000, mesh=mesh).converged
        for h in handles:
            sess.detach(h)
        handles = [sess.submit(a) for a in algs]
        assert sess.run(TwoLevel(**kw), 50000, mesh=mesh).converged

        t0 = time.perf_counter()
        i_loads = i_steps = 0
        i_ms = []
        for b in batches:
            sess.apply_updates(b)
            m = sess.run(TwoLevel(**kw), 50000, mesh=mesh)
            assert m.converged
            i_loads += m.tile_loads
            i_steps += m.supersteps
            i_ms.append(m)
        t_inc = time.perf_counter() - t0

        t_res = 0.0
        r_loads = r_steps = 0
        csr_k = csr0
        for b in batches:
            csr_k = apply_to_csr(csr_k, b)
            s2 = GraphSession(csr_k, 64, capacity=2, seed=0)
            h2 = [s2.submit(a) for a in algs]
            assert s2.run(TwoLevel(**kw), 50000, mesh=mesh).converged
            for h in h2:                        # cold run paid the compile;
                s2.detach(h)                    # time the warm rerun only
            for a in algs:
                s2.submit(a)
            t0 = time.perf_counter()
            mk = s2.run(TwoLevel(**kw), 50000, mesh=mesh)
            t_res += time.perf_counter() - t0
            assert mk.converged
            r_loads += mk.tile_loads
            r_steps += mk.supersteps
        # the acceptance invariant: incremental work is a strict subset
        assert i_loads * 2 <= r_loads, (tag, i_loads, r_loads)
        assert i_steps <= r_steps, (tag, i_steps, r_steps)
        row(f"fig_stream_{tag}", t_inc * 1e6 / max(i_steps, 1),
            inc_tile_loads=i_loads, restart_tile_loads=r_loads,
            inc_supersteps=i_steps, restart_supersteps=r_steps,
            inc_makespan_s=round(t_inc, 2),
            restart_makespan_s=round(t_res, 2),
            load_saving=f"{r_loads / max(i_loads, 1):.2f}x", target="2x",
            **_counters(*i_ms))
        last_sess, last_handles = sess, handles

    # overlay-after-compaction invariant on the last (mesh-free falls back
    # to the device_inf) session: rebuilt tiles bitwise == from-scratch,
    # min-plus fixpoints bitwise == a fresh session on the final CSR
    last_sess.compact()
    assert last_sess.run(TwoLevel(), 50000).converged
    fresh = GraphSession(csr_fin, 64, capacity=2, seed=0)
    fh = [fresh.submit(a) for a in algs]
    assert fresh.run(TwoLevel(), 50000).converged
    import jax
    for g_s, g_f in zip(last_sess.view_groups(), fresh.view_groups()):
        t_s, t_f = jax.device_get((g_s.graph.tiles, g_f.graph.tiles))
        np.testing.assert_array_equal(t_s, t_f)
    for h, f, a in zip(last_handles, fh, algs):
        if a.semiring == "min_plus":
            np.testing.assert_array_equal(last_sess.result(h),
                                          fresh.result(f))
        else:
            np.testing.assert_allclose(last_sess.result(h),
                                       fresh.result(f),
                                       rtol=1e-3, atol=1e-5)
    row("fig_stream_compaction", 0.0,
        tiles_bitwise="ok", minplus_fixpoint_bitwise="ok",
        plus_times="allclose")


def fig_graphscale():
    """Graph-capacity scaling (the 2D-mesh tentpole): sweep graph sizes
    past a simulated single-device adjacency budget CAP.  A graph whose
    sparse BlockPairs tile set exceeds CAP cannot stay resident on one
    device, so the solo baseline falls back to OUT-OF-CORE staging: the
    host drives every superstep (a fused device loop cannot span a
    refill) and re-uploads the evicted tile working set before each one
    — both costs are real, measured device_put + host orchestration, not
    modelled constants.  The same graph block-sharded S ways holds
    tiles/S per shard, stays under CAP, keeps the one-sync fused loop.

    `tile_scaling` is the asserted >= 1.5x acceptance metric for every
    over-budget size at fixed job count: aggregate pair tiles over the
    LARGEST per-shard slice — the per-superstep device critical path in
    tile units, i.e. how much adjacency each superstep processes per
    unit of per-device work once shards run concurrently.  It is
    measured from the actual dst-range partition (repro.dist.mesh2d
    .partition_block_pairs), so skewed rmat block rows lower it below
    the ideal S.  Wall times are recorded alongside but NOT asserted:
    forced host "devices" timeshare one CPU, so sharded wall clock is
    correctness-grade only (same caveat as tab_kernel).  Min-plus
    fixpoints are asserted BITWISE equal to the solo run; `halo_bytes`
    (RunMetrics) — the cross-shard frontier traffic — is asserted
    bounded by the staged frontier, not the tile set, per superstep.
    The final row re-runs the largest size with the int8 error-feedback
    halo (compress_halo) to show the payload shrink at an unchanged
    min-plus fixpoint.  FIG_GRAPHSCALE_SMOKE=1 shrinks the sweep to two
    sizes and S=2 for the CI fast job."""
    import jax
    from repro.algorithms import SSSP
    from repro.core import Fused, GraphSession, TwoLevel
    from repro.dist.graph import shard_session
    from repro.dist.mesh2d import make_mesh2d

    n_dev = len(jax.devices())
    smoke = bool(int(os.environ.get("FIG_GRAPHSCALE_SMOKE", "0")))
    S = 2 if smoke else min(4, n_dev)
    if n_dev < S or S < 2:
        row("fig_graphscale_skipped", 0.0,
            note=f"needs >= 2 devices, have {n_dev}")
        return
    BLOCK = 32
    sizes = (256, 512) if smoke else (256, 512, 1024)
    algs = [PageRank(), PersonalizedPageRank(source=31),
            SSSP(source=0), SSSP(source=17)]
    mesh = make_mesh2d(1, S)

    def build(csr):
        s = GraphSession(csr, BLOCK, capacity=2, seed=0)
        return s, [s.submit(a) for a in algs]

    def tile_bytes(s):
        # int() folds a host-side shape product, not a device value
        return sum(int(np.prod(s._pair_data(g).tiles.shape)) * 4  # noqa: RPA002
                   for g in s.view_groups())

    csrs = {n: rmat_graph(n, 6, seed=20) for n in sizes}
    solo = {n: build(csrs[n]) for n in sizes}
    T = {n: tile_bytes(solo[n][0]) for n in sizes}
    # the simulated budget: every sweep point but the largest fits solo
    CAP = (T[sizes[-2]] + T[sizes[-1]]) // 2

    m2 = None
    for n in sizes:
        fits = T[n] <= CAP
        sess, hs = solo[n]
        if fits:                      # resident: the fused one-sync loop
            assert sess.run(Fused(), 50000).converged
            for h in hs:
                sess.detach(h)
            hs = [sess.submit(a) for a in algs]
            t0 = time.perf_counter()
            m = sess.run(Fused(), 50000)
            dt_solo = time.perf_counter() - t0
            assert m.converged
            solo_ms = [m]
        else:                         # out-of-core: host superstep loop
            host_tiles = [np.asarray(jax.device_get(
                sess._pair_data(g).tiles)) for g in sess.view_groups()]
            assert sess.run(TwoLevel(), 50000).converged   # compile warm
            for h in hs:
                sess.detach(h)
            hs = [sess.submit(a) for a in algs]
            t0 = time.perf_counter()
            solo_ms = []
            for _ in range(50000):
                for ht in host_tiles:          # refill the evicted tiles
                    jax.device_put(ht).block_until_ready()
                m = sess.run(TwoLevel(), max_supersteps=1)
                solo_ms.append(m)
                if m.converged:
                    break
            dt_solo = time.perf_counter() - t0
        res_solo = res_last = [sess.result(h) for h in hs]

        s2, h2 = build(csrs[n])
        assert s2.run(Fused(), 50000, mesh=mesh).converged   # compile warm
        # int() folds a host-side shape product, not a device value
        per_shard = sum(int(np.prod(s2._pair_shards(g).tiles.shape[1:]))  # noqa: RPA002
                        * 4 for g in s2.view_groups())
        if not fits:                  # the capacity story holds: each
            # shard's slice fits the budget the whole set blew through
            assert per_shard <= CAP < T[n], (per_shard, CAP, T[n])
        # per-superstep critical path in pair-tile units: total pairs
        # over the heaviest shard's dst-range slice of each view
        total_pairs = max_shard_pairs = 0
        for g in s2.view_groups():
            dst = np.asarray(jax.device_get(s2._pair_data(g).dst))
            bl = g.graph.num_blocks // S
            cnt = np.array([int(((dst >= i * bl) & (dst < (i + 1) * bl))
                                .sum()) for i in range(S)])
            total_pairs += int(cnt.sum())
            max_shard_pairs += int(cnt.max())
        scaling = total_pairs / max(max_shard_pairs, 1)
        for h in h2:
            s2.detach(h)
        h2 = [s2.submit(a) for a in algs]
        t0 = time.perf_counter()
        m2 = s2.run(Fused(), 50000)
        dt_sh = time.perf_counter() - t0
        assert m2.converged
        np.testing.assert_array_equal(s2.result(h2[2]), res_solo[2])
        np.testing.assert_array_equal(s2.result(h2[3]), res_solo[3])
        np.testing.assert_allclose(s2.result(h2[0]), res_solo[0],
                                   rtol=1e-3, atol=1e-4)
        if not fits:                  # the acceptance bound, 1 -> S shards
            assert scaling >= 1.5, (n, scaling, total_pairs,
                                    max_shard_pairs)
        # halo is frontier-sized, never tile-sized
        assert 0 < m2.halo_bytes / max(m2.supersteps, 1) < T[n]
        row(f"fig_graphscale_n{n}", dt_sh * 1e6 / max(m2.supersteps, 1),
            vertices=n, block_shards=S,
            tile_mb=round(T[n] / 1e6, 3), cap_mb=round(CAP / 1e6, 3),
            per_shard_mb=round(per_shard / 1e6, 3), fits_solo=int(fits),
            pair_tiles=total_pairs, max_shard_pair_tiles=max_shard_pairs,
            tile_scaling=f"{scaling:.2f}x", target="1.5x",
            solo_wall_s=round(dt_solo, 3), shard_wall_s=round(dt_sh, 3),
            wall_note="cpu-timeshared-correctness-grade",
            supersteps=m2.supersteps,
            halo_bytes=round(m2.halo_bytes),
            halo_kb_per_step=round(
                m2.halo_bytes / max(m2.supersteps, 1) / 1e3, 2),
            minplus="bitwise", **_counters(m2, *solo_ms))

    # int8 error-feedback halo on the largest (over-budget) size
    n = sizes[-1]
    s3, h3 = build(csrs[n])
    shard_session(mesh, s3, axes=("jobs", "blocks"), compress_halo=True)
    assert s3.run(Fused(), 50000).converged
    for h in h3:
        s3.detach(h)
    h3 = [s3.submit(a) for a in algs]
    t0 = time.perf_counter()
    m3 = s3.run(Fused(), 50000)
    dt3 = time.perf_counter() - t0
    assert m3.converged
    np.testing.assert_array_equal(s3.result(h3[2]), res_last[2])
    assert 0 < m3.halo_bytes < m2.halo_bytes, (m3.halo_bytes, m2.halo_bytes)
    row(f"fig_graphscale_n{n}_halo8", dt3 * 1e6 / max(m3.supersteps, 1),
        vertices=n, block_shards=S, halo_bytes=round(m3.halo_bytes),
        f32_halo_bytes=round(m2.halo_bytes),
        halo_shrink=f"{m2.halo_bytes / max(m3.halo_bytes, 1):.2f}x",
        supersteps=m3.supersteps, minplus="bitwise", **_counters(m3))


def fig_trace():
    """Observability overhead (repro.obs): the SAME hetero + streaming
    workload with telemetry off vs on, host and device_inf backends.
    Timing is best-of-N of RunMetrics.wall_time_s after a compile warm-up
    (detach/resubmit keeps shapes, so repeats never retrace).  Asserts the
    tentpole invariant — TwoLevel(device, steps_per_sync=inf) with
    telemetry returns the full per-superstep series at host_syncs == 1,
    schedule unchanged, at < 10% overhead — and exports a schema-validated
    Chrome/Perfetto trace next to the JSON records."""
    from repro.algorithms import SSSP
    from repro.core import GraphSession, TwoLevel
    from repro.graph import mutation_stream
    from repro.obs import validate_trace_events

    csr = uniform_graph(900, 8, seed=12)
    algs = [PageRank(), PersonalizedPageRank(source=44), SSSP(source=0),
            SSSP(source=17)]
    batches = mutation_stream(csr, 2, inserts_per_batch=8,
                              deletes_per_batch=4, seed=13)

    def drive(telemetry, kw, repeats=3):
        sess = GraphSession(csr, 64, capacity=4, seed=0,
                            telemetry=telemetry)
        handles = [sess.submit(a) for a in algs]
        warm = sess.run(TwoLevel(**kw), 50000)   # compile warm-up
        assert warm.converged
        best, m = math.inf, warm
        for _ in range(repeats):
            for h in handles:
                sess.detach(h)
            handles = [sess.submit(a) for a in algs]
            m = sess.run(TwoLevel(**kw), 50000)
            assert m.converged
            best = min(best, m.wall_time_s)
        for b in batches:                        # streaming leg: trace the
            sess.apply_updates(b)                # apply/dirty-boost story
            assert sess.run(TwoLevel(**kw), 50000).converged
        return sess, m, best

    for tag, kw in (("host", dict()),
                    ("device_inf", dict(backend="device",
                                        steps_per_sync=math.inf))):
        _, m_off, t_off = drive(None, kw)
        sess, m_on, t_on = drive(True, kw)
        # telemetry must observe, not perturb: identical schedule ...
        assert m_on.supersteps == m_off.supersteps, (m_on, m_off)
        assert m_on.tile_loads == m_off.tile_loads
        tel = m_on.telemetry
        # ... with a complete series even on the 1-sync device path
        assert tel is not None and len(tel) == m_on.supersteps
        assert int(tel.tile_loads.sum()) == m_on.tile_loads
        if tag == "device_inf":
            assert m_on.host_syncs == 1, m_on.host_syncs
        overhead = t_on / max(t_off, 1e-9) - 1.0
        if tag == "device_inf":   # the acceptance bound (host-path wall
            # time is python-bookkeeping noise at this graph size)
            assert overhead < 0.10, f"telemetry overhead {overhead:.1%}"
        n_events = validate_trace_events(sess.trace.to_json())
        if _JSON_DIR:
            path = os.path.join(_JSON_DIR, f"TRACE_{tag}.json")
            sess.trace.export(path)
            print(f"wrote {path}", flush=True)
        row(f"fig_trace_{tag}", t_on * 1e6 / max(m_on.supersteps, 1),
            telemetry_off_s=round(t_off, 4), telemetry_on_s=round(t_on, 4),
            overhead=f"{overhead * 100:.1f}%", supersteps=m_on.supersteps,
            series_len=len(tel), trace_events=n_events, target="10%",
            **_counters(m_on))


def fig_serve():
    """Serve-front SLO observability (ROADMAP item 3): open-loop arrivals
    through the two-level admission scheduler into a long-lived
    GraphSession, swept over the inter-job parallelism knob.

    Open loop means the arrival schedule is FIXED before the run: a slow
    configuration builds queue (and p99 latency) instead of throttling
    its own offered load, so the throughput-vs-parallelism curve exposes
    the real intra- vs inter-query trade-off (Hauck et al., PAPERS.md).
    Everything is seeded and latencies are counted in scheduler ticks, so
    the records — and the regression gate anchored on them — reproduce
    bit-for-bit.  The last sweep point's ServeMetrics + SLOTracker +
    harness summary are snapshotted through a MetricsRegistry
    (schema-validated) to REGISTRY_fig_serve.json next to the records."""
    from repro.core import GraphSession
    from repro.obs import (LoadgenConfig, MetricsRegistry, OpenLoopHarness,
                           SLOTarget, SLOTracker,
                           validate_registry_snapshot)
    from repro.serve.concurrent import ConcurrentServeScheduler

    smoke = bool(int(os.environ.get("FIG_SERVE_SMOKE", "0")))
    if smoke:
        n_vertices, ticks, base_rate, tenants = 256, 160, 0.25, 40
        sweep, drain, update_every = (1, 4), 1200, 50
    else:
        n_vertices, ticks, base_rate, tenants = 512, 1800, 0.62, 200
        sweep, drain, update_every = (1, 2, 4, 8, 16), 1500, 300

    csr = rmat_graph(n_vertices, 5, seed=21)
    block = 64
    n_groups = -(-csr.n // block)
    cfg = LoadgenConfig(seed=33, ticks=ticks, base_rate=base_rate,
                        burst_amplitude=0.6, burst_period=max(ticks // 4, 1),
                        n_tenants=tenants, update_every=update_every)
    targets = [SLOTarget(family="*", p99_latency_steps=600.0,
                         deadline_steps=1000.0)]
    curve = {}
    last = None
    for max_running in sweep:
        sess = GraphSession(csr, block, capacity=max(4, max_running),
                            seed=0)
        slo = SLOTracker(targets=targets, window=512)
        sched = ConcurrentServeScheduler(n_groups, batch_budget=max_running,
                                         seed=5, slo=slo)
        h = OpenLoopHarness(sess, sched, cfg, max_running=max_running,
                            drain_ticks=drain)
        t0 = time.perf_counter()
        s = h.run()
        wall = time.perf_counter() - t0
        if not smoke:
            assert s["arrivals"] >= 1000, s["arrivals"]
        curve[max_running] = s["throughput_per_tick"]
        last = (sched, slo, s)
        lat = s["latency_ticks"]
        row(f"fig_serve_p{max_running}", wall * 1e6 / max(s["ticks"], 1),
            max_running=max_running, arrivals=s["arrivals"],
            admitted=s["admitted"], completed=s["completed"],
            ticks=s["ticks"], supersteps=s["supersteps"],
            p50_latency_ticks=round(lat["p50"], 6),
            p99_latency_ticks=round(lat["p99"], 6),
            throughput_per_tick=s["throughput_per_tick"],
            latency_by_family={
                fam: {"p50": round(v["p50"], 6), "p99": round(v["p99"], 6),
                      "count": v["count"]}
                for fam, v in s["latency_by_family"].items()},
            wall_s=round(wall, 3),
            tile_loads=s["counters"]["tile_loads"],
            tile_pair_loads=s["counters"]["tile_pair_loads"],
            halo_bytes=s["counters"]["halo_bytes"],
            host_syncs=s["counters"]["host_syncs"],
            updates_applied=s["updates_applied"])
    # open loop delivers the trade-off: more inter-job parallelism must
    # not reduce completions on the same offered load
    ms = sorted(curve)
    assert curve[ms[-1]] >= curve[ms[0]], curve
    sched, slo, s = last
    registry = MetricsRegistry()
    registry.register("serve", sched.metrics)
    registry.register("slo", slo)
    registry.register("loadgen", s)
    registry.register("sweep", {"throughput_per_tick_by_parallelism":
                                {str(k): v for k, v in curve.items()}})
    doc = registry.snapshot()
    validate_registry_snapshot(doc)
    if _JSON_DIR:
        path = os.path.join(_JSON_DIR, "REGISTRY_fig_serve.json")
        registry.export(path)
        print(f"wrote {path}", flush=True)


MODES = {
    "fig4_5_memory_redundancy": fig4_5_memory_redundancy,
    "fig_convergence": fig_convergence,
    "fig_throughput": fig_throughput,
    "tab_do_cost": tab_do_cost,
    "tab_kernel": tab_kernel,
    "fig_scaling": fig_scaling,
    "fig_arrival": fig_arrival,
    "fig_hetero": fig_hetero,
    "fig_sync": fig_sync,
    "fig_stream": fig_stream,
    "fig_graphscale": fig_graphscale,
    "fig_trace": fig_trace,
    "fig_serve": fig_serve,
}


def main(argv=None) -> None:
    global _CURRENT_MODE, _JSON_DIR
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modes", nargs="*", metavar="mode",
                    help=f"benchmark modes to run (default: all) "
                         f"from: {', '.join(MODES)}")
    ap.add_argument("--json", metavar="DIR", nargs="?", default=None,
                    const=os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))),
                    help="write per-mode records to DIR/BENCH_<mode>.json "
                         "(no DIR: the repo root, where committed records "
                         "persist the perf trajectory)")
    args = ap.parse_args(argv)
    unknown = [m for m in args.modes if m not in MODES]
    if unknown:
        ap.error(f"unknown mode(s) {unknown}; choose from {list(MODES)}")
    if args.json:
        os.makedirs(args.json, exist_ok=True)
        _JSON_DIR = args.json
    print("name,us_per_call,derived")
    for name in (args.modes or MODES):
        _CURRENT_MODE = name
        MODES[name]()
    if args.json:
        for name, records in RECORDS.items():
            path = os.path.join(args.json, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"mode": name, "records": records}, f, indent=1)
            print(f"wrote {path}")
    print(f"\n{len(ROWS)} benchmark rows OK")


if __name__ == "__main__":
    main()
