"""Property-based overlay/compaction correctness for evolving graphs.

The tentpole invariant of repro.stream: after ANY sequence of update
batches absorbed incrementally (tile edits + delta-COO overlay + job-state
invalidation), compact-then-run lands every job on the fixpoint of a
FRESH session built on the rebuilt CSR — bitwise for min-plus (the
fixpoint is schedule-invariant and compaction makes the tiles bit-exact),
within the plus-times tolerance — across all four schedule policies on
BOTH backends.  Random small CSRs × heterogeneous job mixes × random
mutation streams probe it; the wider policy × backend grid is heavy and
runs in the slow job.

Runs under the real `hypothesis` when installed, else the deterministic
shim in tests/_hypothesis_shim.py (registered by conftest).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms import BFS, Katz, PageRank, PersonalizedPageRank, SSSP
from repro.algorithms.base import MIN_PLUS
from repro.core import AllBlocks, Fused, GraphSession, Independent, TwoLevel
from repro.graph import mutation_stream
from repro.graph.structure import CSRGraph
from repro.stream import apply_to_csr

pytestmark = pytest.mark.slow

BLOCK = 16


def _random_csr(seed: int, n: int, deg: int, weighted: bool) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = n * deg
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    w = (rng.uniform(0.5, 4.0, m).astype(np.float32) if weighted else None)
    return CSRGraph.from_edges(n, src, dst, w)


def _job_mix(rng: np.random.Generator, n: int, weighted: bool):
    """2-3 jobs across both families (PageRank/PPR only on unit weights,
    as in test_policy_properties)."""
    pool = [
        lambda: Katz(alpha=0.02),
        lambda: SSSP(source=int(rng.integers(n))),
        lambda: BFS(source=int(rng.integers(n))),
    ]
    if not weighted:
        pool += [
            lambda: PageRank(damping=float(rng.uniform(0.6, 0.9))),
            lambda: PersonalizedPageRank(source=int(rng.integers(n))),
        ]
    k = int(rng.integers(2, 4))
    return [pool[int(rng.integers(len(pool)))]() for _ in range(k)]


def _assert_same_fixpoint(alg, got, want):
    if alg.semiring == MIN_PLUS:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def _evolve_and_compare(csr, algs, policy, batches, seed, *,
                        overlay_capacity=2):
    """Drive `policy` through the stream incrementally, compact, reconverge,
    and compare against a fresh session on the rebuilt CSR."""
    sess = GraphSession(csr, BLOCK, capacity=2, seed=seed,
                        overlay_capacity=overlay_capacity)
    handles = [sess.submit(a) for a in algs]
    sess.run(policy, max_supersteps=6)            # updates land mid-run
    csr_k = csr
    for b in batches:
        sess.apply_updates(b)
        sess.run(policy, max_supersteps=4)
        csr_k = apply_to_csr(csr_k, b)
    sess.compact()
    assert sess.run(policy, 50000).converged, policy.name

    fresh = GraphSession(csr_k, BLOCK, capacity=2, seed=seed)
    fh = [fresh.submit(a) for a in algs]
    assert fresh.run(TwoLevel(), 50000).converged
    # compaction == from-scratch build, bit for bit
    for g_s, g_f in zip(sess.view_groups(), fresh.view_groups()):
        assert g_s.overlay.capacity == 0
        np.testing.assert_array_equal(np.asarray(g_s.graph.tiles),
                                      np.asarray(g_f.graph.tiles))
        np.testing.assert_array_equal(np.asarray(g_s.graph.nbr_ids),
                                      np.asarray(g_f.graph.nbr_ids))
    for alg, h, f in zip(algs, handles, fh):
        _assert_same_fixpoint(alg, sess.result(h), fresh.result(f))


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([24, 40, 56]),
       deg=st.integers(1, 4), weighted=st.booleans())
@settings(max_examples=6, deadline=None)
def test_compact_then_run_matches_fresh_session(seed, n, deg, weighted):
    csr = _random_csr(seed, n, deg, weighted)
    rng = np.random.default_rng(seed + 1)
    algs = _job_mix(rng, n, weighted)
    batches = mutation_stream(csr, int(rng.integers(1, 4)),
                              inserts_per_batch=4, deletes_per_batch=2,
                              seed=seed + 2, weighted=weighted, w_max=4.0)
    _evolve_and_compare(csr, algs, TwoLevel(), batches, seed % 97)


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([24, 40]),
       deg=st.integers(1, 3), weighted=st.booleans())
@settings(max_examples=3, deadline=None)
def test_compact_then_run_across_policies_and_backends(seed, n, deg,
                                                       weighted):
    """The heavy grid: every policy × host/device absorbs the SAME stream
    to the same rebuilt-CSR fixpoint."""
    csr = _random_csr(seed, n, deg, weighted)
    rng = np.random.default_rng(seed + 1)
    algs = _job_mix(rng, n, weighted)
    batches = mutation_stream(csr, 2, inserts_per_batch=3,
                              deletes_per_batch=2, seed=seed + 2,
                              weighted=weighted, w_max=4.0)
    grid = [TwoLevel(), Independent(), AllBlocks(),
            TwoLevel(backend="device", steps_per_sync=2),
            Independent(backend="device", steps_per_sync=1),
            AllBlocks(backend="device", steps_per_sync=4),
            Fused()]
    for policy in grid:
        _evolve_and_compare(csr, algs, policy, batches, seed % 89)
