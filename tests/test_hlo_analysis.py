"""HLO analysis unit tests: loop trip parsing, collective wire accounting,
dot-FLOP counting (validated against a known matmul-in-scan program)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *specs, shardings=None):
    jitted = jax.jit(fn) if shardings is None else jax.jit(
        fn, in_shardings=shardings)
    return jitted.lower(*specs).compile()


def test_dot_flops_counts_scan_trip():
    L, D = 8, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    comp = _compile(f, jax.ShapeDtypeStruct((32, D), jnp.float32),
                    jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    flops = H.parse_dot_flops(comp.as_text())
    expect = 2 * 32 * D * D * L
    assert expect * 0.9 <= flops <= expect * 1.2, (flops, expect)


def test_dot_flops_no_loop():
    def f(a, b):
        return a @ b

    comp = _compile(f, jax.ShapeDtypeStruct((16, 32), jnp.float32),
                    jax.ShapeDtypeStruct((32, 8), jnp.float32))
    flops = H.parse_dot_flops(comp.as_text())
    assert flops == pytest.approx(2 * 16 * 32 * 8, rel=0.01)


def test_loop_trip_count_parser():
    cond = """
  %constant.5 = s32[] constant(40)
  %compare.1 = pred[] compare(%get-tuple-element.3, %constant.5), direction=LT
"""
    assert H._loop_trip_count(cond) == 40


def test_loop_trip_count_scientific_notation():
    # XLA prints large / f32 loop bounds in scientific notation; the
    # digits-only parse used to drop these (multiplier fell to 1)
    cond = """
  %constant.5 = f32[] constant(1e+06)
  %compare.1 = pred[] compare(%get-tuple-element.3, %constant.5), direction=LT
"""
    assert H._loop_trip_count(cond) == 1_000_000
    cond_mixed = """
  %constant.5 = f32[] constant(2.5e+03)
  %compare.1 = pred[] compare(%gte.3, %constant.5), direction=LT
"""
    assert H._loop_trip_count(cond_mixed) == 2500
    cond_neg = """
  %constant.5 = f32[] constant(-3)
  %constant.6 = s32[] constant(12)
  %compare.1 = pred[] compare(%gte.3, %constant.6), direction=LT
"""
    # negative bound is not a trip count; the integer one wins
    assert H._loop_trip_count(cond_neg) == 12


def test_parse_scalar_forms():
    assert H._parse_scalar("40") == 40
    assert H._parse_scalar("1e+06") == 1_000_000
    assert H._parse_scalar("2.14748365e+09") == 2147483650
    assert H._parse_scalar("3.5") == 3
    assert H._parse_scalar("-7") == -7
    assert H._parse_scalar("inf") is None
    assert H._parse_scalar("nan") is None
    assert H._parse_scalar("{1, 2}") is None


def test_collective_wire_formulas():
    c = H.Collective(op="all-reduce", tensor_bytes=1000, group_size=4,
                     multiplier=1, computation="x")
    assert c.wire_bytes_per_device == pytest.approx(2 * 1000 * 3 / 4)
    c = H.Collective(op="all-gather", tensor_bytes=1000, group_size=4,
                     multiplier=1, computation="x")
    assert c.wire_bytes_per_device == pytest.approx(1000 * 3 / 4)
    c = H.Collective(op="reduce-scatter", tensor_bytes=250, group_size=4,
                     multiplier=1, computation="x")
    assert c.wire_bytes_per_device == pytest.approx(250 * 3)


def test_tensor_bytes_tuple_types():
    assert H._tensor_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2
    assert H._tensor_bytes("f32[128,256]") == 128 * 256 * 4


def test_analytic_matches_hlo_dot_flops_on_smoke_arch():
    """Cross-check: analytic block FLOPs vs parsed dots for a smoke train
    step (within 35% — analytic excludes elementwise, HLO includes bwd
    rearrangement dots)."""
    import dataclasses
    from repro import configs
    from repro.models import LM
    from repro.launch.analytic import cell_flops
    from repro.models.config import ShapeConfig

    cfg = dataclasses.replace(configs.get_smoke("qwen3-32b"),
                              scan_layers=True)
    model = LM(cfg)
    shape = ShapeConfig("t", "train", 32, 4)

    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}

    def loss_fn(p, b):
        return model.loss(p, b)

    def step(p, b):
        return jax.grad(loss_fn)(p, b)

    comp = jax.jit(step).lower(params, batch).compile()
    hlo_flops = H.parse_dot_flops(comp.as_text())
    ana = cell_flops(cfg, shape)
    # compare forward+backward matmul flops (exclude optimizer constant)
    expect = ana["fwd_flops"] * 3
    assert 0.5 * expect < hlo_flops < 2.0 * expect, (hlo_flops, expect)


def test_roofline_terms_dominance():
    t = H.roofline_terms(197e12, 100e9, 1e9)   # 1s compute, .12s mem, .02s coll
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
