"""Serve-front SLO observability: loadgen, SLO tracking, registry, regress.

The PR's acceptance surface:
  * TelemetrySeries per-superstep sums reproduce RunMetrics totals
    INCLUDING the backfilled tile_pair_loads and halo_bytes counters,
    across all four policies x host/device/device-inf cadences;
  * loadgen is bit-deterministic under a fixed seed — two runs produce
    identical admission AND completion sequences;
  * SLOTracker extends ServeMetrics/LatencyStats (shared first-seen
    stamps, windowed percentiles, deadline violations, target verdicts);
  * MetricsRegistry snapshots validate against the registry schema and
    round-trip through JSON and Prometheus text exposition;
  * the regression gate passes on the committed BENCH trajectory and
    provably fails on a doctored >= 20% us_per_call regression.
"""

import copy
import json
import math
import os

import numpy as np
import pytest

from repro.algorithms import PageRank, SSSP
from repro.core import Fused, GraphSession, TwoLevel
from repro.core.policy import AllBlocks, Independent
from repro.graph import rmat_graph
from repro.obs import (Arrival, LoadgenConfig, MetricsRegistry,
                       OpenLoopHarness, REGISTRY_SCHEMA, SERIES_FIELDS,
                       SlidingWindowLatency, SLOTarget, SLOTracker,
                       generate_arrivals, validate_registry_snapshot)
from repro.obs.regress import (METRIC_SPECS, compare_docs, main as
                               regress_main, run_gate)
from repro.serve.concurrent import ConcurrentServeScheduler, RequestStream

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CSR = rmat_graph(300, 5, seed=7)


def _session(**kw):
    sess = GraphSession(CSR, 32, capacity=2, seed=3, telemetry=True, **kw)
    sess.submit(PageRank())
    sess.submit(SSSP(source=0))
    return sess


# --- backfilled counters: series sums == RunMetrics totals ------------------


@pytest.mark.parametrize("policy_cls", [TwoLevel, Independent, AllBlocks])
@pytest.mark.parametrize("cadence", ["host", "device", "device_inf"])
def test_series_sums_reproduce_run_totals(policy_cls, cadence):
    """Per-superstep sums of EVERY series column — including the
    backfilled tile_pair_loads and halo_bytes — equal the RunMetrics
    totals, on every policy x cadence."""
    kw = {"host": dict(), "device": dict(backend="device"),
          "device_inf": dict(backend="device", steps_per_sync=math.inf)
          }[cadence]
    sess = _session()
    m = sess.run(policy_cls(**kw), 500)
    assert m.converged
    tel = m.telemetry
    assert len(tel) == m.supersteps
    assert int(tel.tile_loads.sum()) == m.tile_loads
    assert int(tel.job_block_pushes.sum()) == m.job_block_pushes
    assert int(tel.tile_pair_loads.sum()) == m.tile_pair_loads
    assert m.tile_pair_loads > 0
    np.testing.assert_allclose(float(tel.halo_bytes.sum()), m.halo_bytes,
                               rtol=1e-6, atol=1e-6)


def test_series_sums_reproduce_run_totals_fused():
    sess = _session()
    m = sess.run(Fused(), 500)
    assert m.converged
    tel = m.telemetry
    assert int(tel.tile_pair_loads.sum()) == m.tile_pair_loads > 0
    assert float(tel.halo_bytes.sum()) == m.halo_bytes == 0.0


def test_new_counters_are_series_fields_and_trace_counter_tracks():
    assert "tile_pair_loads" in SERIES_FIELDS
    assert "halo_bytes" in SERIES_FIELDS
    sess = _session()
    m = sess.run(TwoLevel(), 500)
    assert m.converged
    tracks = [e for e in sess.trace.events
              if e.get("ph") == "C" and e["name"] == "telemetry"]
    assert tracks
    assert {"tile_pair_loads", "halo_bytes"} <= set(tracks[0]["args"])
    # the trace counter samples carry the same per-superstep values as
    # the series, so their sums reproduce the run totals too
    assert sum(e["args"]["tile_pair_loads"]
               for e in tracks) == m.tile_pair_loads
    # to_dict carries the new columns for exporters
    td = m.telemetry.to_dict()
    assert sum(td["tile_pair_loads"]) == m.tile_pair_loads
    assert "halo_bytes" in td


# --- loadgen ----------------------------------------------------------------


def _world(seed=11, ticks=90, max_running=3, update_every=30):
    csr = rmat_graph(192, 5, seed=9)
    sess = GraphSession(csr, 32, capacity=max(2, max_running), seed=3)
    n_groups = -(-csr.n // 32)
    slo = SLOTracker(targets=[SLOTarget(family="*", p99_latency_steps=500,
                                        deadline_steps=600)], window=128)
    sched = ConcurrentServeScheduler(n_groups, batch_budget=max_running,
                                     seed=5, slo=slo)
    cfg = LoadgenConfig(seed=seed, ticks=ticks, base_rate=0.25,
                        n_tenants=30, update_every=update_every)
    return OpenLoopHarness(sess, sched, cfg, max_running=max_running), \
        sched, slo


def test_generate_arrivals_is_deterministic_and_well_formed():
    cfg = LoadgenConfig(seed=4, ticks=200, base_rate=0.8, n_tenants=50)
    a1 = generate_arrivals(cfg, n_groups=10, n_vertices=300)
    a2 = generate_arrivals(cfg, n_groups=10, n_vertices=300)
    assert a1 == a2 and len(a1) > 50
    fams = {n for n, _ in cfg.families}
    tenant_fam = {}
    for arr in a1:
        assert isinstance(arr, Arrival)
        assert 0 <= arr.tick < cfg.ticks
        assert 0 <= arr.tenant < cfg.n_tenants
        assert 0 <= arr.group < 10 and 0 <= arr.source < 300
        assert arr.family in fams
        # a tenant is pinned to ONE family for its lifetime
        assert tenant_fam.setdefault(arr.tenant, arr.family) == arr.family
    # a different seed reshuffles the schedule
    a3 = generate_arrivals(LoadgenConfig(seed=5, ticks=200, base_rate=0.8,
                                         n_tenants=50), 10, 300)
    assert a3 != a1


def test_loadgen_config_validation():
    with pytest.raises(ValueError):
        LoadgenConfig(base_rate=0.0)
    with pytest.raises(ValueError):
        LoadgenConfig(families=(("nope", 1.0),))
    with pytest.raises(ValueError):
        LoadgenConfig(families=(("sssp", -1.0),))


def test_harness_rejects_mismatched_groups():
    csr = rmat_graph(192, 5, seed=9)
    sess = GraphSession(csr, 32, capacity=2, seed=3)
    sched = ConcurrentServeScheduler(3, batch_budget=2, seed=5)
    with pytest.raises(ValueError, match="block count"):
        OpenLoopHarness(sess, sched, LoadgenConfig(seed=1))


def test_loadgen_is_bit_deterministic_under_a_fixed_seed():
    """Two identically-seeded harness runs produce identical admission
    AND completion sequences (ticks, tenants, families, latencies)."""
    h1, _, _ = _world()
    s1 = h1.run()
    h2, _, _ = _world()
    s2 = h2.run()
    assert h1.admission_log == h2.admission_log
    assert h1.completion_log == h2.completion_log
    assert s1 == s2
    assert s1["completed"] > 0 and s1["updates_applied"] > 0


def test_harness_closes_the_loop_through_scheduler_and_session():
    h, sched, slo = _world(update_every=0)
    s = h.run()
    # every arrival was admitted and completed (the world drains)
    assert s["admitted"] == s["completed"] == s["arrivals"] > 0
    # ServeMetrics and SLOTracker observed the same completions
    assert sched.metrics.service_s.summary()["count"] == s["completed"]
    assert slo.completed == s["completed"]
    # completions carry per-family latency
    assert set(s["latency_by_family"]) <= {"pagerank", "ppr", "sssp",
                                           "bfs"}
    # the session ends empty: every handle detached
    assert sum(g.num_active for g in h.sess.view_groups()) == 0


def test_harness_respects_max_running():
    """Reconstruct concurrency from the logs: admissions at tick t join
    before completions stamped t+1 leave, so the per-tick peak is
    cumulative admissions minus cumulative completions."""
    h, _, _ = _world(max_running=2)
    h.run()
    admits = sorted(t for t, *_ in h.admission_log)
    leaves = sorted(t for t, *_ in h.completion_log)
    peak, ai, li = 0, 0, 0
    for t in range(h.ticks_run + 1):
        while ai < len(admits) and admits[ai] <= t:
            ai += 1
        peak = max(peak, ai - li)
        while li < len(leaves) and leaves[li] <= t + 1:
            li += 1
    assert 0 < peak <= 2


# --- SLO tracking -----------------------------------------------------------


def test_sliding_window_latency_retention():
    w = SlidingWindowLatency(window=4)
    for i in range(10):
        w.add(float(i))
    assert w.samples == [6.0, 7.0, 8.0, 9.0]
    assert w.summary()["count"] == 4
    with pytest.raises(ValueError):
        SlidingWindowLatency(window=0)


def test_slo_tracker_windows_violations_and_verdicts():
    t = SLOTracker(targets=[
        SLOTarget(family="fast", p50_latency_steps=5, p99_latency_steps=8,
                  deadline_steps=10),
        SLOTarget(family="*", deadline_steps=100)], window=64)

    class R:
        def __init__(self, sid):
            self.stream_id = sid
            self._seen_step = None

    # fast family: one in-deadline, one blown deadline
    r1, r2, r3 = R(0), R(0), R(1)
    t.on_seen(r1, 0)
    t.on_seen(r2, 0)
    t.on_seen(r3, 2)
    t.on_admit(r1, "fast", 1)
    t.on_complete(r1, "fast", 4)       # latency 4: within everything
    t.on_complete(r2, "fast", 20)      # latency 20 > deadline 10
    t.on_complete(r3, "slow", 30)      # latency 28 < catch-all 100
    t.on_step(30, {"fast": 2, "slow": 0})
    rep = t.report()
    assert rep["completed"] == 3
    assert rep["deadline_violations_total"] == 1
    fast = rep["families"]["fast"]
    assert fast["deadline_violations"] == 1
    assert fast["latency_steps"]["count"] == 2
    assert fast["slo"]["ok"] is False          # p99 blown by the 20
    slow = rep["families"]["slow"]
    assert slow["slo"]["ok"] is True           # catch-all target matched
    assert rep["tenants"]["0"]["count"] == 2
    # duplicate family targets are rejected
    with pytest.raises(ValueError):
        SLOTracker(targets=[SLOTarget(family="x"), SLOTarget(family="x")])


def test_slo_tracker_shares_seen_stamps_with_serve_metrics():
    """Wired through the scheduler, tracker and metrics agree on the wait
    clock because they share the req._seen_step stamp."""
    from repro.serve.concurrent import Request
    slo = SLOTracker(window=16)
    sched = ConcurrentServeScheduler(4, 2, seed=0, slo=slo)
    st = RequestStream(0, family="chat")
    sched.add_stream(st)
    for g in range(4):
        st.add(Request(0, g, 1.0, 1))
    done = []
    while st.waiting:
        done += sched.schedule_step()
    for r in done:
        sched.complete(r)
    assert slo.completed == 4
    # same stamps -> identical wait-step samples in both views
    assert sorted(sched.metrics.wait_steps.samples) == \
        sorted(slo.wait_by_family["chat"].samples)
    # family latency recorded under the stream's declared family
    assert list(slo.report()["families"]) == ["chat"]


# --- MetricsRegistry --------------------------------------------------------


def test_registry_snapshot_validates_and_round_trips(tmp_path):
    reg = MetricsRegistry()
    reg.register("plain", {"a": 1, "b": {"c": [1, 2, 3]}})
    reg.register("live", lambda: {"x": 2.5})
    doc = reg.snapshot()
    assert doc["schema"] == REGISTRY_SCHEMA
    assert validate_registry_snapshot(doc) == 2
    out = tmp_path / "snap.json"
    exported = reg.export(out)
    assert exported == json.loads(out.read_text())
    assert validate_registry_snapshot(json.loads(out.read_text())) == 2


def test_registry_accepts_the_real_sources():
    h, sched, slo = _world(ticks=40, update_every=0)
    s = h.run()
    reg = MetricsRegistry()
    reg.register("serve", sched.metrics)   # summary()
    reg.register("slo", slo)               # report()
    reg.register("loadgen", s)             # plain dict
    doc = reg.snapshot()
    assert validate_registry_snapshot(doc) == 3
    assert doc["sources"]["slo"]["completed"] == s["completed"]


def test_registry_rejects_bad_names_sources_and_snapshots():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.register("bad name!", {})
    reg.register("a", {"x": 1})
    with pytest.raises(ValueError):
        reg.register("a", {})              # duplicate
    with pytest.raises(TypeError):
        reg.register("b", 42)
        reg.snapshot()
    reg.unregister("b")
    # schema violations
    with pytest.raises(ValueError, match="schema"):
        validate_registry_snapshot({"schema": "nope", "sources": {}})
    with pytest.raises(ValueError, match="non-JSON"):
        validate_registry_snapshot(
            {"schema": REGISTRY_SCHEMA,
             "sources": {"s": {"x": object()}}})
    with pytest.raises(ValueError, match="non-finite"):
        validate_registry_snapshot(
            {"schema": REGISTRY_SCHEMA,
             "sources": {"s": {"x": float("nan")}}})


def test_registry_prometheus_exposition():
    reg = MetricsRegistry()
    reg.register("serve", {"wait": {"p50": 1.5, "p99": 9.0},
                           "ok": True, "note": "ignored",
                           "series": [1, 2, 3]})
    text = reg.to_prometheus()
    lines = text.strip().splitlines()
    assert "repro_serve_wait_p50 1.5" in lines
    assert "repro_serve_wait_p99 9" in lines
    assert "repro_serve_ok 1" in lines
    assert "repro_serve_series_sum 6" in lines
    assert "repro_serve_series_last 3" in lines
    assert not any("note" in ln for ln in lines)
    # every sample line is preceded by its TYPE header
    for i, ln in enumerate(lines):
        if not ln.startswith("#"):
            assert lines[i - 1] == f"# TYPE {ln.split()[0]} gauge"


# --- the regression gate ----------------------------------------------------


def _fig_sync_doc():
    with open(os.path.join(REPO_ROOT, "BENCH_fig_sync.json")) as f:
        return json.load(f)


def test_gate_passes_on_the_committed_trajectory():
    result = run_gate(REPO_ROOT, REPO_ROOT)
    assert result["ok"] and not result["violations"]
    assert "fig_sync" in result["compared_modes"]


def test_gate_fails_on_doctored_us_per_call_regression():
    """The acceptance criterion: an injected >= 20% us_per_call
    regression must trip the gate."""
    base = _fig_sync_doc()
    doctored = copy.deepcopy(base)
    doctored["records"][0]["us_per_call"] = round(
        base["records"][0]["us_per_call"] * 1.20, 1)
    violations, _ = compare_docs(base, doctored)
    assert [v.metric for v in violations] == ["us_per_call"]
    assert violations[0].kind == "timing"
    # counters-only mode ignores the timing wobble...
    ok, _ = compare_docs(base, doctored, skip_timing=True)
    assert not ok
    # ...but still catches a counter regression exactly
    doctored["records"][1]["tile_loads"] += 1
    bad, _ = compare_docs(base, doctored, skip_timing=True)
    assert [v.metric for v in bad] == ["tile_loads"]


def test_gate_direction_lower_is_worse_for_throughput():
    base = {"mode": "fig_serve", "records": [
        {"name": "p4", "completed": 100, "throughput_per_tick": 0.5}]}
    worse = {"mode": "fig_serve", "records": [
        {"name": "p4", "completed": 90, "throughput_per_tick": 0.4}]}
    better = {"mode": "fig_serve", "records": [
        {"name": "p4", "completed": 120, "throughput_per_tick": 0.9}]}
    v, _ = compare_docs(base, worse)
    assert {x.metric for x in v} == {"completed", "throughput_per_tick"}
    v, _ = compare_docs(base, better)
    assert not v


def test_gate_missing_rows_warn_unless_required():
    base = _fig_sync_doc()
    partial = {"mode": "fig_sync", "records": base["records"][:1]}
    v, w = compare_docs(base, partial)
    assert not v and any("missing" in x for x in w)
    v, w = compare_docs(base, partial, require_all=True)
    assert v and v[0].kind == "missing"


def test_gate_cli_exit_codes(tmp_path):
    # 0: self-gate on the committed records
    assert regress_main(["--baseline", REPO_ROOT, "--modes",
                         "fig_sync,fig_trace"]) == 0
    # 1: doctored regression (>= 20% us_per_call) in a fresh dir
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    doc = _fig_sync_doc()
    doc["records"][0]["us_per_call"] = round(
        doc["records"][0]["us_per_call"] * 1.3, 1)
    (fresh / "BENCH_fig_sync.json").write_text(json.dumps(doc))
    out = tmp_path / "verdict.json"
    assert regress_main(["--baseline", REPO_ROOT, "--fresh", str(fresh),
                         "--modes", "fig_sync", "--json", str(out)]) == 1
    verdict = json.loads(out.read_text())
    assert not verdict["ok"]
    assert verdict["violations"][0]["metric"] == "us_per_call"
    # the same fresh dir is clean under --skip-timing
    assert regress_main(["--baseline", REPO_ROOT, "--fresh", str(fresh),
                         "--modes", "fig_sync", "--skip-timing"]) == 0
    # 2: no records at all
    empty = tmp_path / "empty"
    empty.mkdir()
    assert regress_main(["--baseline", str(empty)]) == 2


def test_gate_specs_cover_the_issue_metrics():
    for metric in ("us_per_call", "tile_loads", "tile_pair_loads",
                   "halo_bytes", "host_syncs"):
        assert metric in METRIC_SPECS
