"""Compiled-artifact contract checker self-tests.

Lowers the REAL device superstep once (module-scoped — compile cost is
paid once for the file) and asserts every contract holds on the current
tree; then deliberately BREAKS the 1-sync invariant two ways (host
callback injected into the HLO; while-loop stripped) and asserts the
checker flags each, so a future regression can't pass by the checker
going blind."""

import math
import types

import pytest

from repro.analysis import contracts as C

jax = pytest.importorskip("jax")


@pytest.fixture(scope="module")
def inf_setup():
    from repro.core import TwoLevel
    sess = C._canonical_session()
    policy = TwoLevel(backend="device", steps_per_sync=math.inf)
    _, hlo = C.lower_device_superstep(sess, policy)
    return sess, policy, hlo


def test_device_inf_contracts_all_hold(inf_setup):
    sess, policy, _ = inf_setup
    results = C.check_device_contracts(sess, policy)
    failures = [r for r in results if not r.ok]
    assert not failures, "\n".join(f"{r.name}: {r.detail}"
                                   for r in failures)
    names = {r.name for r in results}
    # the acceptance-criterion pair: 1 host sync + VMEM-budgeted kernels
    assert {"one-sync", "one-sync-runtime", "vmem-budget"} <= names


def test_run_host_syncs_is_exactly_one(inf_setup):
    sess, policy, _ = inf_setup
    m = sess.run(policy, 2000)
    assert m.converged and m.host_syncs == 1


def test_broken_one_sync_host_callback_flagged(inf_setup):
    _, _, hlo = inf_setup
    assert C.check_one_sync(hlo).ok
    broken = hlo + ("\n  %cb = f32[] custom-call(), "
                    "custom_call_target=\"xla_python_cpu_callback\"\n")
    res = C.check_one_sync(broken)
    assert not res.ok and "host-callback" in res.detail


def test_broken_one_sync_outfeed_flagged(inf_setup):
    _, _, hlo = inf_setup
    broken = hlo + "\n  %of = token[] outfeed(%x, %tok)\n"
    assert not C.check_one_sync(broken).ok


def test_broken_one_sync_missing_while_flagged(inf_setup):
    _, _, hlo = inf_setup
    no_loop = hlo.replace(" while(", " call(").replace("=while(",
                                                       "=call(")
    res = C.check_one_sync(no_loop)
    assert not res.ok and "while" in res.detail


def test_no_f64_detects_injected_promotion(inf_setup):
    _, _, hlo = inf_setup
    assert C.check_no_f64(hlo).ok
    assert not C.check_no_f64(hlo + "\n  %p = f64[4]{0} convert(%x)\n").ok


def test_vmem_budget_formula_flags_oversized_tile():
    from repro.kernels.mj_spmm.ops import _VMEM_BUDGET
    # Vb=32 (the canonical block size) is comfortably inside budget
    assert C.mj_spmm_vmem_bytes(2, 32) <= _VMEM_BUDGET
    # a block size whose bare tile pair exceeds the budget must fail:
    # the kernel cannot stage a single grid cell, job-chunking or not
    big_vb = 2048   # 2 * Vb^2 * 4 = 32 MiB > 12 MiB budget
    assert C.mj_spmm_vmem_bytes(2, big_vb) > _VMEM_BUDGET
    fake = types.SimpleNamespace(view_groups=lambda: [
        types.SimpleNamespace(
            key="fake", capacity=2,
            graph=types.SimpleNamespace(block_size=big_vb))])
    results = C.check_vmem_budget(fake)
    assert any(not r.ok for r in results)


def test_tile_bytes_cross_check_flags_unaccountable_traffic(inf_setup):
    _, _, hlo = inf_setup
    good = types.SimpleNamespace(tile_loads=10, host_syncs=1)
    assert C.check_tile_bytes(hlo, good, vb=32).ok
    # a schedule claiming to stage more tiles than the program's HBM
    # traffic can account for is lying about one of the two
    absurd = types.SimpleNamespace(tile_loads=10**12, host_syncs=1)
    assert not C.check_tile_bytes(hlo, absurd, vb=32).ok


def test_host_programs_pure_and_f32():
    results = C.check_host_programs()
    failures = [r for r in results if not r.ok]
    assert not failures, "\n".join(f"{r.name}: {r.detail}"
                                   for r in failures)


def test_finite_cadence_contracts_hold():
    from repro.core import TwoLevel
    sess = C._canonical_session()
    results = C.check_device_contracts(
        sess, TwoLevel(backend="device", steps_per_sync=4))
    failures = [r for r in results if not r.ok]
    assert not failures, "\n".join(f"{r.name}: {r.detail}"
                                   for r in failures)
    # finite cadence syncs once per chunk, not once per run
    assert "one-sync-runtime" not in {r.name for r in results}
