"""Device-resident scheduling: the jnp analogues must match the host core.

Covers the tentpole acceptance surface:
  * do_select_device is DISTRIBUTIONALLY equivalent to the host sampler
    (per-block selection frequencies over >=1k draws);
  * global_queue_device agrees with the host synthesis on the
    reserved-head-slot edge cases (the Fig. 7 invariants);
  * TwoLevelScheduler/serve keep one core across backend="host"|"device";
  * the compiled superstep is CACHED on the session (no re-trace across
    run() calls, resubmissions, recycled slots);
  * steps_per_sync amortizes host round-trips without changing the
    schedule (same supersteps/tile_loads, >=4x fewer syncs at K=8).
"""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.algorithms import PageRank, PersonalizedPageRank, SSSP
from repro.core import (Fused, GraphSession, TwoLevel, TwoLevelScheduler,
                        do_select, do_select_device, global_queue,
                        global_queue_device)
from repro.graph import rmat_graph
from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                    RequestStream)

CSR = rmat_graph(300, 5, seed=7)


# --- Function 2: device sampler vs host sampler -----------------------------


def _frequencies(node_un, p_mean, q, s, draws):
    freq_h = np.zeros(len(node_un))
    for i in range(draws):
        out = do_select(node_un, p_mean, q, np.random.default_rng(1000 + i),
                        s)
        freq_h[out] += 1
    sel, msk = jax.vmap(lambda k: do_select_device(
        jnp.asarray(node_un, jnp.float32), jnp.asarray(p_mean, jnp.float32),
        q, k, s))(jax.random.split(jax.random.PRNGKey(0), draws))
    sel, msk = np.asarray(sel), np.asarray(msk)
    freq_d = np.zeros(len(node_un))
    for i in range(draws):
        freq_d[sel[i][msk[i] > 0]] += 1
    return freq_h / draws, freq_d / draws


def test_device_sampler_matches_host_selection_frequencies():
    """>=1k draws each: the per-block marginal selection frequency of the
    device sampler must track the host sampler's.  Means are placed in
    distinct log-buckets so the exact CBP comparator and its scalar
    surrogate rank identically — what remains is pure sampling-threshold
    randomness, the thing being compared."""
    rng = np.random.default_rng(3)
    b_n, q, s, draws = 64, 6, 16, 1200
    node_un = rng.integers(0, 30, b_n).astype(np.float64)
    p_mean = np.where(node_un > 0, 2.0 ** rng.integers(-3, 9, b_n),
                      0.0).astype(np.float64)
    freq_h, freq_d = _frequencies(node_un, p_mean, q, s, draws)
    # marginals agree per block and in total queue mass
    assert np.abs(freq_h - freq_d).max() < 0.08
    assert abs(freq_h.sum() - freq_d.sum()) < 0.05 * max(freq_h.sum(), 1)
    # the certainly-hot blocks are certain under both samplers
    np.testing.assert_array_equal(freq_h > 0.99, freq_d > 0.99)


def test_device_sampler_degenerate_cases_match_host_exactly():
    key = jax.random.PRNGKey(0)
    # all converged -> empty queue
    sel, msk = do_select_device(jnp.zeros(10), jnp.zeros(10), 3, key)
    assert msk.sum() == 0
    # fewer live blocks than q -> the whole live set, no sampling
    node_un = np.zeros(20)
    p_mean = np.zeros(20)
    node_un[[3, 11, 17]] = [5.0, 2.0, 9.0]
    p_mean[[3, 11, 17]] = [1.0, 8.0, 64.0]
    sel, msk = do_select_device(jnp.asarray(node_un, jnp.float32),
                                jnp.asarray(p_mean, jnp.float32), 8, key)
    got = set(np.asarray(sel)[np.asarray(msk) > 0].tolist())
    want = set(do_select(node_un, p_mean, 8,
                         np.random.default_rng(0)).tolist())
    assert got == want == {3, 11, 17}
    # the hot block heads the queue
    assert int(sel[0]) == 17


# --- Fig. 7: device synthesis vs host synthesis -----------------------------


def _dev_gq(job_queues, num_blocks, q, alpha=0.8):
    j = max(1, len(job_queues))
    sel = np.zeros((j, q), np.int32)
    msk = np.zeros((j, q), np.float32)
    for i, jq in enumerate(job_queues):
        L = min(len(jq), q)
        sel[i, :L] = jq[:L]
        msk[i, :L] = 1.0
    gsel, gmsk = global_queue_device(jnp.asarray(sel), jnp.asarray(msk),
                                     num_blocks, q, alpha)
    gsel, gmsk = np.asarray(gsel), np.asarray(gmsk)
    return gsel[gmsk > 0]


def test_device_synthesis_reserves_individual_heads():
    """The edge case the reserved (1-alpha)q slots exist for: a singleton
    queue's head must enter the global queue although its cumulative
    weight loses to every shared block — and the selected SET must match
    the host synthesis exactly."""
    jq = [np.arange(1, 9), np.arange(1, 9), np.array([9])]
    host = global_queue(jq, num_blocks=12, q=8, alpha=0.8)
    dev = _dev_gq(jq, num_blocks=12, q=8, alpha=0.8)
    assert 9 in dev.tolist()
    assert set(dev.tolist()) == set(host.tolist())
    assert len(set(dev.tolist())) == len(dev)      # no duplicates


def test_device_synthesis_many_heads_never_crowd_out_weighted_slots():
    """16 jobs with 16 distinct queue heads compete for 2 reserved slots
    (q=10, alpha=0.8): the ceil(alpha*q)=8 cumulative-weight winners must
    ALL survive — the reserved mechanism may only claim its (1-alpha)q
    quota — and the device set must equal the host set exactly.  (A naive
    'boost every head' rendering fails this: 10 heads would fill the
    whole queue.)"""
    jq = [np.array([40 + j, 0, 1, 2, 3, 4, 5, 6, 7]) for j in range(16)]
    host = global_queue(jq, num_blocks=64, q=10, alpha=0.8)
    dev = _dev_gq(jq, num_blocks=64, q=10, alpha=0.8)
    assert set(dev.tolist()) == set(host.tolist())
    # the 8 weight-ranked blocks all present, exactly 2 reserved heads
    assert set(range(8)) <= set(dev.tolist())
    assert len([b for b in dev.tolist() if b >= 40]) == 2
    assert len(dev) == 10


def test_device_synthesis_duplicate_heads_counted_once_and_first():
    jq = [np.array([7, 1]), np.array([7, 2]), np.array([7, 3])]
    host = global_queue(jq, num_blocks=10, q=4)
    dev = _dev_gq(jq, num_blocks=10, q=4)
    assert dev[0] == host[0] == 7
    assert list(dev).count(7) == 1
    assert set(dev.tolist()) == set(host.tolist())


def test_device_synthesis_alpha_one_has_no_reserved_slots():
    jq = [np.array([1, 2, 3, 4]), np.array([1, 2, 3, 4]), np.array([9])]
    host = global_queue(jq, num_blocks=12, q=4, alpha=1.0)
    dev = _dev_gq(jq, num_blocks=12, q=4, alpha=1.0)
    assert dev[0] == host[0] == 1
    assert set(dev.tolist()) == set(host.tolist())


def test_device_synthesis_alpha_zero_keeps_one_weighted_slot():
    """Host floor: n_global = max(1, ceil(alpha*q)), so even alpha=0 must
    keep the top cumulative-weight block; heads take only the rest."""
    jq = [np.array([10 + j, 1, 2, 3]) for j in range(5)]
    host = global_queue(jq, num_blocks=16, q=2, alpha=0.0)
    dev = _dev_gq(jq, num_blocks=16, q=2, alpha=0.0)
    assert set(dev.tolist()) == set(host.tolist())
    assert 1 in dev.tolist()      # the weighted winner survives


def test_device_run_advances_the_sampling_stream_across_runs():
    """Host semantics: the scheduler RNG advances across run()/step()
    calls (only the legacy shim resets per call).  The device backend
    must advance its fold_in stream position the same way, or an
    arrival-model loop of step() calls would replay one sample forever."""
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    sess.submit(PageRank())
    pos0 = sess.scheduler._step
    m1 = sess.run(TwoLevel(backend="device"), max_supersteps=5)
    assert sess.scheduler._step == pos0 + m1.supersteps
    m2 = sess.run(TwoLevel(backend="device"), 20000)
    assert m2.converged
    assert sess.scheduler._step == pos0 + m1.supersteps + m2.supersteps


def test_device_synthesis_short_and_empty_queues():
    jq = [np.array([3]), np.array([5])]
    assert set(_dev_gq(jq, 8, 4, alpha=1.0).tolist()) == {3, 5}
    assert len(_dev_gq([np.empty(0, np.int64)], 5, 3)) == 0


# --- one scheduler core, pluggable backend ----------------------------------


def test_scheduler_backend_device_keeps_the_list_interface():
    """Same object, same select() contract: when the candidate set fits
    the queue (no sampling randomness) both backends pick the same set."""
    node_un = np.zeros((2, 16))
    p_mean = np.zeros((2, 16))
    node_un[0, [1, 4]] = [3.0, 9.0]
    p_mean[0, [1, 4]] = [2.0, 16.0]
    node_un[1, [4, 9]] = [7.0, 2.0]
    p_mean[1, [4, 9]] = [16.0, 0.5]
    out = {}
    for backend in ("host", "device"):
        sched = TwoLevelScheduler(16, 4, seed=0, backend=backend)
        queues, gq = sched.select(node_un, p_mean)
        assert len(queues) == 2
        assert all(len(set(jq.tolist())) == len(jq) for jq in queues)
        out[backend] = set(gq.tolist())
    assert out["host"] == out["device"] == {1, 4, 9}


def test_scheduler_backend_validation_and_reset():
    with pytest.raises(ValueError):
        TwoLevelScheduler(8, 2, backend="gpu")
    sched = TwoLevelScheduler(8, 2, seed=3, backend="device")
    sched._next_key()
    assert sched._step == 1
    sched.reset()
    assert sched._step == 0


def test_serve_scheduler_runs_on_the_device_backend():
    """The serve layer inherits the device core with zero serve-side code:
    the shared hot group still serves both streams within budget."""
    sched = ConcurrentServeScheduler(n_groups=8, batch_budget=2, seed=0,
                                     backend="device")
    s1, s2 = RequestStream(1), RequestStream(2)
    sched.add_stream(s1)
    sched.add_stream(s2)
    s1.add(Request(1, 5, urgency=9.0, tokens_left=5))
    s2.add(Request(2, 5, urgency=9.0, tokens_left=5))
    admitted = sched.schedule_step()
    assert len(admitted) == 2
    assert {r.stream_id for r in admitted} == {1, 2}
    assert all(r.group == 5 for r in admitted)


# --- policy knobs ------------------------------------------------------------


def test_policy_backend_and_steps_per_sync_validation():
    with pytest.raises(ValueError):
        TwoLevel(backend="gpu")
    with pytest.raises(ValueError):
        TwoLevel(steps_per_sync=4)            # host syncs every superstep
    with pytest.raises(ValueError):
        TwoLevel(backend="device", steps_per_sync=0)
    with pytest.raises(ValueError):
        TwoLevel(backend="device", steps_per_sync=2.5)
    assert Fused().steps_per_sync == math.inf
    assert Fused(steps_per_sync=4).steps_per_sync == 4
    assert Fused().backend == "device"


def test_superstep_compiles_once_across_runs_and_resubmissions(
        transfer_sentinel, retrace_pin):
    """Satellite: the old Fused.run re-traced its while_loop every call.
    The compiled step must be cached on the session and survive run(),
    resubmission into a recycled slot, and detach — one cache entry, and
    jax must not re-trace (pinned via jax's own lowering counter).  The
    whole scenario runs under the transfer sentinel (every sync must be
    an explicit device_get) and runs 2-3 under the retrace sentinel."""
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    h0 = sess.submit(PageRank())
    assert sess.run(Fused(), 20000).converged
    sess.submit(PersonalizedPageRank(source=7))     # same capacity
    with retrace_pin(sess):
        assert sess.run(Fused(), 20000).converged
        sess.detach(h0)
        sess.submit(PageRank(damping=0.6))          # recycled slot
        assert sess.run(Fused(), 20000).converged
    entries = [k for k in sess._jit_cache if k[0] == "superstep"]
    assert len(entries) == 1
    # three runs, one compilation: the jit object's trace cache holds a
    # single entry (shapes/dtypes never changed across runs)
    assert sess._jit_cache[entries[0]]._cache_size() == 1


def test_steps_per_sync_amortizes_host_round_trips():
    """Acceptance: K=8 cuts scheduling round-trips >=4x vs K=1 while the
    schedule itself is unchanged (same key stream fold_in(seed, step), so
    identical supersteps AND tile_loads)."""
    algs = [PageRank(), PersonalizedPageRank(source=7)]
    ms = {}
    for k in (1, 8):
        sess = GraphSession(CSR, 32, capacity=2, seed=5)
        for a in algs:
            sess.submit(a)
        ms[k] = sess.run(TwoLevel(backend="device", steps_per_sync=k),
                         20000)
    assert ms[1].converged and ms[8].converged
    assert ms[1].supersteps == ms[8].supersteps
    assert ms[1].tile_loads == ms[8].tile_loads
    assert ms[1].job_block_pushes == ms[8].job_block_pushes
    assert ms[1].host_syncs >= 4 * ms[8].host_syncs


def test_host_backend_reports_one_sync_per_superstep():
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    sess.submit(PageRank())
    m = sess.run(TwoLevel(), 20000)
    assert m.converged
    # one scheduling sync per superstep + the final all-converged poll
    assert m.host_syncs == m.supersteps + 1


def test_device_backend_never_pushes_a_converged_group():
    """The host driver's invariant — a fully-converged view group is never
    pushed, so sub-tolerance plus-times residual mass stays where
    convergence left it — must hold inside the jitted superstep too.
    PageRank(0.5) on a 30x30 grid converges long before SSSP crosses the
    diameter; once it does, further device supersteps must leave its
    group state BIT-identical (without the freeze, residual deltas keep
    scattering and the result drifts toward the tolerance)."""
    from repro.graph import grid_graph
    sess = GraphSession(grid_graph(30), 32, capacity=1, seed=3)
    h_pr = sess.submit(PageRank(damping=0.5))
    h_ss = sess.submit(SSSP(source=0))
    pol = TwoLevel(backend="device")
    for _ in range(500):
        if sess.converged(h_pr):
            break
        sess.run(pol, max_supersteps=1)
    assert sess.converged(h_pr) and not sess.converged(h_ss)
    pt = [g for g in sess.view_groups() if g.semiring == "plus_times"][0]
    snap_v, snap_d = np.asarray(pt.values), np.asarray(pt.deltas)
    sess.run(pol, max_supersteps=10)          # min-plus family still hot
    assert not sess.converged(h_ss)
    np.testing.assert_array_equal(np.asarray(pt.values), snap_v)
    np.testing.assert_array_equal(np.asarray(pt.deltas), snap_d)


def test_fused_and_explicit_device_twolevel_share_one_compilation():
    """Fused() IS TwoLevel(backend='device', steps_per_sync=inf): running
    both on one session must not compile the superstep twice (the cache
    keys on the selection program, not the policy's name)."""
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    sess.submit(PageRank())
    assert sess.run(Fused(), 20000).converged
    assert sess.run(TwoLevel(backend="device", steps_per_sync=math.inf),
                    20000).converged
    assert len([k for k in sess._jit_cache if k[0] == "superstep"]) == 1


def test_device_two_level_matches_host_fixpoint_fast():
    """Cheap fixed-seed cross-backend check in the fast suite (the full
    policy x backend x cadence grid lives in the slow property suite)."""
    ref_sess = GraphSession(CSR, 32, capacity=2, seed=5)
    r0 = ref_sess.submit(PageRank())
    r1 = ref_sess.submit(SSSP(source=0))
    assert ref_sess.run(TwoLevel(), 20000).converged
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    h0 = sess.submit(PageRank())
    h1 = sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(backend="device", steps_per_sync=4),
                    20000).converged
    np.testing.assert_array_equal(sess.result(h1), ref_sess.result(r1))
    np.testing.assert_allclose(sess.result(h0), ref_sess.result(r0),
                               rtol=1e-3, atol=1e-5)
