"""Evolving graphs (repro.stream): live edge updates under running jobs.

Covers the new_subsystem acceptance criteria:
  * UpdateBatch / apply_to_csr semantics (upsert, ordered ops, in-batch
    min-weight dedupe) and the CSRGraph hardening satellites;
  * apply_updates while jobs run: min-plus fixpoints stay BITWISE equal
    to a fresh session on the rebuilt CSR (insert fast path, delete
    support-test reseed, WCC conservative reseed), plus-times within
    tolerance — across host and device backends and a heterogeneous mix;
  * the delta-COO overlay absorbs structurally-new block pairs, a full
    overlay row compacts, and compacted tiles are bitwise identical to a
    from-scratch build;
  * dirty-block priority injection reaches both drivers and the serve
    scheduler's notify_group_update analogue;
  * RunMetrics stream counters.
"""

import numpy as np
import pytest

from repro.algorithms import BFS, Katz, PageRank, PersonalizedPageRank, SSSP, WCC
from repro.algorithms.base import MIN_PLUS
from repro.core import Fused, GraphSession, TwoLevel
from repro.graph import chain_graph, mutation_stream, uniform_graph
from repro.graph.structure import CSRGraph
from repro.stream import UpdateBatch, apply_to_csr

CSR = uniform_graph(300, 5, seed=8)                       # unweighted
CSR_W = uniform_graph(200, 5, seed=9, weighted=True, w_max=9.0)


def _fresh_fixpoint(csr, algs, seed=0, block=32):
    sess = GraphSession(csr, block, capacity=2, seed=seed)
    handles = [sess.submit(a) for a in algs]
    assert sess.run(TwoLevel(), 50000).converged
    return sess, [sess.result(h) for h in handles]


def _check(algs, got, want):
    for a, g, w in zip(algs, got, want):
        if a.semiring == MIN_PLUS:
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-5)


# -- CSR hardening satellites ------------------------------------------------


def test_from_edges_empty_and_list_inputs():
    g = CSRGraph.from_edges(5, [], [])
    assert g.nnz == 0 and g.indptr.tolist() == [0] * 6
    assert g.symmetrized().nnz == 0
    assert g.out_degree.tolist() == [0] * 5
    g2 = CSRGraph.from_edges(5, [0, 1], [1, 2])            # plain lists
    assert g2.nnz == 2 and g2.weights.dtype == np.float32
    with pytest.raises(ValueError):
        CSRGraph.from_edges(5, [0], [5])                   # out of range
    with pytest.raises(ValueError):
        CSRGraph.from_edges(5, [0, 1], [1])                # ragged


def test_from_edges_duplicate_min_weight_is_idempotent():
    """Repeated mutation batches re-insert edges; min-dedupe must never
    raise a stored weight and must be stable under re-application."""
    g = CSRGraph.from_edges(4, [0, 0, 0, 2], [1, 1, 1, 3],
                            [3.0, 1.0, 2.0, 5.0])
    assert g.nnz == 2 and g.edge_weight(0, 1) == 1.0
    again = CSRGraph.from_edges(
        4, np.concatenate([np.repeat(np.arange(4), np.diff(g.indptr)),
                           [0]]),
        np.concatenate([g.indices, [1]]),
        np.concatenate([g.weights, [9.0]]))
    assert again.edge_weight(0, 1) == 1.0                  # min survives


def test_symmetrized_antiparallel_min():
    g = CSRGraph.from_edges(3, [0, 1], [1, 0], [2.0, 7.0])
    s = g.symmetrized()
    assert s.edge_weight(0, 1) == 2.0 and s.edge_weight(1, 0) == 2.0


# -- UpdateBatch / apply_to_csr ---------------------------------------------


def test_apply_to_csr_ordered_upsert_delete():
    g = CSRGraph.from_edges(4, [0, 1], [1, 2], [2.0, 3.0])
    b = UpdateBatch.concat([
        UpdateBatch.inserts([0, 2], [3, 0], [1.5, 4.0]),   # new edges
        UpdateBatch.inserts([0], [1], [9.0]),              # reweight UP
        UpdateBatch.deletes([1], [2]),                     # remove
        UpdateBatch.deletes([3], [0]),                     # absent: no-op
    ])
    g2 = apply_to_csr(g, b)
    assert g2.edge_weight(0, 1) == 9.0                     # upsert replaces
    assert g2.edge_weight(0, 3) == 1.5
    assert g2.edge_weight(2, 0) == 4.0
    assert g2.edge_weight(1, 2) is None
    assert g.edge_weight(1, 2) == 3.0                      # original intact
    # delete-then-insert re-creates; in-batch duplicate inserts keep min
    b2 = UpdateBatch.concat([
        UpdateBatch.deletes([0], [1]),
        UpdateBatch.inserts([0, 0], [1, 1], [5.0, 4.0]),
    ])
    assert apply_to_csr(g2, b2).edge_weight(0, 1) == 4.0
    with pytest.raises(ValueError):
        apply_to_csr(g, UpdateBatch.inserts([0], [99]))


# -- incremental recomputation matches fresh sessions ------------------------


@pytest.mark.parametrize(
    "policy",
    [TwoLevel(), TwoLevel(backend="device", steps_per_sync=4), Fused()],
    ids=["host", "device_k4", "fused"])
def test_updates_while_running_match_rebuilt_fixpoints(policy):
    """Insert + delete batches at arbitrary supersteps: every job ends at
    the fixpoint of the FINAL graph (min-plus bitwise)."""
    algs = [PageRank(), SSSP(source=0)]
    sess = GraphSession(CSR, 32, capacity=2, seed=0)
    handles = [sess.submit(a) for a in algs]
    sess.run(policy, max_supersteps=7)          # mid-convergence
    batches = mutation_stream(CSR, 2, inserts_per_batch=6,
                              deletes_per_batch=3, seed=3)
    csr_k = CSR
    for b in batches:
        sess.apply_updates(b)
        sess.run(policy, max_supersteps=5)      # updates land mid-run too
        csr_k = apply_to_csr(csr_k, b)
    assert sess.run(policy, 50000).converged
    _, ref = _fresh_fixpoint(csr_k, algs)
    _check(algs, [sess.result(h) for h in handles], ref)


def test_min_plus_insert_fast_path_is_exact_and_cheap():
    """A weight-lowering insert re-activates only the source — no reseed —
    and still lands on the rebuilt CSR's exact distances."""
    sess = GraphSession(CSR_W, 32, capacity=1, seed=1)
    h = sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(), 50000).converged
    far = int(np.argmax(np.where(np.isfinite(sess.result(h)),
                                 sess.result(h), -1)))
    b = UpdateBatch.inserts([0], [far], [0.5])  # shortcut from the source
    stats = sess.apply_updates(b)
    assert stats.reseed_fraction == 0.0         # monotone: nothing reseeded
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(CSR_W, b), [SSSP(source=0)],
                             seed=1)
    np.testing.assert_array_equal(sess.result(h), ref[0])
    assert sess.result(h)[far] == 0.5


def test_min_plus_delete_reseeds_support_set_exactly():
    sess = GraphSession(CSR_W, 32, capacity=2, seed=2)
    h0 = sess.submit(SSSP(source=0))
    h1 = sess.submit(SSSP(source=17))
    assert sess.run(TwoLevel(), 50000).converged
    # delete several existing edges (possibly on shortest paths)
    rng = np.random.default_rng(0)
    src_all = np.repeat(np.arange(CSR_W.n), np.diff(CSR_W.indptr))
    idx = rng.choice(len(src_all), 6, replace=False)
    b = UpdateBatch.deletes(src_all[idx], CSR_W.indices[idx])
    stats = sess.apply_updates(b)
    assert stats.dirty_blocks > 0
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(CSR_W, b),
                             [SSSP(source=0), SSSP(source=17)], seed=2)
    np.testing.assert_array_equal(sess.result(h0), ref[0])
    np.testing.assert_array_equal(sess.result(h1), ref[1])


def test_wcc_delete_splits_component_conservative_reseed():
    """Zero-weight label propagation has no support order — deletes fall
    back to conservative reachability reseed and still match exactly."""
    csr = chain_graph(96)                       # ring: one component
    sess = GraphSession(csr, 16, capacity=1, seed=0)
    h = sess.submit(WCC())
    assert sess.run(TwoLevel(), 50000).converged
    assert sess.result(h).max() == 0.0          # single component
    # cutting one directed ring edge leaves the (symmetrized) component
    # intact; cutting two splits the undirected cycle in two
    b = UpdateBatch.deletes([10, 50], [11, 51])
    sess.apply_updates(b)
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(csr, b), [WCC()], block=16)
    np.testing.assert_array_equal(sess.result(h), ref[0])
    assert len(np.unique(sess.result(h))) == 2  # two components now


def test_plus_times_weighted_reweight_exact_correction():
    """Weighted Katz (raw weights, contractive alpha) absorbs reweights/
    deletes/inserts via the exact delta-invariant correction.  (PageRank
    stays off weighted graphs — out-degree normalization is only
    stochastic for unit weights, the repo-wide convention.)"""
    algs = [Katz(alpha=0.01)]
    sess = GraphSession(CSR_W, 32, capacity=2, seed=0)
    handles = [sess.submit(a) for a in algs]
    assert sess.run(TwoLevel(), 50000).converged
    src_all = np.repeat(np.arange(CSR_W.n), np.diff(CSR_W.indptr))
    b = UpdateBatch.concat([
        UpdateBatch.inserts(src_all[[3, 40]], CSR_W.indices[[3, 40]],
                            [0.25, 8.0]),       # reweights of existing edges
        UpdateBatch.deletes(src_all[[80]], CSR_W.indices[[80]]),
        UpdateBatch.inserts([5], [190], [2.0]),  # structural insert
    ])
    sess.apply_updates(b)
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(CSR_W, b), algs)
    _check(algs, [sess.result(h) for h in handles], ref)


def test_pagerank_degree_rescale_on_inserts_and_deletes():
    """Unit-weight PageRank: inserts/deletes change out-degrees, so the
    whole source row rescales (deg_old/deg_new) and the delta correction
    covers every entry of the changed rows."""
    algs = [PageRank(), PersonalizedPageRank(source=7)]
    sess = GraphSession(CSR, 32, capacity=2, seed=0)
    handles = [sess.submit(a) for a in algs]
    assert sess.run(TwoLevel(), 50000).converged
    src_all = np.repeat(np.arange(CSR.n), np.diff(CSR.indptr))
    b = UpdateBatch.concat([
        UpdateBatch.inserts([7, 7, 100], [33, 231, 5]),   # degree changes
        UpdateBatch.deletes(src_all[[10, 120]], CSR.indices[[10, 120]]),
    ])
    sess.apply_updates(b)
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(CSR, b), algs)
    _check(algs, [sess.result(h) for h in handles], ref)


def test_heterogeneous_session_absorbs_update_stream():
    """The full mix — two PT views + two MP views over one shared CSR —
    under a multi-batch stream, one view compacted mid-stream."""
    algs = [PageRank(), PersonalizedPageRank(source=7), SSSP(source=0),
            BFS(source=3)]
    sess = GraphSession(CSR, 32, capacity=2, seed=4)
    handles = [sess.submit(a) for a in algs]
    assert sess.run(TwoLevel(), 50000).converged
    csr_k = CSR
    for i, b in enumerate(mutation_stream(CSR, 3, inserts_per_batch=5,
                                          deletes_per_batch=2, seed=5)):
        sess.apply_updates(b)
        if i == 1:
            sess.compact()                      # explicit mid-stream compact
        assert sess.run(TwoLevel(), 50000).converged
        csr_k = apply_to_csr(csr_k, b)
    _, ref = _fresh_fixpoint(csr_k, algs, seed=4)
    _check(algs, [sess.result(h) for h in handles], ref)


# -- overlay + compaction ----------------------------------------------------


def test_overlay_absorbs_new_block_pair_and_compaction_is_bitwise():
    csr = chain_graph(256)
    sess = GraphSession(csr, 32, capacity=1, seed=0, overlay_capacity=4)
    h = sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(), 50000).converged
    b = UpdateBatch.inserts([5], [200])         # block pair (0, 6): no slot
    sess.apply_updates(b)
    grp = sess.view_groups()[0]
    assert grp.overlay.capacity == 4            # grew on first need
    assert grp.ov_entry == {(5, 200): (0, 0)}
    assert sess.run(TwoLevel(), 50000).converged
    ref_sess, ref = _fresh_fixpoint(apply_to_csr(csr, b), [SSSP(source=0)])
    np.testing.assert_array_equal(sess.result(h), ref[0])
    # deleting the overlay edge clears its slot
    sess.apply_updates(UpdateBatch.deletes([5], [200]))
    assert grp.ov_entry == {} and not grp.ov_used.any()
    sess.apply_updates(b)                       # and it can come back
    assert sess.run(TwoLevel(), 50000).converged
    np.testing.assert_array_equal(sess.result(h), ref[0])
    sess.compact()
    grp = sess.view_groups()[0]
    assert grp.overlay.capacity == 0
    for a_s, a_r in (("tiles", "tiles"), ("nbr_ids", "nbr_ids"),
                     ("nbr_mask", "nbr_mask")):
        np.testing.assert_array_equal(
            np.asarray(getattr(grp.graph, a_s)),
            np.asarray(getattr(ref_sess.view_groups()[0].graph, a_r)))
    assert sess.run(TwoLevel(), 50000).converged
    np.testing.assert_array_equal(sess.result(h), ref[0])


def test_overlay_slot_reclaimed_in_same_batch():
    """A slot freed by a delete and reclaimed by an insert in the SAME
    batch must apply the insert (duplicate scatter indices are deduped;
    an unspecified-order scatter could let the stale clear win)."""
    csr = chain_graph(256)
    sess = GraphSession(csr, 32, capacity=1, seed=0, overlay_capacity=1)
    h = sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(), 50000).converged
    sess.apply_updates(UpdateBatch.inserts([5], [200]))   # fills (0, 0)
    grp = sess.view_groups()[0]
    assert grp.ov_entry == {(5, 200): (0, 0)}
    b = UpdateBatch.concat([UpdateBatch.deletes([5], [200]),
                            UpdateBatch.inserts([6], [210])])
    sess.apply_updates(b)                       # reclaims slot (0, 0)
    assert grp.ov_entry == {(6, 210): (0, 0)}
    assert float(grp.overlay.mask[0, 0]) == 1.0  # insert won, not the clear
    assert int(grp.overlay.dst[0, 0]) == 210
    assert sess.run(TwoLevel(), 50000).converged
    csr_k = apply_to_csr(apply_to_csr(csr, UpdateBatch.inserts([5], [200])),
                         b)
    _, ref = _fresh_fixpoint(csr_k, [SSSP(source=0)])
    np.testing.assert_array_equal(sess.result(h), ref[0])


def test_overlay_overflow_triggers_compaction():
    csr = chain_graph(256)
    sess = GraphSession(csr, 32, capacity=1, seed=0, overlay_capacity=2)
    h = sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(), 50000).converged
    # 3 structurally-new pairs from block 0 > capacity 2 -> compact
    b = UpdateBatch.inserts([1, 2, 3], [100, 150, 200])
    stats = sess.apply_updates(b)
    assert stats.compacted_views == 1
    grp = sess.view_groups()[0]
    assert grp.overlay.capacity == 0            # emptied by compaction
    assert grp.graph.max_nbr_blocks > 2         # rebuilt layout holds them
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(csr, b), [SSSP(source=0)])
    np.testing.assert_array_equal(sess.result(h), ref[0])


# -- scheduling integration --------------------------------------------------


def test_dirty_boost_reaches_both_drivers_and_is_consumed():
    for policy in (TwoLevel(), Fused()):
        sess = GraphSession(CSR, 32, capacity=1, seed=0)
        h = sess.submit(SSSP(source=0))
        assert sess.run(policy, 50000).converged
        sess.apply_updates(UpdateBatch.inserts([0], [250], [1.0]))
        assert sess._dirty_boost is not None
        assert (sess._dirty_boost > 0).any()
        m = sess.step(policy)                   # first superstep consumes it
        assert sess._dirty_boost is None
        assert m.updates_applied == 1 and m.dirty_blocks > 0
        m2 = sess.run(policy, 50000)
        assert m2.converged and m2.updates_applied == 0
        del h


def test_stream_metrics_counters():
    sess = GraphSession(CSR_W, 32, capacity=1, seed=0)
    sess.submit(SSSP(source=0))
    assert sess.run(TwoLevel(), 50000).converged
    src_all = np.repeat(np.arange(CSR_W.n), np.diff(CSR_W.indptr))
    sess.apply_updates(UpdateBatch.deletes(src_all[[0]], CSR_W.indices[[0]]))
    sess.apply_updates(UpdateBatch.inserts([1], [2], [0.1]))
    m = sess.run(TwoLevel(), 50000)
    assert m.converged
    assert m.updates_applied == 2               # accumulated across applies
    assert m.dirty_blocks >= 1
    assert 0.0 <= m.reseed_fraction <= 1.0


def test_apply_updates_requires_session_csr():
    from repro.core import ConcurrentEngine, make_run
    eng = ConcurrentEngine(make_run([PageRank()], CSR, 32), seed=0)
    with pytest.raises(ValueError, match="CSRGraph"):
        eng.session.apply_updates(UpdateBatch.inserts([0], [1]))
    sess = GraphSession(CSR, 32)
    with pytest.raises(TypeError):
        sess.apply_updates([(0, 1, 1.0)])


def test_apply_updates_before_first_submit():
    sess = GraphSession(CSR, 32, capacity=1, seed=0)
    b = UpdateBatch.inserts([0], [250], [1.0])
    stats = sess.apply_updates(b)               # no views yet: CSR advances
    assert stats.updates_applied == 1 and stats.dirty_blocks == 0
    h = sess.submit(SSSP(source=0))             # view built from updated CSR
    assert sess.run(TwoLevel(), 50000).converged
    _, ref = _fresh_fixpoint(apply_to_csr(CSR, b), [SSSP(source=0)])
    np.testing.assert_array_equal(sess.result(h), ref[0])


@pytest.mark.slow
def test_pallas_push_consumes_overlay():
    """The kernel-backed shared push applies the overlay ride-along in
    jnp around the pallas base push — min-plus stays bitwise equal to the
    vmap path under a structural insert."""
    csr = chain_graph(128)
    b = UpdateBatch.inserts([3], [100])         # new block pair for Vb=32
    results = {}
    for pallas in (False, True):
        sess = GraphSession(csr, 32, capacity=1, seed=0, use_pallas=pallas)
        h = sess.submit(SSSP(source=0))
        assert sess.run(TwoLevel(), 50000).converged
        sess.apply_updates(b)
        assert sess.view_groups()[0].overlay.capacity > 0
        assert sess.run(TwoLevel(), 50000).converged
        results[pallas] = sess.result(h)
    np.testing.assert_array_equal(results[True], results[False])
    _, ref = _fresh_fixpoint(apply_to_csr(csr, b), [SSSP(source=0)])
    np.testing.assert_array_equal(results[True], ref[0])
    # plus-times arm of the wrapper (overlay contribution from the
    # pre-consumption deltas): same insert under PageRank
    pt = {}
    for pallas in (False, True):
        sess = GraphSession(csr, 32, capacity=1, seed=0, use_pallas=pallas)
        h = sess.submit(PageRank())
        assert sess.run(TwoLevel(), 50000).converged
        sess.apply_updates(b)
        assert sess.run(TwoLevel(), 50000).converged
        pt[pallas] = sess.result(h)
    np.testing.assert_allclose(pt[True], pt[False], rtol=1e-5, atol=1e-7)
    _, ref_pt = _fresh_fixpoint(apply_to_csr(csr, b), [PageRank()])
    np.testing.assert_allclose(pt[True], ref_pt[0], rtol=1e-3, atol=1e-5)


def test_serve_dirty_group_injection():
    """The serve-layer analogue: notify_group_update front-runs admission
    for streams waiting on updated groups, for exactly one step."""
    from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                        RequestStream)

    def build():
        sched = ConcurrentServeScheduler(n_groups=16, batch_budget=2, seed=0)
        s = RequestStream(0)
        for g in range(16):                     # one request per group,
            s.add(Request(0, g, urgency=16 - g, tokens_left=1))
        sched.add_stream(s)                     # group 0 most urgent
        return sched

    base = build()
    admitted = base.schedule_step()
    assert all(r.group != 13 for r in admitted)  # low urgency: not admitted
    boosted = build()
    boosted.notify_group_update([13])
    admitted = boosted.schedule_step()
    assert any(r.group == 13 for r in admitted)  # dirty group front-runs
    assert boosted._dirty_boost is None          # consumed
    with pytest.raises(ValueError):
        boosted.notify_group_update([99])
