"""Fused superstep megakernel + sparse block-pair tests.

Covers the bugfix acceptance criteria:
  * the megakernel (select -> stage -> multi-job push -> priority pairs in
    ONE Pallas program over destination-sorted BlockPairs) matches the jnp
    oracle — bitwise for min-plus, float-tolerance for plus-times;
  * `BlockPairs` construction invariants (dst-sorted runs, first/last
    flags, src_nnz real-byte accounting, dense_op faithfulness, the
    edgeless inert pad pair);
  * interpret-resolution has ONE source of truth (`kernels.common`):
    interpret=None means interpret iff backend != "tpu", for every
    kernel entry point (the silent-interpret bug regression);
  * scatter drop-mode parity: sentinel (out-of-range) neighbour ids are
    DROPPED identically by the kernel route and the vmapped engine push;
  * prime job counts degrade the job chunk to 1 under a tight VMEM
    budget and the kernel still validates inside that budget;
  * padded selection slots aliasing block 0 must not re-push block 0;
  * `tile_pair_loads` (real adjacency bytes) agrees between the host and
    device drivers and across the kernel/jnp push routes.
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graph import (CSRGraph, build_blocked, build_block_pairs,
                         rmat_graph, uniform_graph)
from repro.kernels import common
from repro.kernels.fused_superstep import ops as fused_ops
from repro.kernels.fused_superstep.kernel import fused_superstep_call
from repro.kernels.fused_superstep.ops import _pick_job_block, fused_push
from repro.kernels.fused_superstep.ref import fused_superstep_ref
from repro.core.push import push_min_one, push_plus_one, shared_push_fn


def _pairs_for(semiring, n=150, deg=4, vb=16, seed=13):
    if semiring == "plus_times":
        csr = rmat_graph(n, deg, seed=seed)
        g = build_blocked(csr, vb, fill=0.0, normalize="out_degree")
    else:
        csr = uniform_graph(n, deg, seed=seed, weighted=True, w_max=7.0)
        g = build_blocked(csr, vb, fill=float(np.inf))
    return g, build_block_pairs(g)


def _rand_state(rng, j, bn, vb, semiring):
    if semiring == "plus_times":
        d = rng.standard_normal((j, bn, vb)).astype(np.float32)
        base = rng.standard_normal((j, bn, vb)).astype(np.float32)
        return jnp.asarray(d), jnp.asarray(base), None
    d = (rng.random((j, bn, vb)) * 10).astype(np.float32)
    d[rng.random(d.shape) < 0.5] = np.inf          # non-pending vertices
    vals = (rng.random((j, bn, vb)) * 10).astype(np.float32)
    base = np.where(rng.random((j, bn, vb)) < 0.5, vals, np.inf)
    return jnp.asarray(d), jnp.asarray(base), jnp.asarray(vals)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("jb", [None, 1, 2])
@pytest.mark.parametrize("j", [1, 4, 6])
def test_fused_kernel_matches_ref_plus_times(j, jb):
    if jb is not None and j % jb:
        pytest.skip("job_block must divide J")
    g, bp = _pairs_for("plus_times")
    rng = np.random.default_rng(j * 10 + (jb or 0))
    d, base, _ = _rand_state(rng, j, g.num_blocks, g.block_size,
                             "plus_times")
    out, nu, ps = fused_superstep_call(
        bp.src, bp.dst, bp.first, bp.last, d, base, bp.tiles,
        semiring="plus_times", tolerance=1e-6, job_block=jb,
        interpret=True)
    r_out, r_nu, r_ps = fused_superstep_ref(
        bp.src, bp.dst, bp.first, bp.last, d, base, bp.tiles,
        semiring="plus_times", tolerance=1e-6)
    t = np.asarray(bp.dst_touched)
    np.testing.assert_allclose(np.asarray(out)[:, t], np.asarray(r_out)[:, t],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(nu)[:, t], np.asarray(r_nu)[:, t])
    np.testing.assert_allclose(np.asarray(ps)[:, t], np.asarray(r_ps)[:, t],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("jb", [None, 1, 3])
def test_fused_kernel_matches_ref_min_plus_bitwise(jb):
    """Min is exact in any evaluation order: the kernel's fused per-pair
    min-fold must be BIT-EQUAL to the oracle's scatter-min."""
    j = 6
    g, bp = _pairs_for("min_plus")
    rng = np.random.default_rng(7 + (jb or 0))
    d, base, vals = _rand_state(rng, j, g.num_blocks, g.block_size,
                                "min_plus")
    vo, do, nu, ps = fused_superstep_call(
        bp.src, bp.dst, bp.first, bp.last, d, base, bp.tiles,
        values=vals, semiring="min_plus", job_block=jb, interpret=True)
    r_vo, r_do, r_nu, r_ps = fused_superstep_ref(
        bp.src, bp.dst, bp.first, bp.last, d, base, bp.tiles,
        values=vals, semiring="min_plus")
    t = np.asarray(bp.dst_touched)
    np.testing.assert_array_equal(np.asarray(vo)[:, t], np.asarray(r_vo)[:, t])
    np.testing.assert_array_equal(np.asarray(do)[:, t], np.asarray(r_do)[:, t])
    np.testing.assert_array_equal(np.asarray(nu)[:, t], np.asarray(r_nu)[:, t])
    np.testing.assert_allclose(np.asarray(ps)[:, t], np.asarray(r_ps)[:, t],
                               rtol=1e-6)


@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_fused_push_matches_vmapped_engine_push(semiring):
    """The megakernel route == the per-job vmapped engine push on a real
    selection (min-plus bitwise; plus-times within contraction-order
    tolerance)."""
    g, bp = _pairs_for(semiring)
    rng = np.random.default_rng(3)
    j, bn, vb = 4, g.num_blocks, g.block_size
    _, deltas, vals = _rand_state(rng, j, bn, vb, "min_plus")
    if semiring == "plus_times":
        vals = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
        deltas = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
    sel = jnp.asarray([0, 2, 5, 7], jnp.int32)
    msk = jnp.ones(4, jnp.float32)
    scales = jnp.asarray(rng.random(j), jnp.float32)
    push_one = push_plus_one if semiring == "plus_times" else push_min_one
    v1, d1 = jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0))(
        vals, deltas, g.tiles, g.nbr_ids, sel, msk, scales)
    v2, d2 = fused_push(vals, deltas, bp, sel, msk, scales,
                        semiring=semiring, interpret=True)
    if semiring == "min_plus":
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    else:
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)


def test_shared_push_fn_pair_emulation_matches_vmap():
    """use_pallas=False plus-times pair sweep (per-(job, pair) einsum +
    scatter-add) == the vmapped push_one it replaced."""
    g, bp = _pairs_for("plus_times")
    rng = np.random.default_rng(11)
    j, bn, vb = 3, g.num_blocks, g.block_size
    vals = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
    dels = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
    sel = jnp.asarray([1, 4, 6], jnp.int32)
    msk = jnp.ones(3, jnp.float32)
    scales = jnp.asarray(rng.random(j), jnp.float32)
    fn = shared_push_fn("plus_times", push_plus_one, use_pallas=False)
    v1, d1 = fn(vals, dels, g.tiles, g.nbr_ids, sel, msk, scales, None, None)
    v2, d2 = fn(vals, dels, g.tiles, g.nbr_ids, sel, msk, scales, None, bp)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# BlockPairs construction
# ---------------------------------------------------------------------------

def test_block_pairs_invariants():
    g, bp = _pairs_for("plus_times", seed=5)
    src, dst, slot = map(np.asarray, (bp.src, bp.dst, bp.slot))
    first, last = np.asarray(bp.first), np.asarray(bp.last)
    ids, msk = np.asarray(g.nbr_ids), np.asarray(g.nbr_mask)
    assert bp.num_pairs == int(msk.sum())
    assert (np.diff(dst) >= 0).all()                     # dst-sorted
    assert (ids[src, slot] == dst).all()                 # slot consistency
    # first/last mark exactly the dst-run boundaries
    np.testing.assert_array_equal(first[1:], (dst[1:] != dst[:-1]))
    assert first[0] == 1 and last[-1] == 1
    np.testing.assert_array_equal(last[:-1], first[1:])
    # src_nnz counts real pairs per SOURCE block (tile_pair_loads unit)
    np.testing.assert_array_equal(np.asarray(bp.src_nnz),
                                  msk.sum(axis=1).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(bp.dst_touched),
        np.isin(np.arange(g.num_blocks), dst))
    # pair tiles are the real ELL tiles, in pair order
    np.testing.assert_array_equal(np.asarray(bp.tiles),
                                  np.asarray(g.tiles)[src, slot])


def test_block_pairs_dense_op_reconstructs_operator():
    g, bp = _pairs_for("plus_times", n=100, deg=6, vb=16, seed=2)
    if bp.dense_op is None:
        pytest.skip("graph below dense_op density threshold")
    bn, vb = g.num_blocks, g.block_size
    dense = np.zeros((bn, vb, bn, vb), np.float32)
    src, dst = np.asarray(bp.src), np.asarray(bp.dst)
    dense[src, :, dst, :] = np.asarray(bp.tiles)
    np.testing.assert_array_equal(np.asarray(bp.dense_op),
                                  dense.reshape(bn * vb, bn * vb))


def test_block_pairs_edgeless_pad_pair_is_inert():
    csr = CSRGraph.from_edges(40, [], [])
    for fill, semiring in ((0.0, "plus_times"), (float(np.inf), "min_plus")):
        g = build_blocked(csr, 16, fill=fill)
        bp = build_block_pairs(g)
        assert bp.num_pairs == 1
        assert int(np.asarray(bp.src_nnz).sum()) == 0
        assert not np.asarray(bp.dst_touched).any()
        rng = np.random.default_rng(0)
        d, base, vals = _rand_state(rng, 2, g.num_blocks, 16, semiring)
        v, dl = fused_push(vals if vals is not None
                           else jnp.zeros_like(base), base, bp,
                           jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.float32),
                           jnp.ones(2, jnp.float32), semiring=semiring,
                           interpret=True)
        # nothing selected, nothing touched: state passes through
        np.testing.assert_array_equal(np.asarray(dl), np.asarray(base))


# ---------------------------------------------------------------------------
# interpret resolution (silent-interpret regression)
# ---------------------------------------------------------------------------

def test_interpret_resolves_false_on_tpu_backend(monkeypatch):
    """interpret=None must mean interpret=False when the backend is a real
    TPU — the one-source-of-truth rule in kernels.common.  (The old
    mj_spmm_call defaulted interpret=True unconditionally: a TPU caller
    bypassing ops.mj_spmm silently ran the interpreter.)"""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert common.default_interpret() is False
    assert common.resolve_interpret(None) is False
    assert common.resolve_interpret(True) is True

    seen = {}

    def spy(*a, **kw):
        seen["interpret"] = kw["interpret"]
        raise RuntimeError("stop")

    import repro.kernels.mj_spmm.kernel as mjk
    monkeypatch.setattr(mjk, "_mj_spmm_jit", spy)
    with pytest.raises(RuntimeError):
        mjk.mj_spmm_call(jnp.zeros((1, 2, 8)), jnp.zeros((1, 1, 8, 8)))
    assert seen["interpret"] is False

    import repro.kernels.priority_pairs.kernel as ppk
    monkeypatch.setattr(ppk, "_pairs_jit", spy)
    with pytest.raises(RuntimeError):
        ppk.priority_pairs_call(jnp.zeros((1, 2, 8)))
    assert seen["interpret"] is False


def test_interpret_resolves_true_off_tpu():
    assert jax.default_backend() != "tpu"
    assert common.default_interpret() is True
    assert common.resolve_interpret(None) is True
    assert common.resolve_interpret(False) is False


# ---------------------------------------------------------------------------
# scatter drop-mode parity (sentinel neighbour ids)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_push_shared_drops_sentinel_neighbors_like_vmap(semiring):
    """Out-of-range neighbour ids (sentinel BN) must be DROPPED by the
    kernel route's scatter exactly as by the engine push — min-plus
    bitwise.  (The old plus-times scatter omitted mode="drop", leaving
    the sentinel behavior unspecified rather than aligned.)"""
    from repro.kernels.mj_spmm.ops import push_shared
    rng = np.random.default_rng(4)
    J, BN, VB, K = 3, 6, 16, 3
    tiles = np.where(rng.random((BN, K, VB, VB)) < 0.7, 0.0,
                     rng.random((BN, K, VB, VB))).astype(np.float32)
    nbr = rng.integers(0, BN, (BN, K)).astype(np.int32)
    nbr[:, -1] = BN                 # sentinel slot: out of range -> dropped
    nbr = jnp.asarray(nbr)
    if semiring == "min_plus":
        tiles = np.where(tiles == 0.0, np.inf, tiles)
    tiles = jnp.asarray(tiles)
    sel = jnp.asarray([0, 2, 4], jnp.int32)
    msk = jnp.ones(3, jnp.float32)
    scale = jnp.asarray(rng.random(J), jnp.float32)
    if semiring == "plus_times":
        vals = jnp.asarray(rng.random((J, BN, VB)), jnp.float32)
        dels = jnp.asarray(rng.random((J, BN, VB)), jnp.float32)
        push_one = push_plus_one
    else:
        vals = jnp.asarray(rng.random((J, BN, VB)) * 10, jnp.float32)
        dels = jnp.where(jnp.asarray(rng.random((J, BN, VB))) < 0.5,
                         vals, jnp.inf)
        push_one = push_min_one
    v1, d1 = jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0))(
        vals, dels, tiles, nbr, sel, msk, scale)
    v2, d2 = push_shared(vals, dels, tiles, nbr, sel, msk, scale,
                         semiring=semiring, interpret=True)
    if semiring == "min_plus":
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    else:
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# job-chunk degradation + padded-slot aliasing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_prime_job_count_degrades_chunk_and_validates(monkeypatch, semiring):
    """J=13 (prime) under a tight VMEM budget: the only divisor under the
    cap is 1 — the kernel must still validate and its per-cell footprint
    must honour the (monkeypatched) budget."""
    from repro.analysis import contracts as C
    vb = 16
    stripes = 3 if semiring == "plus_times" else 6
    fixed = vb * vb * 4
    per_job = (stripes * vb + 2) * 4
    budget = fixed + 4 * per_job + 1        # room for jb=4 -> degrade to 1
    monkeypatch.setattr(common, "VMEM_BUDGET", budget)
    assert _pick_job_block(13, vb, semiring) == 1
    assert C.fused_superstep_vmem_bytes(13, vb, semiring) <= budget

    g, bp = _pairs_for(semiring, vb=vb)
    rng = np.random.default_rng(9)
    d, base, vals = _rand_state(rng, 13, g.num_blocks, vb, semiring)
    sel = jnp.asarray([0, 2, 5], jnp.int32)
    msk = jnp.ones(3, jnp.float32)
    scales = jnp.ones(13, jnp.float32)
    push_one = push_plus_one if semiring == "plus_times" else push_min_one
    if vals is None:
        vals = jnp.zeros_like(base)
    v1, d1 = jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0))(
        vals, base, g.tiles, g.nbr_ids, sel, msk, scales)
    v2, d2 = fused_push(vals, base, bp, sel, msk, scales,
                        semiring=semiring, interpret=True)
    if semiring == "min_plus":
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    else:
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_padded_selection_slot_aliasing_block0(semiring):
    """A padded selection slot aliases block 0 (sel id 0, mask 0).  With
    block 0 ITSELF selected in a live slot, the padded alias must not
    re-push block 0 — parity with the mask-aware engine push."""
    g, bp = _pairs_for(semiring)
    rng = np.random.default_rng(6)
    j, bn, vb = 3, g.num_blocks, g.block_size
    _, base, vals = _rand_state(rng, j, bn, vb, "min_plus")
    if semiring == "plus_times":
        vals = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
        base = jnp.asarray(rng.random((j, bn, vb)), jnp.float32)
    sel = jnp.asarray([0, 3, 0], jnp.int32)       # slot 2 pads onto block 0
    msk = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    scales = jnp.asarray(rng.random(j), jnp.float32)
    push_one = push_plus_one if semiring == "plus_times" else push_min_one
    v1, d1 = jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0))(
        vals, base, g.tiles, g.nbr_ids, sel, msk, scales)
    v2, d2 = fused_push(vals, base, bp, sel, msk, scales,
                        semiring=semiring, interpret=True)
    if semiring == "min_plus":
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    else:
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# mj_spmm HBM-fetch accounting (the corrected BlockSpec residency story)
# ---------------------------------------------------------------------------

def test_mj_spmm_hbm_fetch_counts_per_grid_step_d_fetches(monkeypatch):
    """The d-chunk's index (i, jt) changes at (almost) every grid step, so
    d is re-fetched k times per job chunk — NOT kept resident across k as
    the old BlockSpec comment claimed.  Only the j/jb == 1 degenerate
    grid keeps d resident."""
    from repro.analysis import contracts as C
    import repro.kernels.mj_spmm.ops as mj_ops
    q, k, vb = 5, 3, 32
    # ample budget: jb == j -> jt == 1, d IS resident across k
    assert mj_ops._pick_job_block(8, vb) == 8
    assert (C.mj_spmm_hbm_fetch_bytes(q, k, 8, vb)
            == q * 1 * 8 * vb * 4 + q * k * vb * vb * 4)
    # tight budget: jb == 4 -> jt == 2, every d chunk fetched k times
    fixed = 2 * vb * vb * 4
    per_job = 2 * vb * 4
    monkeypatch.setattr(mj_ops, "_VMEM_BUDGET", fixed + 4 * per_job)
    assert mj_ops._pick_job_block(8, vb) == 4
    assert (C.mj_spmm_hbm_fetch_bytes(q, k, 8, vb)
            == q * k * 2 * 4 * vb * 4 + q * k * vb * vb * 4)


# ---------------------------------------------------------------------------
# pair-loads accounting across drivers
# ---------------------------------------------------------------------------

def test_tile_pair_loads_consistent_across_drivers():
    """tile_pair_loads (real nonzero pairs staged) must agree between the
    host driver, the device driver, and the kernel/jnp push routes — the
    selections are identical, so the real bytes moved are too."""
    from repro.algorithms import PageRank, SSSP
    from repro.core import GraphSession, TwoLevel

    csr = rmat_graph(150, 4, seed=13)
    loads = {}
    for label, use_pallas, policy in [
        ("host", False, TwoLevel()),
        ("host_k", True, TwoLevel()),
        ("dev", False, TwoLevel(backend="device", steps_per_sync=math.inf)),
    ]:
        sess = GraphSession(csr, 16, capacity=2, seed=5,
                            use_pallas=use_pallas)
        sess.submit(PageRank())
        sess.submit(SSSP(source=3))
        m = sess.run(policy, 20000)
        assert m.converged
        loads[label] = (m.supersteps, m.tile_loads, m.tile_pair_loads)
    assert loads["host"][2] > 0
    assert loads["host"] == loads["host_k"] == loads["dev"]
    # the pair accounting is finer than block staging: a staged block
    # moves src_nnz >= 0 pairs, bounded by K per block
    sup, tl, tpl = loads["host"]
    assert tpl <= tl * 16
    assert "tile_pair_loads" in m.to_dict()
