"""Distribution correctness, run in subprocesses with 8 host devices:

1. the paper's fused engine with the production sharding (jobs over `model`,
   vertex blocks over `data`) reaches the same PageRank fixpoint as the
   single-device run;
2. a checkpoint saved under one mesh restores onto a different mesh
   (elastic re-shard) bit-exactly.
"""

import os
import subprocess
import sys

ENGINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.algorithms import PageRank, PersonalizedPageRank
from repro.core import ConcurrentEngine, make_run
from repro.graph import rmat_graph

csr = rmat_graph(256, 5, seed=21)
algs = [PageRank(), PageRank(damping=0.7),
        PersonalizedPageRank(source=3), PersonalizedPageRank(source=99)]

# single-device reference
run_ref = make_run(algs, csr, block_size=16)
eng_ref = ConcurrentEngine(run_ref, seed=0)
m_ref = eng_ref.run_fused(20000)
assert m_ref.converged
ref = eng_ref.results()

# sharded: jobs over model, blocks over data
mesh = jax.make_mesh((4, 2), ("data", "model"))
run = make_run(algs, csr, block_size=16)
jobs_sh = NamedSharding(mesh, P("model", "data", None))
tile_sh = NamedSharding(mesh, P("data", None, None, None))
run.values = jax.device_put(run.values, jobs_sh)
run.deltas = jax.device_put(run.deltas, jobs_sh)
g = run.graph
g.tiles = jax.device_put(g.tiles, tile_sh)
g.nbr_ids = jax.device_put(g.nbr_ids, NamedSharding(mesh, P("data", None)))
eng = ConcurrentEngine(run, seed=0)
with mesh:
    m = eng.run_fused(20000)
assert m.converged
out = eng.results()
np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-7)
print("DIST-ENGINE-OK")
"""

ELASTIC_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import save_checkpoint, restore_checkpoint

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((8,), ("data",))
tree = {"w": jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", None))),
        "s": jnp.int32(7)}
save_checkpoint(d, 5, tree)

# restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 2x4)
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
sh = {"w": NamedSharding(mesh_b, P("model", "data")),
      "s": NamedSharding(mesh_b, P())}
restored, step = restore_checkpoint(d, like, sh)
assert step == 5
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
assert restored["w"].sharding.spec == P("model", "data")
print("ELASTIC-OK")
"""


def _run(script, marker):
    result = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=480,
        env={**os.environ, "PYTHONPATH": "src"})
    assert marker in result.stdout, result.stderr[-2000:]


def test_fused_engine_sharded_matches_single_device():
    _run(ENGINE_SCRIPT, "DIST-ENGINE-OK")


def test_elastic_checkpoint_reshard():
    _run(ELASTIC_SCRIPT, "ELASTIC-OK")
