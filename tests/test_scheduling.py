"""Unit + property tests for the paper's scheduling primitives."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (block_pairs, cbp, do_score, do_select, global_queue,
                        optimal_queue_length)


# --- Function 1 (CBP), paper Table 1 cases ---------------------------------

def test_cbp_case1_higher_mean_and_count_wins():
    assert cbp((10, 5.0), (5, 2.0))          # case 1: both larger -> a


def test_cbp_case3_equal_mean_more_nodes_wins():
    assert cbp((10, 2.0), (5, 2.0))          # case 3


def test_cbp_case4_equal_count_higher_mean_wins():
    assert cbp((5, 5.0), (5, 2.0))           # case 4


def test_cbp_case2_within_band_total_decides():
    # means within 20% band, b's total higher -> b wins
    a, b = (2, 10.0), (10, 9.0)              # |10-9| < 0.2*10; 20 < 90
    assert not cbp(a, b)
    assert cbp(b, a)


def test_cbp_case2_outside_band_mean_decides():
    a, b = (2, 10.0), (100, 7.0)             # |10-7| >= 2.0 -> mean decides
    assert cbp(a, b)


def test_cbp_antisymmetric_on_strict_orders():
    rng = np.random.default_rng(0)
    for _ in range(200):
        pa = (float(rng.integers(1, 50)), float(rng.uniform(0.1, 10)))
        pb = (float(rng.integers(1, 50)), float(rng.uniform(0.1, 10)))
        if pa == pb:
            continue
        # at most one strict winner (ties both-True are allowed only for
        # equal pairs, handled above)
        if cbp(pa, pb) and cbp(pb, pa):
            # both claim >=: acceptable only if neither mean nor total differ
            assert np.isclose(pa[1], pb[1]) and np.isclose(
                pa[0] * pa[1], pb[0] * pb[1])


# --- pairs (Eq. 1) -----------------------------------------------------------

def test_block_pairs_eq1():
    p = jnp.asarray([[[0.0, 2.0, 4.0, 0.0],
                      [0.0, 0.0, 0.0, 0.0]]])
    n, m = block_pairs(p)
    assert n[0, 0] == 2 and m[0, 0] == 3.0
    assert n[0, 1] == 0 and m[0, 1] == 0.0


# --- Function 2 (DO selection) ----------------------------------------------

@given(bn=st.integers(4, 300), qfrac=st.floats(0.05, 0.9),
       seed=st.integers(0, 10000))
@settings(max_examples=30, deadline=None)
def test_do_select_returns_live_sorted_queue(bn, qfrac, seed):
    rng = np.random.default_rng(seed)
    node_un = rng.integers(0, 20, bn).astype(np.float64)
    p_mean = np.where(node_un > 0, rng.uniform(0.1, 5.0, bn), 0.0)
    q = max(1, int(qfrac * bn))
    out = do_select(node_un, p_mean, q, np.random.default_rng(seed + 1), s=50)
    # no converged blocks, no duplicates, bounded length
    assert len(out) <= q
    assert len(set(out.tolist())) == len(out)
    assert (node_un[out] > 0).all()
    # CBP-descending order
    for i in range(len(out) - 1):
        a = (node_un[out[i]], p_mean[out[i]])
        b = (node_un[out[i + 1]], p_mean[out[i + 1]])
        assert cbp(a, b) or (a == b)


def test_do_select_picks_the_hot_block():
    bn = 100
    node_un = np.ones(bn)
    p_mean = np.full(bn, 0.01)
    node_un[42] = 50
    p_mean[42] = 100.0
    out = do_select(node_un, p_mean, 5, np.random.default_rng(0))
    assert out[0] == 42


def test_do_select_all_converged():
    out = do_select(np.zeros(10), np.zeros(10), 3, np.random.default_rng(0))
    assert len(out) == 0


# --- De_Gl_Priority -----------------------------------------------------------

def test_global_queue_fig7_accumulation():
    # two jobs, q=4; block 7 ranked head by both -> top cumulative Pri 2q=8
    jq = [np.array([7, 1, 2, 3]), np.array([7, 4, 5, 6])]
    gq = global_queue(jq, num_blocks=10, q=4, alpha=0.8)
    assert gq[0] == 7
    assert len(gq) <= 4


def test_global_queue_reserved_slots_for_individual_heads():
    # job B's head (block 9) has low cumulative weight but must be reserved
    jq = [np.array([1, 2, 3, 4, 5, 6, 7, 8]),
          np.array([1, 2, 3, 4, 5, 6, 7, 8]),
          np.array([9])]
    gq = global_queue(jq, num_blocks=12, q=8, alpha=0.8)
    assert 9 in gq.tolist()


def test_global_queue_empty():
    assert len(global_queue([np.empty(0, np.int64)], 5, 3)) == 0


# --- q = C * B_N / sqrt(V_N) (Eq. 4) -----------------------------------------

def test_optimal_queue_length_formula_and_clamp():
    # V_N = 1e6, B_N = 1000 -> q = 100*1000/1000 = 100
    assert optimal_queue_length(1000, 10**6) == 100
    assert optimal_queue_length(4, 10**6) == 1      # clamp low
    assert optimal_queue_length(10, 4) == 10        # clamp to B_N


# --- device DO score approximates CBP order ----------------------------------

def test_do_score_orders_clear_cases_like_cbp():
    n = jnp.asarray([10.0, 5.0, 0.0])
    m = jnp.asarray([5.0, 2.0, 0.0])
    s = np.asarray(do_score(n, m))
    assert s[0] > s[1]          # case 1
    assert s[2] == -np.inf      # converged
    # band case within one log-bucket: means within 20%, total decides
    n2 = jnp.asarray([2.0, 10.0])
    m2 = jnp.asarray([10.0, 9.8])
    s2 = np.asarray(do_score(n2, m2))
    assert s2[1] > s2[0]


def test_do_score_statistical_agreement_with_cbp():
    """CBP is non-transitive (band rule admits cycles), so no scalar score
    embeds it exactly; require high agreement on random pairs instead."""
    rng = np.random.default_rng(42)
    n = rng.integers(1, 50, size=4000).astype(np.float64)
    m = rng.uniform(0.1, 10.0, size=4000)
    s = np.asarray(do_score(jnp.asarray(n), jnp.asarray(m)))
    agree = total = 0
    for i in range(0, 4000, 2):
        a, b = (n[i], m[i]), (n[i + 1], m[i + 1])
        want = cbp(a, b)
        got = s[i] > s[i + 1]
        total += 1
        agree += int(want == got)
    assert agree / total > 0.85, agree / total


# --- metrics honesty ----------------------------------------------------------

def test_synthesize_never_exceeds_queue_budget():
    """tile_loads is charged as len(gq): the synthesis boundary must never
    hand back more blocks than the staged queue holds (asserted inside
    synthesize, pinned here with adversarially long/overlapping queues)."""
    from repro.core import TwoLevelScheduler
    rng = np.random.default_rng(0)
    sched = TwoLevelScheduler(num_blocks=64, q=7, alpha=0.8)
    for _ in range(25):
        n_jobs = int(rng.integers(1, 12))
        queues = [rng.permutation(64)[:rng.integers(0, 64)]
                  for _ in range(n_jobs)]
        gq = sched.synthesize(queues)
        assert len(gq) <= 7
        assert len(set(gq.tolist())) == len(gq)


def test_two_level_select_counts_only_the_staged_prefix():
    """The Selection must charge exactly the staged blocks: tile_loads ==
    number of valid queue slots <= q, and a (job, block) push event needs
    the job unconverged on a STAGED block."""
    from repro.algorithms import PageRank, SSSP
    from repro.core import GraphSession, TwoLevel
    from repro.graph import rmat_graph

    sess = GraphSession(rmat_graph(200, 5, seed=2), 16, capacity=2, seed=0)
    sess.submit(PageRank())
    sess.submit(SSSP(source=0))
    groups = sess.view_groups()
    node_un, p_mean, active = [], [], []
    for g in groups:
        nu, pm = map(np.asarray, sess._pairs_fn(g)(g.values, g.deltas))
        node_un.append(nu)
        p_mean.append(pm)
        active.append(np.asarray(
            sess._counts_fn(g)(g.values, g.deltas)) > 0)
    selection = TwoLevel().select(sess, node_un, p_mean, active)
    assert selection.sel.shape == (sess.q,)
    assert selection.tile_loads == int(selection.msk.sum()) <= sess.q
    staged = selection.sel[selection.msk > 0]
    expect = sum(int((nu[:, staged] > 0).sum()) for nu in node_un)
    assert selection.job_block_pushes == expect


def test_two_level_and_fused_metrics_agree_on_saturated_queue():
    """On a workload whose hot set always fits the queue (q == B_N), the
    host TwoLevel and the device Fused scheduler stage exactly the same
    blocks each superstep, so tile_loads / job_block_pushes / supersteps
    must agree EXACTLY — pinning that both report the same definition of a
    staging and of a (job, block) processing event.  Min-plus jobs make the
    trajectory bit-reproducible (min is exact in any evaluation order)."""
    from repro.algorithms import SSSP
    from repro.core import ConcurrentEngine, make_run
    from repro.graph import uniform_graph

    csr = uniform_graph(48, 3, seed=4, weighted=True, w_max=5.0)
    algs = [SSSP(source=0), SSSP(source=17)]
    m_t = ConcurrentEngine(make_run(algs, csr, 16), seed=0).run_two_level(20000)
    m_f = ConcurrentEngine(make_run(algs, csr, 16), seed=0).run_fused(20000)
    assert m_t.converged and m_f.converged
    assert m_t.supersteps == m_f.supersteps
    assert m_t.tile_loads == m_f.tile_loads
    assert m_t.job_block_pushes == m_f.job_block_pushes
