"""Substrate tests: optimizer, checkpoint/elastic-restore, restart manager,
gradient compression, data pipeline, concurrent serve scheduler."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   wsd_schedule)
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    latest_step, AsyncCheckpointer)
from repro.dist.fault import RestartManager, StragglerWatchdog
from repro.data.pipeline import SyntheticTokens, PackedFileDataset, Prefetcher


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, schedule="const")
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, metrics = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2
    assert np.isfinite(float(metrics["grad_norm"]))


def test_wsd_schedule_phases():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      stable_frac=0.5, schedule="wsd")
    warm = float(wsd_schedule(cfg, jnp.asarray(5)))
    stable = float(wsd_schedule(cfg, jnp.asarray(30)))
    decay = float(wsd_schedule(cfg, jnp.asarray(90)))
    assert warm < stable
    assert stable == 1.0
    assert decay < stable


def test_grad_clip_applied():
    cfg = AdamWConfig(peak_lr=0.0, clip_norm=1.0, schedule="const")
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(cfg, g, opt, params)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_checkpoint_roundtrip_and_elastic(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray([1.5], jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    for orig, new in zip(jax.tree_util.tree_leaves(tree),
                         jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(orig, np.float32),
                                      np.asarray(new, np.float32))
    # elastic: restore with explicit (different) sharding on a 1-device mesh
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
    restored2, _ = restore_checkpoint(str(tmp_path), like, sh)
    np.testing.assert_array_equal(np.asarray(restored2["a"]),
                                  np.asarray(tree["a"]))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, {"x": jnp.ones(5)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


def test_restart_manager_recovers_from_failures(tmp_path):
    """Simulated preemptions at fixed steps; training must complete with
    identical final state to an uninterrupted run (deterministic data)."""
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, schedule="const")

    def step_fn(state, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch) ** 2)
        g = jax.grad(loss)(state["params"])
        new_p, new_opt, m = adamw_update(cfg, g, state["opt"],
                                         state["params"])
        return {"params": new_p, "opt": new_opt}, m

    def data_fn(step):
        return jnp.asarray(np.random.default_rng(step).standard_normal(4),
                           jnp.float32)

    init = {"params": {"w": jnp.zeros(4)},
            "opt": adamw_init({"w": jnp.zeros(4)})}

    fails = {17, 42}

    def failure_hook(step):
        if step in fails:
            fails.remove(step)
            raise RuntimeError(f"simulated preemption at {step}")

    mgr = RestartManager(str(tmp_path / "ckpt"), save_every=10)
    state, steps, restarts = mgr.run(init, step_fn, data_fn, 60,
                                     failure_hook=failure_hook)
    assert steps == 60 and restarts == 2

    # uninterrupted reference
    ref = init
    for s in range(60):
        ref, _ = step_fn(ref, data_fn(s))
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(ref["params"]["w"]), rtol=1e-5)


def test_restart_manager_survives_donated_state_and_early_failure(tmp_path):
    """Production callers jit the step with donate_argnums=(0,), so the
    initial state's buffers are DEAD after step 1.  A preemption before the
    first periodic checkpoint must still recover (from the step-0 snapshot),
    never from the deleted initial buffers."""
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, schedule="const")

    def raw_step(state, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch) ** 2)
        g = jax.grad(loss)(state["params"])
        new_p, new_opt, m = adamw_update(cfg, g, state["opt"],
                                         state["params"])
        return {"params": new_p, "opt": new_opt}, m

    def data_fn(step):
        return jnp.asarray(np.random.default_rng(step).standard_normal(4),
                           jnp.float32)

    def make_init():
        return {"params": {"w": jnp.zeros(4)},
                "opt": adamw_init({"w": jnp.zeros(4)})}

    # uninterrupted reference (on its own buffers)
    ref = make_init()
    for s in range(12):
        ref, _ = raw_step(ref, data_fn(s))

    def donating_step(state, batch):
        out = raw_step(state, batch)
        for leaf in jax.tree_util.tree_leaves(state):
            leaf.delete()          # emulate donate_argnums=(0,)
        return out

    fails = {2}

    def failure_hook(step):
        if step in fails:
            fails.remove(step)
            raise RuntimeError("early preemption")

    mgr = RestartManager(str(tmp_path / "ckpt"), save_every=10)
    state, steps, restarts = mgr.run(make_init(), donating_step, data_fn, 12,
                                     failure_hook=failure_hook)
    assert steps == 12 and restarts == 1
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(ref["params"]["w"]), rtol=1e-5)


def test_straggler_watchdog():
    wd = StragglerWatchdog(window=8, threshold=2.0)
    for s in range(8):
        assert wd.observe(s, 1.0) is None
    rep = wd.observe(8, 5.0)
    assert rep is not None and rep.ratio == pytest.approx(5.0)


def test_compressed_psum_matches_exact_within_tolerance():
    from repro.dist.compression import make_compressed_grad_fn
    mesh = jax.make_mesh((1,), ("data",))

    def loss_fn(params, batch):
        return jnp.mean((batch @ params["w"]) ** 2)

    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((4, 2)), jnp.float32)}
    batch = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4)),
                        jnp.float32)
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    fn = make_compressed_grad_fn(mesh, loss_fn)
    with mesh:
        loss, grads, new_err = fn(params, err, batch)
    _, exact = jax.value_and_grad(loss_fn)(params, batch)
    np.testing.assert_allclose(np.asarray(grads["w"]),
                               np.asarray(exact["w"]), atol=2e-2)
    # error feedback carries the quantization residual
    assert float(jnp.abs(new_err["w"]).max()) > 0.0


def test_synthetic_data_deterministic_and_resumable():
    ds = SyntheticTokens(1000, 4, 16, seed=3)
    a = np.asarray(ds(5)["tokens"])
    b = np.asarray(ds(5)["tokens"])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, np.asarray(ds(6)["tokens"]))


def test_packed_file_dataset(tmp_path):
    path = str(tmp_path / "tokens.bin")
    PackedFileDataset.write(path, np.arange(1000) % 500)
    ds = PackedFileDataset(path, batch=2, seq_len=32, seed=0)
    batch = ds(0)["tokens"]
    assert batch.shape == (2, 32)
    np.testing.assert_array_equal(np.asarray(ds(0)["tokens"]),
                                  np.asarray(batch))


def test_prefetcher_orders_batches():
    ds = SyntheticTokens(100, 2, 8, seed=1)
    pf = Prefetcher(ds, depth=2).start(0)
    try:
        for s in range(4):
            got = pf.get(s)
            np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                          np.asarray(ds(s)["tokens"]))
    finally:
        pf.stop()


def test_concurrent_serve_scheduler_prioritizes_shared_groups():
    from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                        RequestStream)
    sched = ConcurrentServeScheduler(n_groups=8, batch_budget=4, seed=0)
    s1, s2 = RequestStream(1), RequestStream(2)
    sched.add_stream(s1)
    sched.add_stream(s2)
    # group 3 is hot for both streams (high urgency, many waiting)
    for i in range(3):
        s1.add(Request(1, 3, urgency=5.0, tokens_left=10))
        s2.add(Request(2, 3, urgency=4.0, tokens_left=10))
    s1.add(Request(1, 0, urgency=0.1, tokens_left=10))
    s2.add(Request(2, 1, urgency=0.1, tokens_left=10))
    admitted = sched.schedule_step()
    assert len(admitted) == 4
    # the shared hot group dominates the admitted batch
    assert sum(r.group == 3 for r in admitted) >= 2
    # nothing lost: remaining requests still queued
    assert len(s1.waiting) + len(s2.waiting) == 8 - 4
