"""Minimal, dependency-free stand-in for the `hypothesis` API surface used
by this repo's property tests (given / settings / strategies.integers,
floats, sampled_from).

Registered by tests/conftest.py ONLY when the real hypothesis package is not
installed (the CI image bakes in the jax toolchain but not hypothesis).
Examples are drawn from a fixed-seed RNG so runs are deterministic; on
failure the falsifying example is attached to the raised error.  It is a
shim, not a replacement: no shrinking, no database, no assume().
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import numpy as np

_DEFAULT_MAX_EXAMPLES = 50
_SEED = 0x5EED


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats: _Strategy):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                drawn: Dict[str, Any] = {k: s.draw(rng)
                                         for k, s in strats.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis shim): "
                        f"{fn.__name__}({drawn!r})") from e
        # plain attribute copies, NOT functools.wraps: pytest must see a
        # zero-arg signature, not fn's strategy parameters (it would try to
        # resolve them as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._shim_max_examples = getattr(fn, "_shim_max_examples",
                                             _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco
