"""End-to-end: every schedule mode must reach the same fixpoint as networkx."""

import networkx as nx
import numpy as np
import pytest

from repro.algorithms import PageRank, PersonalizedPageRank, SSSP, BFS, WCC, Katz
from repro.core import ConcurrentEngine, make_run
from repro.graph import rmat_graph, uniform_graph, grid_graph


def _to_nx(csr, weighted=False):
    g = nx.DiGraph()
    g.add_nodes_from(range(csr.n))
    src = np.repeat(np.arange(csr.n), csr.out_degree)
    if weighted:
        g.add_weighted_edges_from(
            zip(src.tolist(), csr.indices.tolist(), csr.weights.tolist()))
    else:
        g.add_edges_from(zip(src.tolist(), csr.indices.tolist()))
    return g


CSR = rmat_graph(300, 5, seed=7)
CSR_W = uniform_graph(250, 5, seed=8, weighted=True, w_max=9.0)
NX = _to_nx(CSR)
NX_W = _to_nx(CSR_W, weighted=True)


@pytest.mark.parametrize("mode", ["two_level", "independent", "all_blocks",
                                  "fused"])
def test_pagerank_matches_networkx(mode):
    algs = [PageRank(damping=0.85), PageRank(damping=0.7)]
    run = make_run(algs, CSR, block_size=32)
    eng = ConcurrentEngine(run, seed=11)
    metrics = getattr(eng, f"run_{mode}")(max_supersteps=20000)
    assert metrics.converged
    res = eng.results()
    for j, d in enumerate([0.85, 0.7]):
        ref = nx.pagerank(NX, alpha=d, tol=1e-12, max_iter=500)
        ref = np.array([ref[i] for i in range(CSR.n)]) * CSR.n
        np.testing.assert_allclose(res[j], ref, rtol=5e-3, atol=1e-4)


@pytest.mark.parametrize("mode", ["two_level", "independent", "all_blocks",
                                  "fused"])
def test_sssp_matches_networkx(mode):
    sources = [0, 17, 101]
    algs = [SSSP(source=s) for s in sources]
    run = make_run(algs, CSR_W, block_size=32)
    eng = ConcurrentEngine(run, seed=3)
    metrics = getattr(eng, f"run_{mode}")(max_supersteps=20000)
    assert metrics.converged
    res = eng.results()
    for j, s in enumerate(sources):
        ref_d = nx.single_source_dijkstra_path_length(NX_W, s)
        ref = np.full(CSR_W.n, np.inf)
        for k, v in ref_d.items():
            ref[k] = v
        finite = np.isfinite(ref)
        np.testing.assert_allclose(res[j][finite], ref[finite], rtol=1e-5)
        assert np.isinf(res[j][~finite]).all()


def test_bfs_hops():
    algs = [BFS(source=0)]
    run = make_run(algs, CSR, block_size=32)
    eng = ConcurrentEngine(run, seed=0)
    assert eng.run_two_level(20000).converged
    res = eng.results()[0]
    ref_d = nx.single_source_shortest_path_length(NX, 0)
    for k, v in ref_d.items():
        assert res[k] == v


def test_wcc_labels():
    csr = uniform_graph(200, 2, seed=9)
    algs = [WCC()]
    run = make_run(algs, csr, block_size=32)
    eng = ConcurrentEngine(run, seed=0)
    assert eng.run_two_level(20000).converged
    res = eng.results()[0]
    comps = list(nx.weakly_connected_components(_to_nx(csr)))
    for comp in comps:
        labels = {res[v] for v in comp}
        assert len(labels) == 1
        assert labels.pop() == min(comp)


def test_katz_matches_networkx():
    csr = grid_graph(12)
    algs = [Katz(alpha=0.05, beta=1.0)]
    run = make_run(algs, csr, block_size=16)
    eng = ConcurrentEngine(run, seed=0)
    assert eng.run_two_level(20000).converged
    res = eng.results()[0]
    ref = nx.katz_centrality(_to_nx(csr).reverse(), alpha=0.05, beta=1.0,
                             max_iter=2000, tol=1e-10, normalized=False)
    ref = np.array([ref[i] for i in range(csr.n)])
    np.testing.assert_allclose(res, ref, rtol=1e-3)


def test_mixed_job_batch_pagerank_ppr():
    """Concurrent heterogeneous jobs sharing one graph view (PR + 3 PPRs)."""
    algs = [PageRank(), PersonalizedPageRank(source=5),
            PersonalizedPageRank(source=50), PersonalizedPageRank(source=120)]
    run = make_run(algs, CSR, block_size=32)
    eng = ConcurrentEngine(run, seed=2)
    m = eng.run_two_level(20000)
    assert m.converged
    res = eng.results()
    ref = nx.pagerank(NX, alpha=0.85, tol=1e-12, max_iter=500)
    ref = np.array([ref[i] for i in range(CSR.n)]) * CSR.n
    np.testing.assert_allclose(res[0], ref, rtol=5e-3, atol=1e-4)
    # PPR mass concentrates near the source
    assert res[1][5] > np.median(res[1])


def test_shared_beats_independent_on_tile_loads():
    """The paper's core claim, as a measurable invariant: CAJS staging is
    <= per-job staging for the same convergence."""
    algs = [PageRank(damping=d) for d in (0.85, 0.8, 0.75, 0.7)]
    run_s = make_run(algs, CSR, block_size=32)
    run_i = make_run(algs, CSR, block_size=32)
    m_s = ConcurrentEngine(run_s, seed=1).run_two_level(20000)
    m_i = ConcurrentEngine(run_i, seed=1).run_independent(20000)
    assert m_s.converged and m_i.converged
    assert m_s.tile_loads < m_i.tile_loads
