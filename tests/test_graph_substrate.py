"""Graph structure + generator tests (networkx as oracle where applicable)."""

import numpy as np
import pytest

from repro.graph import (CSRGraph, build_blocked, rmat_graph, uniform_graph,
                         chain_graph, grid_graph)


def _roundtrip_edges(csr):
    src = np.repeat(np.arange(csr.n), csr.out_degree)
    return set(zip(src.tolist(), csr.indices.tolist()))


def test_csr_from_edges_dedupes_min_weight():
    src = np.array([0, 0, 1, 0], dtype=np.int64)
    dst = np.array([1, 1, 2, 2], dtype=np.int64)
    w = np.array([5.0, 2.0, 1.0, 3.0], dtype=np.float32)
    g = CSRGraph.from_edges(3, src, dst, w)
    assert g.nnz == 3
    # edge (0,1) keeps min weight 2.0
    e01 = g.weights[np.searchsorted(g.indices[g.indptr[0]:g.indptr[1]], 1)]
    assert e01 == 2.0


def test_generators_no_dangling():
    for g in (rmat_graph(500, 4, seed=1), uniform_graph(300, 3, seed=2),
              chain_graph(64), grid_graph(12)):
        assert (g.out_degree >= 1).all()
        assert g.indices.max() < g.n
        assert g.indices.min() >= 0


def test_symmetrize():
    g = chain_graph(10)
    s = g.symmetrized()
    edges = _roundtrip_edges(s)
    for (u, v) in _roundtrip_edges(g):
        assert (v, u) in edges


@pytest.mark.parametrize("n,vb", [(100, 16), (257, 32), (64, 64)])
def test_blocked_reconstruction(n, vb):
    """Dense tiles must reproduce the adjacency matrix exactly."""
    csr = uniform_graph(n, 4, seed=3, weighted=True)
    g = build_blocked(csr, vb, fill=0.0)
    dense = np.zeros((g.n_padded, g.n_padded), dtype=np.float32)
    nbr = np.asarray(g.nbr_ids)
    msk = np.asarray(g.nbr_mask)
    tiles = np.asarray(g.tiles)
    for b in range(g.num_blocks):
        for k in range(g.max_nbr_blocks):
            if msk[b, k]:
                d = nbr[b, k]
                dense[b * vb:(b + 1) * vb, d * vb:(d + 1) * vb] += tiles[b, k]
    ref = np.zeros_like(dense)
    src = np.repeat(np.arange(csr.n), csr.out_degree)
    ref[src, csr.indices] = csr.weights
    np.testing.assert_allclose(dense, ref)


def test_blocked_out_degree_normalize_rows_sum_to_one():
    csr = rmat_graph(200, 6, seed=5)
    g = build_blocked(csr, 32, fill=0.0, normalize="out_degree")
    nbr_sum = np.zeros(g.n_padded, dtype=np.float64)
    tiles = np.asarray(g.tiles, dtype=np.float64)
    msk = np.asarray(g.nbr_mask)
    for b in range(g.num_blocks):
        for k in range(g.max_nbr_blocks):
            if msk[b, k]:
                nbr_sum[b * 32:(b + 1) * 32] += tiles[b, k].sum(axis=1)
    np.testing.assert_allclose(nbr_sum[:csr.n], 1.0, rtol=1e-5)


def test_blocked_min_plus_fill():
    csr = chain_graph(20, weighted=True, w_max=4.0)
    g = build_blocked(csr, 8, fill=float("inf"))
    tiles = np.asarray(g.tiles)
    assert np.isinf(tiles).sum() > 0
    assert (tiles[np.isfinite(tiles)] >= 1.0).all()
