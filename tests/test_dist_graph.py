"""Multi-device concurrent graph engine (repro.dist.graph), run in a
subprocess with 4 host devices:

the job-sharded two-level engine (8 concurrent jobs over a jobs-axis mesh,
tiles replicated, values/deltas job-sharded) must converge to the SAME
per-job results as the single-device engine — bit-for-bit, because
partitioning the vmapped job axis reassigns devices without changing any
per-job arithmetic.  Same for the fused on-device engine, plus the
non-divisible-jobs fallback (J=5 on 4 devices -> replicated, still exact).
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, PersonalizedPageRank
from repro.core import ConcurrentEngine, make_run
from repro.dist.graph import make_job_mesh, shard_run
from repro.graph import rmat_graph

assert len(jax.devices()) == 4
csr = rmat_graph(256, 5, seed=11)
algs = [PageRank(), PageRank(damping=0.7)] + \
       [PersonalizedPageRank(source=13 * i + 2) for i in range(6)]

# single-device reference
ref_eng = ConcurrentEngine(make_run(algs, csr, 16), seed=0)
m_ref = ref_eng.run_two_level(20000)
assert m_ref.converged
ref = ref_eng.results()

# job-sharded two-level: 8 jobs over 4 devices
mesh = make_job_mesh(4)
eng = ConcurrentEngine(make_run(algs, csr, 16), seed=0)
m = eng.run_two_level(20000, mesh=mesh)
assert m.converged
assert m.supersteps == m_ref.supersteps, (m.supersteps, m_ref.supersteps)
np.testing.assert_array_equal(eng.results(), ref)
sh = eng.run.values.sharding
assert sh.spec[0] == "jobs", sh
print("TWO-LEVEL-SHARDED-OK")

# job-sharded fused engine: same fixpoint, on-device loop
ref2 = ConcurrentEngine(make_run(algs, csr, 16), seed=0)
mr2 = ref2.run_fused(20000)
eng2 = ConcurrentEngine(make_run(algs, csr, 16), seed=0)
m2 = eng2.run_fused(20000, mesh=mesh)
assert mr2.converged and m2.converged
np.testing.assert_array_equal(eng2.results(), ref2.results())
print("FUSED-SHARDED-OK")

# non-divisible J falls back to replication, still exact
algs5 = algs[:5]
ref5 = ConcurrentEngine(make_run(algs5, csr, 16), seed=0)
ref5.run_two_level(20000)
eng5 = ConcurrentEngine(make_run(algs5, csr, 16), seed=0)
eng5.run_two_level(20000, mesh=mesh)
np.testing.assert_array_equal(eng5.results(), ref5.results())
print("REMAINDER-OK")
"""


def test_job_sharded_engines_match_single_device_bitwise():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    pythonpath = src + os.pathsep + os.environ.get("PYTHONPATH", "")
    result = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=480,
        env={**os.environ, "PYTHONPATH": pythonpath.rstrip(os.pathsep)})
    for marker in ("TWO-LEVEL-SHARDED-OK", "FUSED-SHARDED-OK",
                   "REMAINDER-OK"):
        assert marker in result.stdout, result.stderr[-2000:]
