"""Analyzer self-tests: one true-positive and one true-negative source
fixture per lint rule (RPA001-RPA007), plus engine mechanics (noqa,
baseline fingerprints, CLI exit codes).

The fixtures are distilled from the real findings this PR fixed — each TP
is the shape of a bug that existed in the tree (or in its git history),
each TN is the idiomatically-correct rewrite the rule must NOT flag."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis.lint import lint_source
from repro.analysis.rules import default_rules

RULES = default_rules()


def run_lint(source: str, path: str = "src/repro/core/policy.py"):
    return lint_source(path, textwrap.dedent(source), RULES)


def rule_ids(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------
# RPA001 tracer-leak
# --------------------------------------------------------------------------

def test_rpa001_true_positive_branch_on_tracer():
    findings = run_lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(values, deltas):
            if values > 0:          # leak: Python branch on a tracer
                return values + deltas
            return values
        """)
    assert "RPA001" in rule_ids(findings)


def test_rpa001_true_positive_coercion_of_tracer():
    findings = run_lint("""
        import jax

        @jax.jit
        def step(x):
            return x * float(x)     # leak: float() of a tracer
        """)
    assert "RPA001" in rule_ids(findings)


def test_rpa001_true_negative_static_branches():
    # the real overlay_push / attn_block shapes: is-None gates, config
    # attrs, shape reads, string-mode switches — all static under trace
    findings = run_lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x, overlay, cfg, kind, cache):
            if overlay is None or overlay.capacity == 0:
                return x
            if cfg.qkv_bias:
                x = x + 1
            if kind in ("attn", "swa"):
                x = x * 2
            if kind == "swa":
                x = x - 1
            if "pos_arr" in cache:
                x = x + cache["pos_arr"]
            if x.shape[0] > 1:
                x = jnp.where(x > 0, x, 0.0)   # lax-level select: fine
            return x
        """)
    assert "RPA001" not in rule_ids(findings)


def test_rpa001_only_fires_inside_jitted_functions():
    findings = run_lint("""
        import numpy as np

        def host_helper(x):
            if x > 0:               # plain host code: no trace, no leak
                return x
            return -x
        """)
    assert "RPA001" not in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA002 loop-host-sync
# --------------------------------------------------------------------------

def test_rpa002_true_positive_per_iteration_materialize():
    # the reseed_min_plus shape this PR fixed: np.asarray(grp.values[j])
    # once per job inside the loop
    findings = run_lint("""
        import numpy as np

        def reseed(grp, n):
            total = 0
            for j in range(4):
                dist = np.asarray(grp.values[j]).reshape(-1)[:n]
                total += int(dist.sum())
            return total
        """)
    assert "RPA002" in rule_ids(findings)


def test_rpa002_true_positive_float_coercion_in_loop():
    findings = run_lint("""
        import jax.numpy as jnp

        def residuals(xs):
            out = []
            for x in xs:
                out.append(float(jnp.max(x)))   # one sync per element
            return out
        """)
    assert "RPA002" in rule_ids(findings)


def test_rpa002_true_negative_hoisted_device_get():
    findings = run_lint("""
        import jax
        import numpy as np

        def reseed(grp, n):
            values_h = np.asarray(jax.device_get(grp.values))
            total = 0
            for j in range(4):
                dist = values_h[j].reshape(-1)[:n]
                total += int(dist.sum())
            return total
        """)
    assert "RPA002" not in rule_ids(findings)


def test_rpa002_true_negative_explicit_device_get_in_loop():
    # an explicit device_get inside the loop is the sanctioned intentional
    # sync (the device driver's once-per-chunk read)
    findings = run_lint("""
        import jax

        def drive(step_fn, state):
            while True:
                state, un = step_fn(state)
                it, un_h = map(int, jax.device_get((state[0], un)))
                if un_h == 0:
                    break
            return it
        """)
    assert "RPA002" not in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA003 select-dtype
# --------------------------------------------------------------------------

def test_rpa003_true_positive_dtypeless_zeros_in_scheduling_module():
    # the serve/concurrent.py shape this PR fixed
    findings = run_lint("""
        import numpy as np

        def pairs(n_groups, waiting):
            n_un = np.zeros(n_groups)       # f64 drift across the boundary
            for r in waiting:
                n_un[r.group] += 1
            return n_un
        """, path="src/repro/serve/concurrent.py")
    assert "RPA003" in rule_ids(findings)


def test_rpa003_true_negative_explicit_dtype():
    findings = run_lint("""
        import numpy as np

        def pairs(n_groups, waiting):
            n_un = np.zeros(n_groups, dtype=np.float32)
            sel = np.zeros(4, dtype=np.int32)
            return n_un, sel
        """, path="src/repro/serve/concurrent.py")
    assert "RPA003" not in rule_ids(findings)


def test_rpa003_scoped_to_selection_modules():
    # the same dtype-less zeros OUTSIDE a scheduling module is not the
    # selection contract's business
    findings = run_lint("""
        import numpy as np

        def helper(n):
            return np.zeros(n)
        """, path="src/repro/graph/generators.py")
    assert "RPA003" not in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA004 nondeterminism
# --------------------------------------------------------------------------

def test_rpa004_true_positive_wall_clock_and_global_rng():
    findings = run_lint("""
        import time
        import numpy as np

        def schedule(jobs):
            np.random.seed(int(time.time()))
            return np.random.permutation(len(jobs))
        """)
    assert "RPA004" in rule_ids(findings)


def test_rpa004_true_negative_threaded_seed_and_perf_counter():
    findings = run_lint("""
        import time
        import numpy as np

        def schedule(jobs, seed):
            rng = np.random.default_rng(seed)
            t0 = time.perf_counter()
            order = rng.permutation(len(jobs))
            return order, time.perf_counter() - t0
        """)
    assert "RPA004" not in rule_ids(findings)


def test_rpa004_unseeded_default_rng_flagged():
    findings = run_lint("""
        import numpy as np

        def schedule(jobs):
            rng = np.random.default_rng()   # OS entropy
            return rng.permutation(len(jobs))
        """)
    assert "RPA004" in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA005 jit-cache-key
# --------------------------------------------------------------------------

def test_rpa005_true_positive_inline_jit_call():
    # the Con_processing / serve-engine shape this PR fixed
    findings = run_lint("""
        import jax

        def push_all(fn, values, deltas):
            return jax.jit(jax.vmap(fn))(values, deltas)
        """)
    assert "RPA005" in rule_ids(findings)


def test_rpa005_true_positive_lambda_jit_per_call():
    findings = run_lint("""
        import jax
        import jax.numpy as jnp

        def counts(groups):
            out = []
            for g in groups:
                f = jax.jit(lambda v: jnp.sum(v > 0))
                out.append(f(g.values))
            return out
        """)
    assert "RPA005" in rule_ids(findings)


def test_rpa005_true_negative_guarded_cache_and_factory():
    # the session's _jit_cache pattern AND build_device_step's
    # return-a-jitted-callable factory must both stay clean
    findings = run_lint("""
        import jax
        import jax.numpy as jnp

        _cache = {}

        def counts_fn(key, alg):
            if key not in _cache:
                _cache[key] = jax.jit(
                    lambda v, d: jnp.sum(alg.unconverged(v, d)))
            return _cache[key]

        def build_step(policy, sess):
            def step_fn(state):
                return state
            return jax.jit(step_fn)
        """)
    assert "RPA005" not in rule_ids(findings)


def test_rpa005_unhashable_cache_key_component():
    findings = run_lint("""
        def make_key(grp, caps):
            key = ("superstep", [g for g in caps], grp.key)
            return key
        """)
    assert "RPA005" in rule_ids(findings)


def test_rpa005_hashable_cache_key_clean():
    findings = run_lint("""
        def make_key(grp, caps):
            key = ("superstep", tuple(caps), grp.key)
            return key
        """)
    assert "RPA005" not in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA006 f64-promotion
# --------------------------------------------------------------------------

def test_rpa006_true_positive_64bit_device_dtype():
    findings = run_lint("""
        import jax.numpy as jnp
        import numpy as np

        def state(n):
            return jnp.zeros(n, dtype=np.float64)
        """)
    assert "RPA006" in rule_ids(findings)


def test_rpa006_true_positive_x64_flip():
    findings = run_lint("""
        import jax

        def enable():
            jax.config.update("jax_enable_x64", True)
        """)
    assert "RPA006" in rule_ids(findings)


def test_rpa006_true_negative_f32_and_host_i64():
    findings = run_lint("""
        import jax.numpy as jnp
        import numpy as np

        def state(n):
            dev = jnp.zeros(n, dtype=jnp.float32)
            host = np.zeros(n, dtype=np.int64)   # host-side i64 is fine
            return dev, host
        """)
    assert "RPA006" not in rule_ids(findings)


# --------------------------------------------------------------------------
# RPA007 set-iteration
# --------------------------------------------------------------------------

def test_rpa007_true_positive_set_iteration():
    # the _affected_reachable shape this PR fixed
    findings = run_lint("""
        def seeds_to_stack(seeds):
            return [s for s in set(seeds)]
        """)
    assert "RPA007" in rule_ids(findings)


def test_rpa007_true_positive_for_over_set_union():
    findings = run_lint("""
        def visit(a, b):
            out = []
            for x in a | set(b):
                out.append(x)
            return out
        """)
    assert "RPA007" in rule_ids(findings)


def test_rpa007_true_negative_sorted_set():
    findings = run_lint("""
        def seeds_to_stack(seeds):
            return sorted(set(seeds))
        """)
    assert "RPA007" not in rule_ids(findings)


# --------------------------------------------------------------------------
# engine mechanics
# --------------------------------------------------------------------------

def test_every_rule_has_a_true_positive_fixture():
    """Acceptance: >= 6 distinct rules, each demonstrated by a TP above.
    This meta-test keeps the fixture set honest if rules are added."""
    demonstrated = set()
    tp_sources = {
        "RPA001": "import jax\n@jax.jit\ndef f(x):\n    if x > 0:\n"
                  "        return x\n    return -x\n",
        "RPA002": "import jax.numpy as jnp\ndef f(xs):\n"
                  "    return [float(jnp.max(x)) for x in xs]\n",
        "RPA003": "import numpy as np\ndef f(n):\n    return np.zeros(n)\n",
        "RPA004": "import time\ndef f():\n    return time.time()\n",
        "RPA005": "import jax\ndef f(g, x):\n    return jax.jit(g)(x)\n",
        "RPA006": "import jax.numpy as jnp\ndef f(n):\n"
                  "    return jnp.zeros(n, dtype='float64')\n",
        "RPA007": "def f(s):\n    return [x for x in set(s)]\n",
    }
    for rid, src in tp_sources.items():
        found = rule_ids(lint_source("src/repro/core/policy.py", src,
                                     RULES))
        assert rid in found, f"{rid} TP fixture no longer fires"
        demonstrated.add(rid)
    assert len(demonstrated) >= 6


def test_noqa_suppresses_single_rule():
    src = ("import time\n"
           "def f():\n"
           "    return time.time()  # noqa: RPA004\n")
    assert lint_source("src/repro/x.py", src, RULES) == []
    src_other = ("import time\n"
                 "def f():\n"
                 "    return time.time()  # noqa: RPA001\n")
    assert rule_ids(lint_source("src/repro/x.py", src_other,
                                RULES)) == {"RPA004"}


def test_syntax_error_reported_not_raised():
    findings = lint_source("src/repro/broken.py", "def f(:\n", RULES)
    assert [f.rule for f in findings] == ["RPA999"]


def test_baseline_fingerprints_stable_under_line_moves():
    src = "import time\ndef f():\n    return time.time()\n"
    moved = "import time\n\n\ndef f():\n    return time.time()\n"
    f1 = lint_source("src/repro/x.py", src, RULES)
    f2 = lint_source("src/repro/x.py", moved, RULES)
    fp1 = [fp for _, fp in baseline_mod.fingerprints(f1)]
    fp2 = [fp for _, fp in baseline_mod.fingerprints(f2)]
    assert fp1 == fp2 and len(fp1) == 1


def test_baseline_roundtrip_filters(tmp_path: Path):
    src = ("import time\ndef f():\n"
           "    a = time.time()\n    b = time.time()\n    return a + b\n")
    findings = lint_source("src/repro/x.py", src, RULES)
    assert len(findings) == 2
    bl = tmp_path / "baseline.json"
    n = baseline_mod.write(str(bl), findings)
    assert n == 2
    accepted = baseline_mod.load(str(bl))
    assert baseline_mod.filter_findings(findings, accepted) == []
    # identical lines get distinct occurrence indices
    assert len(accepted) == 2


def _run_cli(args, cwd=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd)


def test_cli_clean_file_exits_zero(tmp_path: Path):
    f = tmp_path / "clean.py"
    f.write_text("import numpy as np\n\n\ndef f(n):\n"
                 "    return np.arange(n, dtype=np.int32)\n")
    r = _run_cli([str(f)])
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_finding_exits_one_and_reports_json(tmp_path: Path):
    f = tmp_path / "dirty.py"
    f.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    report = tmp_path / "report.json"
    r = _run_cli([str(f), "--json", str(report)])
    assert r.returncode == 1
    data = json.loads(report.read_text())
    assert data["counts"]["RPA004"] == 1
    assert data["findings"][0]["rule"] == "RPA004"
    assert {r_["id"] for r_ in data["rules"]} >= {
        "RPA001", "RPA002", "RPA003", "RPA004", "RPA005", "RPA006",
        "RPA007"}


def test_cli_baseline_suppresses(tmp_path: Path):
    f = tmp_path / "dirty.py"
    f.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    bl = tmp_path / "bl.json"
    r = _run_cli([str(f), "--write-baseline", str(bl)])
    assert r.returncode == 0
    r = _run_cli([str(f), "--baseline", str(bl)])
    assert r.returncode == 0, r.stdout + r.stderr


def test_repo_src_tree_is_clean():
    """The CI gate's promise: the shipped tree lints clean with an EMPTY
    baseline (acceptance criterion for this PR)."""
    repo = Path(__file__).resolve().parent.parent
    from repro.analysis.lint import lint_paths
    findings = lint_paths([str(repo / "src")], RULES)
    assert findings == [], "\n".join(f.format() for f in findings)
