"""Test bootstrap: src/ on sys.path (belt-and-braces next to the pyproject
pythonpath setting, so bare `pytest tests/...` works from any cwd) and a
deterministic hypothesis shim when the real package is absent."""

import importlib.util
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

if importlib.util.find_spec("hypothesis") is None:
    _shim_path = os.path.join(os.path.dirname(__file__),
                              "_hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
