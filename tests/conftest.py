"""Test bootstrap: src/ on sys.path (belt-and-braces next to the pyproject
pythonpath setting, so bare `pytest tests/...` works from any cwd) and a
deterministic hypothesis shim when the real package is absent."""

import importlib.util
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

if importlib.util.find_spec("hypothesis") is None:
    _shim_path = os.path.join(os.path.dirname(__file__),
                              "_hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


# ---------------------------------------------------------------------------
# runtime sentinels (repro.analysis.sentinels) as fixtures
# ---------------------------------------------------------------------------

import pytest  # noqa: E402  (after the path bootstrap, deliberately)


@pytest.fixture
def transfer_sentinel():
    """Run the test body under jax.transfer_guard_device_to_host
    ("disallow"): every device->host movement must be an explicit
    jax.device_get — any implicit coercion (float(), .item(),
    copy-forcing np.asarray) fails the test."""
    from repro.analysis.sentinels import no_implicit_transfers
    with no_implicit_transfers():
        yield


@pytest.fixture
def retrace_pin():
    """Factory fixture: `with retrace_pin(sess): ...` fails the test if
    the session's jit cache gains unexpected keys or an already-compiled
    entry re-traces inside the block."""
    from repro.analysis.sentinels import retrace_sentinel
    return retrace_sentinel
