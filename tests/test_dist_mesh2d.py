"""2D (jobs x blocks) mesh: block-sharded graph state (repro.dist.mesh2d),
run in subprocesses with 4 host devices.

The tentpole contract: partitioning the BlockPairs tile stream across a
`blocks` mesh axis — each shard owning its destination rows of every job's
state and exchanging only compressed frontier deltas — must change WHERE
the arithmetic runs, never what it computes.  Min-plus fixpoints are
bit-identical to the single-device engine (idempotent semiring, same
superstep count); plus-times matches to tolerance.  On top of that:

1. a graph whose full tile set exceeds a simulated single-device memory
   cap runs to the correct fixpoint once 4-way block-sharded, with
   cross-shard traffic (RunMetrics.halo_bytes) bounded by the staged
   frontier, not the tile bytes;
2. the full policy grid (TwoLevel / Independent / AllBlocks / Fused, host
   and device drivers) agrees on a (2 x 2) jobs-x-blocks mesh;
3. streaming: overlay updates + compact() on a 2D mesh equal a fresh
   session on the mutated graph (same invariant test_stream_properties
   pins single-device);
4. shard loss: checkpoint_session -> restore_session onto a SMALLER mesh
   resumes the scheduler stream and still reaches the bitwise min-plus
   fixpoint in the same total superstep count (elastic reshard);
5. non-divisible extents fall back to replication with a one-time
   MeshLayoutWarning naming the chosen layout;
6. entering / leaving / re-entering a mesh re-uses the per-key jit cache
   entries (retrace_sentinel: one entry per (policy, mesh-signature) key,
   pinned — the cache-key promise in GraphSession._device_step_fn).

quantize_ef (dist.compression) is also unit-tested here in-process on
frontier-delta-shaped inputs: signed values, zero runs, per-row scales,
and the error-feedback telescope the halo exchange relies on.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

CORE_SCRIPT = r"""
import os, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, SSSP
from repro.analysis.sentinels import retrace_sentinel
from repro.core import Fused, GraphSession, TwoLevel
from repro.dist.graph import shard_session, unshard_session
from repro.dist.mesh2d import (MeshLayoutWarning, make_mesh2d,
                               reset_layout_warnings)
from repro.graph import rmat_graph

assert len(jax.devices()) == 4
csr = rmat_graph(128, 4, seed=7)
BLOCK = 16


def build(**kw):
    sess = GraphSession(csr, BLOCK, capacity=2, seed=0, **kw)
    hs = [sess.submit(PageRank()), sess.submit(PageRank(damping=0.7)),
          sess.submit(SSSP(source=3)), sess.submit(SSSP(source=17))]
    return sess, hs


# single-device reference fixpoint
ref, href = build()
mref = ref.run(TwoLevel(), 20000)
assert mref.converged
res = [ref.result(h) for h in href]

# --- 1. past a simulated single-device memory cap, 4-way block shards ----
mesh = make_mesh2d(1, 4)
s1, h1 = build()
m1 = s1.run(Fused(), 20000, mesh=mesh)
assert m1.converged
groups = s1.view_groups()
total_tile_bytes = sum(
    int(np.prod(s1._pair_data(g).tiles.shape)) * 4 for g in groups)
per_shard_tile_bytes = sum(
    int(np.prod(s1._pair_shards(g).tiles.shape[1:])) * 4 for g in groups)
CAP = total_tile_bytes // 2          # simulated device memory budget
assert per_shard_tile_bytes <= CAP < total_tile_bytes, (
    per_shard_tile_bytes, CAP, total_tile_bytes)
r1 = [s1.result(h) for h in h1]
np.testing.assert_array_equal(r1[2], res[2])       # min-plus: bitwise
np.testing.assert_array_equal(r1[3], res[3])
np.testing.assert_allclose(r1[0], res[0], rtol=1e-3, atol=1e-4)
np.testing.assert_allclose(r1[1], res[1], rtol=1e-3, atol=1e-4)
print("CAP-OK")

# --- halo traffic scales with the staged frontier, not the tile set -----
assert m1.halo_bytes > 0
bn = s1.view_groups()[0].graph.num_blocks
frontier_bound = m1.supersteps * (
    sum(g.capacity * s1.q * BLOCK * 4 for g in groups) + 8 * bn)
assert m1.halo_bytes <= frontier_bound, (m1.halo_bytes, frontier_bound)
# shipping whole tiles every superstep would cost this much:
assert m1.halo_bytes < total_tile_bytes * m1.supersteps
print("HALO-OK")

# --- 5. non-divisible extents: replicated fallback, one-time warning ----
csr6 = rmat_graph(96, 3, seed=5)        # B_N = 6, not divisible by 4

def build6():
    s = GraphSession(csr6, BLOCK, capacity=2, seed=0)
    h = s.submit(SSSP(source=1))
    return s, h

ref6, rh6 = build6()
ref6.run(TwoLevel(), 20000)
reset_layout_warnings()
s6, h6 = build6()
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    m6 = s6.run(TwoLevel(), 20000, mesh=mesh)
lw = [x for x in w if issubclass(x.category, MeshLayoutWarning)]
assert len(lw) == 1, [str(x.message) for x in lw]
assert "blocks-replicated" in str(lw[0].message), str(lw[0].message)
assert m6.converged
np.testing.assert_array_equal(s6.result(h6), ref6.result(rh6))
# same fallback layout again -> already warned, stays silent
s6b, h6b = build6()
with warnings.catch_warnings(record=True) as w2:
    warnings.simplefilter("always")
    s6b.run(TwoLevel(), 20000, mesh=mesh)
assert not [x for x in w2 if issubclass(x.category, MeshLayoutWarning)], \
    [str(x.message) for x in w2]
# ... until the registry is reset
reset_layout_warnings()
s6c, _ = build6()
with warnings.catch_warnings(record=True) as w3:
    warnings.simplefilter("always")
    s6c.run(TwoLevel(), 20000, mesh=mesh)
assert [x for x in w3 if issubclass(x.category, MeshLayoutWarning)]
# jobs-axis fallback too: capacity 2 does not divide 4 jobs shards
reset_layout_warnings()
s7, h7 = build6()
with warnings.catch_warnings(record=True) as w4:
    warnings.simplefilter("always")
    m7 = s7.run(TwoLevel(), 20000, mesh=make_mesh2d(4, 1))
msgs = [str(x.message) for x in w4
        if issubclass(x.category, MeshLayoutWarning)]
assert any("jobs-replicated" in m for m in msgs), msgs
assert m7.converged
np.testing.assert_array_equal(s7.result(h7), ref6.result(rh6))
print("WARN-OK")

# --- 6. mesh re-specialization keeps one jit entry per key --------------
s8, h8 = build()
pol = Fused()
s8.run(pol, 20000)                       # pins the single-device entry
with retrace_sentinel(s8, allow_new=("superstep",)):
    s8.run(pol, 20000, mesh=mesh)        # first 2D compile: one new key
with retrace_sentinel(s8):               # NO growth allowed from here on
    unshard_session(s8)
    s8.run(pol, 20000)                   # back on the 1D entry
    shard_session(mesh, s8, axes=("jobs", "blocks"))
    s8.run(pol, 20000)                   # back on the 2D entry
steps = [k for k in s8._jit_cache if k[0] == "superstep"]
assert len(steps) == 2, steps
r8 = [s8.result(h) for h in h8]
np.testing.assert_array_equal(r8[2], res[2])
print("RETRACE-OK")
"""


GRID_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, SSSP
from repro.core import AllBlocks, Fused, GraphSession, Independent, TwoLevel
from repro.dist.mesh2d import make_mesh2d
from repro.graph import rmat_graph

csr = rmat_graph(128, 4, seed=7)
BLOCK = 16
mesh = make_mesh2d(2, 2)


def build():
    sess = GraphSession(csr, BLOCK, capacity=2, seed=0)
    hs = [sess.submit(PageRank()), sess.submit(PageRank(damping=0.7)),
          sess.submit(SSSP(source=3)), sess.submit(SSSP(source=17))]
    return sess, hs


ref, href = build()
assert ref.run(TwoLevel(), 20000).converged
res = [ref.result(h) for h in href]

GRID = [
    ("host/two_level", TwoLevel()),
    ("host/independent", Independent()),
    ("host/all_blocks", AllBlocks()),
    ("device/two_level", TwoLevel(backend="device", steps_per_sync=2)),
    ("device/independent", Independent(backend="device", steps_per_sync=1)),
    ("device/all_blocks", AllBlocks(backend="device", steps_per_sync=2)),
    ("device/fused", Fused()),
]
for name, pol in GRID:
    s, hs = build()
    m = s.run(pol, 20000, mesh=mesh)
    assert m.converged, (name, m)
    r = [s.result(h) for h in hs]
    np.testing.assert_array_equal(r[2], res[2], err_msg=name)
    np.testing.assert_array_equal(r[3], res[3], err_msg=name)
    np.testing.assert_allclose(r[0], res[0], rtol=1e-3, atol=1e-4,
                               err_msg=name)
    np.testing.assert_allclose(r[1], res[1], rtol=1e-3, atol=1e-4,
                               err_msg=name)
    print(name, "ok", m.supersteps)

# compressed halo: min-plus stays bitwise (never quantized), plus-times
# within EF tolerance, payload strictly smaller than the f32 halo
from repro.dist.graph import shard_session
sc, hc = build()
shard_session(mesh, sc, axes=("jobs", "blocks"), compress_halo=True)
mc = sc.run(Fused(), 20000)
assert mc.converged
rc = [sc.result(h) for h in hc]
np.testing.assert_array_equal(rc[2], res[2])
np.testing.assert_array_equal(rc[3], res[3])
np.testing.assert_allclose(rc[0], res[0], rtol=5e-3, atol=5e-4)
su, hu = build()
mu = su.run(Fused(), 20000, mesh=mesh)
assert 0 < mc.halo_bytes < mu.halo_bytes, (mc.halo_bytes, mu.halo_bytes)
print("GRID-OK")
"""


STREAM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import Katz, PageRank, SSSP
from repro.core import Fused, GraphSession, TwoLevel
from repro.dist.mesh2d import make_mesh2d
from repro.graph import mutation_stream, rmat_graph
from repro.stream import apply_to_csr

csr = rmat_graph(96, 3, seed=3)
BLOCK = 16
mesh = make_mesh2d(2, 2)
batches = mutation_stream(csr, 2, inserts_per_batch=4, deletes_per_batch=2,
                          seed=9, weighted=False, w_max=4.0)

for policy, tag in [(TwoLevel(), "host"), (Fused(), "device")]:
    sess = GraphSession(csr, BLOCK, capacity=2, seed=11, overlay_capacity=2)
    algs = [PageRank(), SSSP(source=5), Katz(alpha=0.02)]
    hs = [sess.submit(a) for a in algs]
    sess.run(policy, max_supersteps=6, mesh=mesh)
    csr_k = csr
    for b in batches:
        sess.apply_updates(b)
        sess.run(policy, max_supersteps=4)
        csr_k = apply_to_csr(csr_k, b)
    sess.compact()
    assert sess.run(policy, 50000).converged

    fresh = GraphSession(csr_k, BLOCK, capacity=2, seed=11)
    fh = [fresh.submit(a) for a in algs]
    assert fresh.run(TwoLevel(), 50000).converged
    for g_s, g_f in zip(sess.view_groups(), fresh.view_groups()):
        assert g_s.overlay.capacity == 0        # compact() folded it in
        np.testing.assert_array_equal(np.asarray(g_s.graph.tiles),
                                      np.asarray(g_f.graph.tiles))
    for a, h, f in zip(algs, hs, fh):
        if a.semiring == "min_plus":
            np.testing.assert_array_equal(sess.result(h), fresh.result(f))
        else:
            np.testing.assert_allclose(sess.result(h), fresh.result(f),
                                       rtol=1e-3, atol=1e-4)
    print("STREAM-" + tag.upper() + "-OK")
"""


FAULT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, SSSP
from repro.core import GraphSession, TwoLevel
from repro.dist.fault import checkpoint_session, restore_session
from repro.dist.mesh2d import make_mesh2d
from repro.graph import rmat_graph

csr = rmat_graph(128, 4, seed=13)
BLOCK = 16


def build():
    s = GraphSession(csr, BLOCK, capacity=2, seed=2)
    hs = [s.submit(SSSP(source=3)), s.submit(SSSP(source=40)),
          s.submit(PageRank())]
    return s, hs


ref, href = build()
mref = ref.run(TwoLevel(), 20000)
assert mref.converged
res = [ref.result(h) for h in href]

# run 5 supersteps on a 4-shard mesh, checkpoint, then "lose" two shards
s, hs = build()
m_pre = s.run(TwoLevel(), 5, mesh=make_mesh2d(1, 4))
assert not m_pre.converged and m_pre.supersteps == 5
snap = checkpoint_session(s)

# survivor topology: a fresh session (same submissions + seed) on 1x2
s2, hs2 = build()
restore_session(s2, snap, mesh=make_mesh2d(1, 2))
m_post = s2.run(TwoLevel(), 20000)
assert m_post.converged
# the resumed scheduler stream continues where the snapshot stopped:
# identical remaining supersteps, bitwise min-plus fixpoint
assert m_pre.supersteps + m_post.supersteps == mref.supersteps, (
    m_pre.supersteps, m_post.supersteps, mref.supersteps)
np.testing.assert_array_equal(s2.result(hs2[0]), res[0])
np.testing.assert_array_equal(s2.result(hs2[1]), res[1])
np.testing.assert_allclose(s2.result(hs2[2]), res[2], rtol=1e-3,
                           atol=1e-4)
print("FAULT-OK")
"""


def _run(script, markers):
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    pythonpath = src + os.pathsep + os.environ.get("PYTHONPATH", "")
    result = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=480,
        env={**os.environ, "PYTHONPATH": pythonpath.rstrip(os.pathsep)})
    for marker in markers:
        assert marker in result.stdout, result.stderr[-2000:]


def test_block_sharded_fixpoint_past_memory_cap():
    _run(CORE_SCRIPT, ("CAP-OK", "HALO-OK", "WARN-OK", "RETRACE-OK"))


def test_elastic_reshard_resumes_bitwise():
    _run(FAULT_SCRIPT, ("FAULT-OK",))


@pytest.mark.slow
def test_mesh2d_policy_grid_and_compressed_halo():
    _run(GRID_SCRIPT, ("GRID-OK",))


@pytest.mark.slow
def test_mesh2d_streaming_compact_matches_fresh():
    _run(STREAM_SCRIPT, ("STREAM-HOST-OK", "STREAM-DEVICE-OK"))


# ---------------------------------------------------------------------------
# quantize_ef: the int8 error-feedback primitive under the halo exchange
# ---------------------------------------------------------------------------


def _frontier_deltas(seed=0, j=3, b=4, vb=16, density=0.25):
    """Signed, mostly-zero [J, B, Vb] rows — the shape and sparsity of a
    staged frontier-delta payload."""
    rng = np.random.default_rng(seed)
    t = rng.normal(scale=0.1, size=(j, b, vb)).astype(np.float32)
    t *= rng.random((j, b, vb)) < density
    t[:, 1, :] = 0.0                       # a whole zero run (unselected)
    return t


def test_quantize_ef_roundtrip_and_zero_rows():
    from repro.dist.compression import quantize_ef
    t = _frontier_deltas(seed=1)
    deq, err = map(np.asarray, quantize_ef(t, bits=8, axis=-1))
    # dequantized + residual reconstructs the input (EF invariant)
    np.testing.assert_allclose(deq + err, t, rtol=0, atol=1e-6)
    # zero rows stay EXACTLY zero — no quantization noise invents work
    assert not deq[:, 1, :].any() and not err[:, 1, :].any()
    zero_rows = ~t.any(axis=-1)
    assert not deq[zero_rows].any() and not err[zero_rows].any()
    # signs survive
    nz = t != 0
    assert (np.sign(deq[nz & (deq != 0)])
            == np.sign(t[nz & (deq != 0)])).all()
    # per-row error bound: |err| <= scale/2 ~ amax / (2 * 127)
    amax = np.abs(t).max(axis=-1, keepdims=True)
    assert (np.abs(err) <= amax / 127 + 1e-12).all()


def test_quantize_ef_per_row_scales_are_independent():
    from repro.dist.compression import quantize_ef
    t = np.zeros((2, 2, 16), np.float32)
    t[0, 0, :4] = [1e3, -2e3, 5e2, 1.5e3]          # loud row
    t[1, 1, :4] = [1e-3, -2e-3, 5e-4, 1.5e-3]      # quiet row
    deq, err = map(np.asarray, quantize_ef(t, bits=8, axis=-1))
    # the loud row's amax must not widen the quiet row's grid
    assert np.abs(err[1, 1]).max() <= 2e-3 / 127 + 1e-12
    assert np.abs(err[0, 0]).max() <= 2e3 / 127 + 1e-9


def test_quantize_ef_error_feedback_telescopes():
    """Carried residuals drain: over a stream of deltas, the sum of what
    was SENT (dequantized) differs from the sum of what was PRODUCED by
    exactly the final residual — quantization error never accumulates."""
    from repro.dist.compression import quantize_ef
    err = np.zeros((3, 4, 16), np.float32)
    sent = np.zeros_like(err)
    produced = np.zeros_like(err)
    for k in range(12):
        t = _frontier_deltas(seed=100 + k)
        deq, err = map(np.asarray, quantize_ef(t + err, bits=8, axis=-1))
        sent += deq
        produced += t
    np.testing.assert_allclose(produced - sent, err, rtol=0, atol=1e-4)
    # and the residual itself is one quantization step, not 12
    assert np.abs(err).max() < 0.05
