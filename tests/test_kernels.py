"""Pallas kernel tests: shape/dtype sweeps + property tests vs ref oracles
(interpret=True executes the kernel bodies on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.mj_spmm.ops import mj_spmm, push_shared
from repro.kernels.mj_spmm.ref import mj_spmm_ref
from repro.kernels.priority_pairs.ops import priority_pairs
from repro.kernels.priority_pairs.ref import priority_pairs_ref


SHAPES = [  # (q, K, J, Vb)
    (1, 1, 1, 8),
    (2, 3, 4, 16),
    (4, 2, 8, 32),
    (3, 5, 2, 64),
    (2, 2, 6, 128),
]


@pytest.mark.parametrize("q,k,j,vb", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_mj_spmm_matches_ref(q, k, j, vb, dtype, semiring):
    rng = np.random.default_rng(q * 1000 + k * 100 + j * 10 + vb)
    d = rng.standard_normal((q, j, vb)).astype(np.float32)
    t = rng.standard_normal((q, k, vb, vb)).astype(np.float32)
    if semiring == "min_plus":
        # sparse tiles: most entries +inf (absent edges)
        mask = rng.random((q, k, vb, vb)) < 0.9
        t = np.where(mask, np.inf, np.abs(t))
        d = np.abs(d)
        d[rng.random(d.shape) < 0.5] = np.inf  # non-pending vertices
    d = jnp.asarray(d, dtype).astype(jnp.float32)
    t = jnp.asarray(t, dtype).astype(jnp.float32)
    out = mj_spmm(d, t, semiring, interpret=True)
    ref = mj_spmm_ref(d, t, semiring)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(
    q=st.integers(1, 3), k=st.integers(1, 3), j=st.integers(1, 6),
    vb=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_mj_spmm_plus_property(q, k, j, vb, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal((q, j, vb)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((q, k, vb, vb)), jnp.float32)
    out = mj_spmm(d, t, "plus_times", interpret=True)
    ref = mj_spmm_ref(d, t, "plus_times")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # linearity: kernel(2d) == 2*kernel(d)
    out2 = mj_spmm(2.0 * d, t, "plus_times", interpret=True)
    np.testing.assert_allclose(np.asarray(out2), 2 * np.asarray(out),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("j,bn,vb", [(1, 1, 8), (3, 7, 16), (8, 4, 64),
                                     (2, 16, 128)])
def test_priority_pairs_matches_ref(j, bn, vb):
    rng = np.random.default_rng(j * 100 + bn * 10 + vb)
    p = np.abs(rng.standard_normal((j, bn, vb))).astype(np.float32)
    p[rng.random(p.shape) < 0.5] = 0.0  # converged vertices
    p = jnp.asarray(p)
    n_k, m_k = priority_pairs(p, interpret=True)
    n_r, m_r = priority_pairs_ref(p)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               rtol=1e-6, atol=1e-7)


def test_priority_pairs_all_converged_block():
    p = jnp.zeros((2, 3, 16), jnp.float32)
    n, m = priority_pairs(p, interpret=True)
    assert (np.asarray(n) == 0).all()
    assert (np.asarray(m) == 0).all()


def test_push_shared_kernel_matches_engine_push():
    """Kernel-backed push == jnp engine push, both semirings."""
    from repro.core.engine import push_plus_one, push_min_one
    rng = np.random.default_rng(0)
    J, BN, VB, K, Q = 3, 6, 16, 2, 3
    tiles_p = jnp.asarray(
        np.where(rng.random((BN, K, VB, VB)) < 0.8, 0.0,
                 rng.random((BN, K, VB, VB))), jnp.float32)
    tiles_m = jnp.where(tiles_p == 0.0, jnp.inf, tiles_p)
    nbr = jnp.asarray(rng.integers(0, BN, (BN, K)), jnp.int32)
    sel = jnp.asarray([0, 2, 5], jnp.int32)
    msk = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
    scale = jnp.asarray(rng.random(J), jnp.float32)

    vals = jnp.asarray(rng.random((J, BN, VB)), jnp.float32)
    dels = jnp.asarray(rng.random((J, BN, VB)), jnp.float32)
    v1, d1 = jax.vmap(push_plus_one,
                      in_axes=(0, 0, None, None, None, None, 0))(
        vals, dels, tiles_p, nbr, sel, msk, scale)
    v2, d2 = push_shared(vals, dels, tiles_p, nbr, sel, msk, scale,
                         semiring="plus_times", interpret=True)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5,
                               atol=1e-6)

    dist = jnp.asarray(rng.random((J, BN, VB)) * 10, jnp.float32)
    pend = jnp.where(jnp.asarray(rng.random((J, BN, VB))) < 0.5, dist, jnp.inf)
    v1, d1 = jax.vmap(push_min_one,
                      in_axes=(0, 0, None, None, None, None, 0))(
        dist, pend, tiles_m, nbr, sel, msk, scale)
    v2, d2 = push_shared(dist, pend, tiles_m, nbr, sel, msk, scale,
                         semiring="min_plus", interpret=True)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2))


@pytest.mark.slow
def test_engine_with_pallas_end_to_end():
    """ConcurrentEngine(use_pallas=True) reaches the same PageRank fixpoint."""
    import networkx as nx
    from repro.algorithms import PageRank
    from repro.core import ConcurrentEngine, make_run
    from repro.graph import rmat_graph

    csr = rmat_graph(150, 4, seed=13)
    run = make_run([PageRank(), PageRank(damping=0.6)], csr, block_size=16)
    eng = ConcurrentEngine(run, seed=5, use_pallas=True)
    m = eng.run_two_level(20000)
    assert m.converged
    res = eng.results()
    g = nx.DiGraph()
    g.add_nodes_from(range(csr.n))
    src = np.repeat(np.arange(csr.n), csr.out_degree)
    g.add_edges_from(zip(src.tolist(), csr.indices.tolist()))
    for jidx, damp in enumerate([0.85, 0.6]):
        ref = nx.pagerank(g, alpha=damp, tol=1e-12, max_iter=500)
        ref = np.array([ref[i] for i in range(csr.n)]) * csr.n
        np.testing.assert_allclose(res[jidx], ref, rtol=5e-3, atol=1e-4)


@pytest.mark.slow
def test_session_with_pallas_min_plus_end_to_end():
    """GraphSession(use_pallas=True) on the MIN_PLUS path (SSSP + BFS, two
    views): result() is BIT-EQUAL to the jnp push — the min-plus fixpoint
    is schedule-invariant and min is exact in any evaluation order, so the
    kernel route may not perturb a single distance."""
    from repro.algorithms import BFS, SSSP
    from repro.core import GraphSession, TwoLevel
    from repro.graph import uniform_graph

    csr = uniform_graph(150, 4, seed=21, weighted=True, w_max=7.0)
    algs = [SSSP(source=0), SSSP(source=33), BFS(source=7)]
    results = {}
    for use_pallas in (False, True):
        sess = GraphSession(csr, 16, capacity=4, seed=3,
                            use_pallas=use_pallas)
        handles = [sess.submit(a) for a in algs]
        assert sess.run(TwoLevel(), 20000).converged
        results[use_pallas] = [sess.result(h) for h in handles]
    for jnp_res, pallas_res in zip(results[False], results[True]):
        np.testing.assert_array_equal(pallas_res, jnp_res)


@pytest.mark.slow
def test_session_with_pallas_heterogeneous_end_to_end():
    """A heterogeneous session under use_pallas=True: ONE selection per
    superstep drives the kernel-backed plus-times push AND the kernel-backed
    min-plus push.  The min-plus job is bit-equal to the jnp route; the
    plus-times job matches within float tolerance (the kernel's contraction
    order may differ from einsum, which can shift the schedule's residual
    sub-tolerance mass)."""
    from repro.algorithms import PageRank, SSSP
    from repro.core import GraphSession, TwoLevel
    from repro.graph import rmat_graph

    csr = rmat_graph(150, 4, seed=13)
    res = {}
    for use_pallas in (False, True):
        sess = GraphSession(csr, 16, capacity=2, seed=5,
                            use_pallas=use_pallas)
        h_pr = sess.submit(PageRank())
        h_ss = sess.submit(SSSP(source=3))
        assert sess.run(TwoLevel(), 20000).converged
        res[use_pallas] = (sess.result(h_pr), sess.result(h_ss))
    np.testing.assert_array_equal(res[True][1], res[False][1])
    np.testing.assert_allclose(res[True][0], res[False][0],
                               rtol=1e-4, atol=1e-6)
