"""repro.obs: per-superstep telemetry, trace export, serve latency SLIs.

The tentpole acceptance surface:
  * a device run with steps_per_sync=inf AND telemetry on still syncs
    exactly once — and returns a per-superstep series covering EVERY
    superstep (the series rides the scan carry);
  * telemetry-on fixpoints are bitwise identical to telemetry-off, both
    backends (observation never perturbs);
  * host and device backends record IDENTICAL series on a fixed seed for
    all four policies (the graph is small enough that q saturates, so no
    sampling divergence between the numpy RNG and fold_in keys);
  * the telemetry-off compiled superstep is byte-for-byte the cached
    pre-observability program: the jit-cache key carries the capacity, so
    toggling telemetry neither invalidates nor re-traces the other
    variant;
  * Selection counter dtypes are pinned (host: python int; device: int32
    scalars);
  * exported traces are valid Chrome trace-event JSON (schema-checked)
    and carry the submit/detach/apply_updates story;
  * ConcurrentServeScheduler records deterministic wait_steps and
    p50/p99 summaries.
"""

import json
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.algorithms import PageRank, PersonalizedPageRank, SSSP
from repro.core import Fused, GraphSession, TwoLevel
from repro.core.policy import AllBlocks, Independent
from repro.graph import rmat_graph
from repro.obs import (TelemetryConfig, TelemetrySeries, SERIES_FIELDS,
                       validate_trace_events)
from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                    RequestStream)
from repro.stream import UpdateBatch

CSR = rmat_graph(300, 5, seed=7)

ALL_POLICIES = [TwoLevel, Independent, AllBlocks, Fused]


def _session(telemetry=True, **kw):
    sess = GraphSession(CSR, 32, capacity=2, seed=3, telemetry=telemetry,
                        **kw)
    sess.submit(PageRank())
    sess.submit(SSSP(source=0))
    return sess


# --- config coercion --------------------------------------------------------


def test_telemetry_config_coercion():
    assert TelemetryConfig.coerce(None) is None
    assert TelemetryConfig.coerce(False) is None
    assert TelemetryConfig.coerce(True) == TelemetryConfig()
    cfg = TelemetryConfig(capacity=16, trace=False)
    assert TelemetryConfig.coerce(cfg) is cfg
    with pytest.raises(TypeError):
        TelemetryConfig.coerce(42)


def test_telemetry_off_session_records_nothing():
    sess = _session(telemetry=None)
    m = sess.run(TwoLevel(), 500)
    assert m.converged and m.telemetry is None
    assert not sess.trace.enabled and sess.trace.events == []
    # a disabled recorder still exports a valid (metadata-only) trace
    validate_trace_events(sess.trace.to_json())


# --- the series itself ------------------------------------------------------


def test_host_series_covers_every_superstep_and_sums_to_totals():
    sess = _session()
    m = sess.run(TwoLevel(), 500)
    tel = m.telemetry
    assert isinstance(tel, TelemetrySeries)
    assert len(tel) == m.supersteps and not tel.truncated
    assert int(tel.tile_loads.sum()) == m.tile_loads
    assert int(tel.job_block_pushes.sum()) == m.job_block_pushes
    assert tel.num_groups == 2       # plus_times + min_plus views
    # supersteps run while work remains: active_jobs >= 1 throughout, and
    # unconverged is monotone-ish to zero at the end (last row may still
    # be nonzero — it describes the state BEFORE the final push)
    assert (tel.active_jobs >= 1).all()
    assert (tel.unconverged[0] > 0).all()
    assert (tel.max_residual >= 0).all()
    # dirty_blocks is zero without apply_updates
    assert (tel.dirty_blocks == 0).all()


def test_device_inf_full_series_at_exactly_one_sync():
    """THE tentpole invariant: steps_per_sync=inf + telemetry returns the
    complete per-superstep series while host_syncs stays 1."""
    sess = _session()
    m = sess.run(TwoLevel(backend="device", steps_per_sync=math.inf), 500)
    assert m.converged
    assert m.host_syncs == 1
    tel = m.telemetry
    assert len(tel) == m.supersteps and not tel.truncated
    assert int(tel.tile_loads.sum()) == m.tile_loads
    assert int(tel.job_block_pushes.sum()) == m.job_block_pushes


@pytest.mark.parametrize("policy_cls", ALL_POLICIES)
def test_host_and_device_record_identical_series(policy_cls):
    """Fixed seed, q saturated (every live block fits the queue, so the
    host numpy RNG and the device fold_in keys never actually sample):
    both backends must log the SAME schedule, column for column."""
    sess_h = _session()
    sess_d = _session()
    if policy_cls is Fused:
        m_h = sess_h.run(TwoLevel(), 500)
        m_d = sess_d.run(Fused(), 500)
    else:
        m_h = sess_h.run(policy_cls(), 500)
        m_d = sess_d.run(policy_cls(backend="device"), 500)
    assert m_h.converged and m_d.converged
    assert m_h.supersteps == m_d.supersteps
    t_h, t_d = m_h.telemetry, m_d.telemetry
    for f in SERIES_FIELDS:
        np.testing.assert_array_equal(getattr(t_h, f), getattr(t_d, f),
                                      err_msg=f)
    np.testing.assert_array_equal(t_h.unconverged, t_d.unconverged)
    np.testing.assert_allclose(t_h.max_residual, t_d.max_residual,
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("kw", [dict(),
                                dict(backend="device"),
                                dict(backend="device",
                                     steps_per_sync=math.inf)])
def test_telemetry_does_not_perturb_the_fixpoint(kw):
    """Bitwise: values/deltas after a telemetry-on run equal the
    telemetry-off run's, every backend/cadence."""
    sess_on, sess_off = _session(True), _session(None)
    m_on = sess_on.run(TwoLevel(**kw), 500)
    m_off = sess_off.run(TwoLevel(**kw), 500)
    assert m_on.converged and m_off.converged
    assert m_on.supersteps == m_off.supersteps
    assert m_on.tile_loads == m_off.tile_loads
    for g_on, g_off in zip(sess_on.view_groups(), sess_off.view_groups()):
        np.testing.assert_array_equal(np.asarray(g_on.values),
                                      np.asarray(g_off.values))
        np.testing.assert_array_equal(np.asarray(g_on.deltas),
                                      np.asarray(g_off.deltas))


def test_device_capacity_truncation_keeps_converging():
    """A run longer than the buffer still converges; the series holds the
    first `capacity` rows and is flagged truncated."""
    sess = _session(TelemetryConfig(capacity=8))
    m = sess.run(TwoLevel(backend="device", steps_per_sync=math.inf), 500)
    assert m.converged and m.supersteps > 8
    tel = m.telemetry
    assert tel.truncated and len(tel) == 8
    # the prefix matches an untruncated run's
    full = _session().run(
        TwoLevel(backend="device", steps_per_sync=math.inf), 500).telemetry
    np.testing.assert_array_equal(tel.tile_loads[:7], full.tile_loads[:7])


def test_dirty_blocks_series_spikes_once_after_apply_updates():
    sess = _session()
    assert sess.run(TwoLevel(), 500).converged
    sess.apply_updates(UpdateBatch.inserts(
        np.array([1, 2]), np.array([5, 9]), np.array([1.0, 1.0])))
    m = sess.run(TwoLevel(), 500)
    tel = m.telemetry
    assert m.dirty_blocks > 0
    assert int(tel.dirty_blocks[0]) == m.dirty_blocks
    assert (tel.dirty_blocks[1:] == 0).all()


# --- compiled-out when off: the jit cache stays pinned ----------------------


def test_telemetry_off_superstep_cache_is_untouched(transfer_sentinel,
                                                    retrace_pin):
    """Off-session: the cache key ends in capacity 0 and re-running never
    re-traces (same _cache_size pin as the device-scheduler suite); the
    re-run is additionally pinned by the analysis sentinels — explicit
    syncs only, zero cache growth."""
    sess = _session(telemetry=None)
    assert sess.run(Fused(), 500).converged
    with retrace_pin(sess):
        assert sess.run(Fused(), 500).converged
    entries = [k for k in sess._jit_cache if k[0] == "superstep"]
    assert len(entries) == 1 and entries[0][-1] == 0
    assert sess._jit_cache[entries[0]]._cache_size() == 1


def test_telemetry_on_compiles_its_own_entry_without_retracing(
        retrace_pin):
    sess = _session(TelemetryConfig(capacity=64))
    assert sess.run(Fused(), 500).converged
    with retrace_pin(sess):
        assert sess.run(Fused(), 500).converged
    entries = [k for k in sess._jit_cache if k[0] == "superstep"]
    assert len(entries) == 1 and entries[0][-1] == 64
    assert sess._jit_cache[entries[0]]._cache_size() == 1


# --- Selection dtype contract -----------------------------------------------


@pytest.mark.parametrize("policy_cls", [TwoLevel, Independent, AllBlocks])
def test_selection_counter_dtypes(policy_cls):
    """Host select returns python ints; device_select returns int32
    scalars — the drivers coerce exactly once (see Selection docstring)."""
    sess = _session(telemetry=None)
    groups = sess.view_groups()
    node_un, p_mean, actives = [], [], []
    for g in groups:
        nu, pm = map(np.asarray, sess._pairs_fn(g)(g.values, g.deltas))
        node_un.append(nu)
        p_mean.append(pm)
        actives.append(nu.sum(-1) > 0)
    selection = policy_cls().select(
        sess, node_un if policy_cls.needs_pairs else
        [nu.sum(-1) for nu in node_un], p_mean, actives)
    assert type(selection.tile_loads) is int
    assert type(selection.job_block_pushes) is int

    nus = [jnp.asarray(nu, jnp.float32) for nu in node_un]
    pms = [jnp.asarray(pm, jnp.float32) for pm in p_mean]
    acts = [jnp.asarray(a) for a in actives]
    d_sel = policy_cls(backend="device").device_select(
        nus, pms, acts, jax.random.PRNGKey(0), q=sess.q,
        alpha=sess.alpha, samples=sess.samples,
        num_blocks=sess.scheduler.num_blocks)
    assert d_sel.tile_loads.dtype == jnp.int32
    assert d_sel.job_block_pushes.dtype == jnp.int32


# --- RunMetrics surface -----------------------------------------------------


def test_run_metrics_to_dict_and_wall_time():
    sess = _session()
    m = sess.run(TwoLevel(), 500)
    assert m.wall_time_s > 0
    d = m.to_dict()
    assert d["supersteps"] == m.supersteps
    assert d["host_syncs"] == m.host_syncs
    assert d["converged"] is True
    assert "telemetry" not in d
    full = m.to_dict(include_telemetry=True)
    assert full["telemetry"]["supersteps"] == m.supersteps
    json.dumps(full)    # JSON-ready all the way down


# --- trace export -----------------------------------------------------------


def test_trace_export_is_valid_chrome_trace_json(tmp_path):
    sess = _session()
    h = sess.submit(PersonalizedPageRank(source=9))
    assert sess.run(TwoLevel(), 500).converged
    sess.apply_updates(UpdateBatch.inserts(
        np.array([0]), np.array([7]), np.array([1.0])))
    assert sess.run(TwoLevel(), 500).converged
    sess.detach(h)
    path = tmp_path / "trace.json"
    sess.trace.export(str(path))
    doc = json.loads(path.read_text())
    assert validate_trace_events(doc) == len(doc["traceEvents"])
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"submit", "detach", "run", "superstep", "apply_updates",
            "converged", "process_name"} <= names
    # per-superstep spans landed on the named superstep track
    spans = [e for e in doc["traceEvents"] if e["name"] == "superstep"]
    assert spans and all(e["ph"] == "X" and e["dur"] >= 0 for e in spans)
    # counter samples carry the full fixed schema
    counters = [e for e in doc["traceEvents"]
                if e["name"] == "telemetry" and e["ph"] == "C"]
    assert counters and set(SERIES_FIELDS) <= set(counters[0]["args"])


def test_trace_schema_validator_rejects_malformed_events():
    with pytest.raises(ValueError):
        validate_trace_events({"events": []})
    with pytest.raises(ValueError):
        validate_trace_events(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                              "pid": 1, "tid": 1}]})  # X without dur
    with pytest.raises(ValueError):
        validate_trace_events(
            {"traceEvents": [{"name": "x", "ph": "B", "ts": 0.0,
                              "pid": 1, "tid": 1}]})  # unknown phase


def test_device_chunks_traced_per_sync():
    sess = _session()
    m = sess.run(TwoLevel(backend="device", steps_per_sync=8), 500)
    chunks = [e for e in sess.trace.events if e["name"] == "device_chunk"]
    assert len(chunks) == m.host_syncs


# --- serve-layer metrics ----------------------------------------------------


def _serve_world():
    sched = ConcurrentServeScheduler(8, 4, seed=0)
    chat = RequestStream(0, family="chat")
    batch = RequestStream(1, family="batch")
    sched.add_stream(chat)
    sched.add_stream(batch)
    for i in range(10):
        chat.add(Request(0, i % 8, 1.0, 4))
        batch.add(Request(1, i % 8, 0.5, 4))
    return sched, chat, batch


def test_serve_metrics_percentiles_and_families():
    sched, chat, batch = _serve_world()
    done = []
    while chat.waiting or batch.waiting:
        done += sched.schedule_step()
    for r in done:
        sched.complete(r, service_s=0.01)
    s = sched.metrics.summary()
    assert s["steps"] == sched._step_idx >= 5     # 20 reqs / budget 4
    assert s["wait_steps"]["count"] == 20
    assert 0 <= s["wait_steps"]["p50"] <= s["wait_steps"]["p99"] \
        <= s["wait_steps"]["max"]
    assert s["service_s"]["count"] == 20
    assert abs(s["service_s"]["p50"] - 0.01) < 1e-9
    assert set(s["queue_depth_by_family"]) == {"chat", "batch"}
    assert set(s["wait_steps_by_stream"]) == {"0", "1"}
    assert len(sched.metrics.gq_occupancy) == s["steps"]
    json.dumps(s)


def test_serve_wait_steps_are_deterministic():
    """wait_steps counts scheduler steps (not wall time), so two identical
    worlds record identical samples."""
    runs = []
    for _ in range(2):
        sched, chat, batch = _serve_world()
        while chat.waiting or batch.waiting:
            sched.schedule_step()
        runs.append(sorted(sched.metrics.wait_steps.samples))
    assert runs[0] == runs[1]
    # budget 4, 20 requests: someone waited, nobody waited forever
    assert runs[0][0] == 0 and 0 < runs[0][-1] <= 5


def test_serve_metrics_opt_out():
    sched = ConcurrentServeScheduler(4, 2, metrics=False)
    st = RequestStream(0)
    sched.add_stream(st)
    st.add(Request(0, 0, 1.0, 1))
    assert sched.metrics is None
    assert len(sched.schedule_step()) == 1      # scheduling unaffected


def test_serve_admissions_land_on_a_shared_trace():
    sess = _session()
    sched = ConcurrentServeScheduler(4, 2, trace=sess.trace)
    st = RequestStream(0)
    sched.add_stream(st)
    st.add(Request(0, 0, 1.0, 1))
    sched.schedule_step()
    ev = [e for e in sess.trace.events if e["name"] == "serve.admit"]
    assert len(ev) == 1 and ev[0]["args"]["admitted"] == 1
