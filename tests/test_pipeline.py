"""Pipeline parallelism: pipelined loss/grads == unpipelined reference.

Runs in a subprocess with 4 host devices (the test process itself keeps the
default single-device config so other tests are unaffected)."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from repro.dist.pipeline import make_pipelined_loss

S, M, MB, D = 4, 4, 2, 16
mesh = jax.make_mesh((S,), ("pod",))
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32),
          "b": jnp.asarray(rng.standard_normal((S, D)) * 0.1, jnp.float32)}
x = jnp.asarray(rng.standard_normal((M * MB, D)), jnp.float32)
y = jnp.asarray(rng.standard_normal((M * MB, D)), jnp.float32)

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"][0] if p["w"].ndim == 3 else h @ p["w"]) + \
        (p["b"][0] if p["b"].ndim == 2 else p["b"])

def stage_fn_local(p, h):
    return jnp.tanh(h @ p["w"]) + p["b"]

def loss_fn(out, y):
    return jnp.mean((out - y) ** 2)

# reference: sequential stages
def ref_loss(params, x, y):
    h = x
    for i in range(S):
        h = stage_fn_local(jax.tree.map(lambda a: a[i], params), h)
    return loss_fn(h, y)

pipe = make_pipelined_loss(mesh, stage_fn_local, loss_fn, axis_name="pod",
                           n_micro=M)
with mesh:
    lp = jax.jit(pipe)(params, x, y)
lr = ref_loss(params, x, y)
assert abs(float(lp) - float(lr)) < 1e-5, (float(lp), float(lr))

with mesh:
    gp = jax.jit(jax.grad(pipe))(params, x, y)
gr = jax.grad(ref_loss)(params, x, y)
for a, b in zip(jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
print("PIPELINE-OK")
"""


def test_pipeline_matches_reference():
    result = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "PIPELINE-OK" in result.stdout, result.stderr[-2000:]
