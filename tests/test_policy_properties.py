"""Property-based cross-policy equivalence suite.

All four schedule policies (TwoLevel, Fused, Independent, AllBlocks) are
schedules over the SAME delta-accumulative semiring arithmetic, so on any
graph × job mix they must reach the same per-job fixpoint: exactly for
min-plus (the fixpoint is schedule-invariant — min is idempotent and
path sums accumulate in path order), and within a tight tolerance for
plus-times (a schedule decides where the residual sub-tolerance mass
sits).  Random small CSRs × heterogeneous job mixes × seeds probe that
invariant — across policies on the host backend, and across the full
backend="device" × steps_per_sync grid — plus the lifecycle property that
detach+resubmit mid-run never perturbs surviving jobs.

Runs under the real `hypothesis` when installed, else the deterministic
shim in tests/_hypothesis_shim.py (registered by conftest).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms import BFS, Katz, PageRank, PersonalizedPageRank, SSSP
from repro.algorithms.base import MIN_PLUS
from repro.core import AllBlocks, Fused, GraphSession, Independent, TwoLevel
from repro.graph.structure import CSRGraph

pytestmark = pytest.mark.slow

BLOCK = 16


def _random_csr(seed: int, n: int, deg: int, weighted: bool) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = n * deg
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    w = (rng.uniform(0.5, 4.0, m).astype(np.float32) if weighted else None)
    return CSRGraph.from_edges(n, src, dst, w)


def _job_mix(rng: np.random.Generator, n: int, weighted: bool):
    """2-4 jobs across both families.  Weighted graphs exclude the
    stochastic plus-times algorithms (PageRank/PPR need row sums <= 1,
    which out-degree normalization only gives for unit weights); Katz with
    a small alpha stays contractive either way."""
    pool = [
        lambda: Katz(alpha=0.02),
        lambda: SSSP(source=int(rng.integers(n))),
        lambda: BFS(source=int(rng.integers(n))),
    ]
    if not weighted:
        pool += [
            lambda: PageRank(damping=float(rng.uniform(0.6, 0.9))),
            lambda: PersonalizedPageRank(source=int(rng.integers(n))),
        ]
    k = int(rng.integers(2, 5))
    return [pool[int(rng.integers(len(pool)))]() for _ in range(k)]


def _run_all(csr, algs, policy, seed):
    sess = GraphSession(csr, BLOCK, capacity=2, seed=seed)
    handles = [sess.submit(a) for a in algs]
    m = sess.run(policy, 50000)
    assert m.converged, (policy.name, algs)
    return sess, [sess.result(h) for h in handles]


def _assert_same_fixpoint(alg, got, want):
    if alg.semiring == MIN_PLUS:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([24, 40, 56]),
       deg=st.integers(1, 4), weighted=st.booleans())
@settings(max_examples=8, deadline=None)
def test_all_policies_reach_the_same_per_job_fixpoint(seed, n, deg,
                                                      weighted):
    csr = _random_csr(seed, n, deg, weighted)
    algs = _job_mix(np.random.default_rng(seed + 1), n, weighted)
    _, ref = _run_all(csr, algs, TwoLevel(), seed=seed % 97)
    for policy in (Fused(), Independent(), AllBlocks()):
        _, got = _run_all(csr, algs, policy, seed=seed % 97)
        for alg, g, w in zip(algs, got, ref):
            _assert_same_fixpoint(alg, g, w)


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([24, 40]),
       deg=st.integers(1, 4), weighted=st.booleans())
@settings(max_examples=5, deadline=None)
def test_device_backend_matches_host_fixpoint_at_any_sync_cadence(
        seed, n, deg, weighted):
    """The tentpole invariant: moving BOTH scheduling levels on device —
    and batching any number of supersteps per host sync — is a schedule
    change only, never an arithmetic one.  Every policy on
    backend="device", at steps_per_sync 1 and 4 (and Fused's inf), must
    reach the host TwoLevel fixpoint: exactly for min-plus, within the
    plus-times tolerance."""
    csr = _random_csr(seed, n, deg, weighted)
    algs = _job_mix(np.random.default_rng(seed + 1), n, weighted)
    _, ref = _run_all(csr, algs, TwoLevel(), seed=seed % 97)
    grid = [TwoLevel(backend="device", steps_per_sync=1),
            TwoLevel(backend="device", steps_per_sync=4),
            Independent(backend="device", steps_per_sync=1),
            Independent(backend="device", steps_per_sync=4),
            AllBlocks(backend="device", steps_per_sync=1),
            AllBlocks(backend="device", steps_per_sync=4),
            Fused(steps_per_sync=4), Fused()]
    for policy in grid:
        _, got = _run_all(csr, algs, policy, seed=seed % 97)
        for alg, g, w in zip(algs, got, ref):
            _assert_same_fixpoint(alg, g, w)


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([24, 40]),
       deg=st.integers(1, 3), steps=st.integers(1, 12))
@settings(max_examples=8, deadline=None)
def test_detach_resubmit_mid_run_never_perturbs_survivors(seed, n, deg,
                                                          steps):
    """Detach one job mid-run and admit a NEW one into the freed capacity:
    every surviving job still reaches its reference fixpoint."""
    csr = _random_csr(seed, n, deg, weighted=False)
    rng = np.random.default_rng(seed + 2)
    algs = _job_mix(rng, n, weighted=False)
    newcomer = SSSP(source=int(rng.integers(n)))
    _, ref = _run_all(csr, algs, TwoLevel(), seed=seed % 89)
    _, ref_new = _run_all(csr, [newcomer], TwoLevel(), seed=seed % 89)

    sess = GraphSession(csr, BLOCK, capacity=2, seed=seed % 89)
    handles = [sess.submit(a) for a in algs]
    sess.run(TwoLevel(), max_supersteps=steps)
    sess.detach(handles[0])                     # leaves mid-run
    h_new = sess.submit(newcomer)               # arrives mid-run
    assert sess.run(TwoLevel(), 50000).converged
    for alg, h, w in zip(algs[1:], handles[1:], ref[1:]):
        _assert_same_fixpoint(alg, sess.result(h), w)
    _assert_same_fixpoint(newcomer, sess.result(h_new), ref_new[0])
    with pytest.raises(KeyError):
        sess.result(handles[0])
