"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + a prefill/decode round-trip on CPU; shapes + finiteness."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import LM


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.n_codebooks:
        tokens = rng.integers(0, cfg.vocab_size, (b, s, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab_size, (b, s))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.patch_prefix:
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.patch_prefix, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_forward_and_loss(name):
    cfg = configs.get_smoke(name)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward_train)(
        params, batch["tokens"], batch.get("patch_embeds"))
    b, s = 2, 32
    s_out = s + cfg.patch_prefix
    if cfg.n_codebooks:
        assert logits.shape == (b, s_out, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # random init, |vocab|-way uniform-ish CE
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_train_step_grads_finite(name):
    cfg = configs.get_smoke(name)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # at least one nonzero gradient leaf
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in leaves)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """Prefill(t[:s]) then decode step == forward_train at position s."""
    cfg = configs.get_smoke(name)
    if cfg.patch_prefix:
        pytest.skip("prefix-VLM decode covered by dryrun lowering")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 17
    batch = _batch(cfg, b, s + 1)
    tokens = batch["tokens"]

    logits_full, _ = jax.jit(model.forward_train)(params, tokens)

    cache = model.init_cache(batch=b, max_len=64)
    last, cache = jax.jit(model.prefill)(params, tokens[:, :s], cache)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits_full[:, s - 1], np.float32), rtol=2e-2, atol=2e-2)

    step_logits, cache = jax.jit(model.decode_step)(
        params, tokens[:, s:s + 1], cache)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(logits_full[:, s], np.float32), rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The exact assigned numbers, spot-checked."""
    c = configs.get("qwen3-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (64, 5120, 64, 8, 25600, 151936)
    assert c.qk_norm
    c = configs.get("mixtral-8x7b")
    assert c.moe and c.n_experts == 8 and c.top_k == 2
    assert c.block_pattern == ("swa",)
    c = configs.get("qwen3-moe-235b-a22b")
    assert c.n_layers == 94 and c.n_experts == 128 and c.top_k == 8
    c = configs.get("recurrentgemma-9b")
    assert c.block_pattern == ("rglru", "rglru", "swa")
    assert c.n_layers == 38 and c.n_kv_heads == 1
    c = configs.get("xlstm-350m")
    assert c.block_pattern.count("mlstm") == 7
    assert c.d_ff == 0
    c = configs.get("musicgen-medium")
    assert c.n_codebooks == 4 and c.vocab_size == 2048
    c = configs.get("pixtral-12b")
    assert c.patch_prefix > 0 and c.vocab_size == 131072
    c = configs.get("minicpm-2b")
    assert c.tie_embeddings and c.vocab_size == 122753
    c = configs.get("phi4-mini-3.8b")
    assert c.vocab_size == 200064
    c = configs.get("qwen2.5-14b")
    assert c.qkv_bias
