"""GraphSession lifecycle: dynamic submit/detach over one shared graph.

Covers the api_redesign acceptance criteria:
  * a job submitted MID-RUN converges to the same result (allclose) as the
    same algorithm run in a static batch, under TwoLevel and Fused, with
    and without a jobs mesh (mesh variant in a 4-host-device subprocess);
  * detaching a converged job frees its slot and later submissions reuse
    it (stale handles are rejected);
  * the legacy ConcurrentEngine shim stays bit-identical to a direct
    GraphSession drive (the existing convergence suite pins the shim's
    fixpoints themselves);
  * HETEROGENEOUS sessions: mixed-semiring jobs (plus-times + min-plus)
    share one session and one staging per selected block — each job still
    reaches its solo-session fixpoint (exact for min-plus), tile loads sit
    below the per-family split, and mesh sharding composes per view.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.algorithms import Katz, PageRank, PersonalizedPageRank, SSSP
from repro.core import (AllBlocks, ConcurrentEngine, Fused, GraphSession,
                        Independent, TwoLevel, make_run)
from repro.graph import rmat_graph, uniform_graph

CSR = rmat_graph(300, 5, seed=7)
CSR_W = uniform_graph(200, 5, seed=8, weighted=True, w_max=9.0)


def _static_reference(algs, csr, block_size, seed):
    eng = ConcurrentEngine(make_run(algs, csr, block_size), seed=seed)
    assert eng.run_two_level(20000).converged
    return eng.results()


@pytest.mark.parametrize(
    "policy",
    [TwoLevel(), Fused(),
     TwoLevel(backend="device", steps_per_sync=4)],
    ids=["two_level", "fused", "device_k4"])
def test_mid_run_submit_matches_static_batch(policy):
    algs = [PageRank(), PersonalizedPageRank(source=7)]
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    h0 = sess.submit(algs[0])
    sess.run(policy, max_supersteps=5)          # job 1 arrives mid-run
    h1 = sess.submit(algs[1])
    assert sess.run(policy, max_supersteps=20000).converged
    ref = _static_reference(algs, CSR, 32, seed=5)
    np.testing.assert_allclose(sess.result(h0), ref[0], rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(sess.result(h1), ref[1], rtol=1e-3, atol=1e-5)


def test_mid_run_submit_min_plus_exact():
    """MIN_PLUS fixpoints are schedule-invariant, so arrival order must not
    change a single distance."""
    sess = GraphSession(CSR_W, 32, capacity=2, seed=3)
    h0 = sess.submit(SSSP(source=0))
    sess.run(TwoLevel(), max_supersteps=3)
    h1 = sess.submit(SSSP(source=17))
    assert sess.run(TwoLevel(), max_supersteps=20000).converged
    ref = _static_reference([SSSP(source=0), SSSP(source=17)], CSR_W, 32,
                            seed=3)
    np.testing.assert_array_equal(sess.result(h0), ref[0])
    np.testing.assert_array_equal(sess.result(h1), ref[1])


def test_session_static_batch_is_bitwise_equal_to_engine_shim():
    algs = [PageRank(damping=0.85), PageRank(damping=0.7)]
    eng = ConcurrentEngine(make_run(algs, CSR, 32), seed=11)
    m_e = eng.run_two_level(20000)
    sess = GraphSession(CSR, 32, capacity=2, seed=11)
    handles = [sess.submit(a) for a in algs]
    m_s = sess.run(TwoLevel(), 20000)
    assert m_e.converged and m_s.converged
    assert m_e.supersteps == m_s.supersteps
    assert m_e.tile_loads == m_s.tile_loads
    assert m_e.job_block_pushes == m_s.job_block_pushes
    np.testing.assert_array_equal(
        eng.results(), np.stack([sess.result(h) for h in handles]))


def test_detach_frees_slot_and_recycles_it():
    sess = GraphSession(CSR, 32, capacity=2, seed=0)
    h0 = sess.submit(PageRank())
    h1 = sess.submit(PersonalizedPageRank(source=3))
    assert sess.run(TwoLevel(), 20000).converged
    assert sess.converged(h0) and sess.converged(h1)
    res0 = sess.detach(h0)                      # frees slot 0
    assert res0.shape == (CSR.n,)
    assert sess.num_active == 1
    h2 = sess.submit(PageRank(damping=0.6))     # reuses the freed slot
    assert h2.slot == h0.slot
    with pytest.raises(KeyError):
        sess.result(h0)                         # stale handle
    with pytest.raises(KeyError):
        sess.detach(h0)
    assert sess.run(TwoLevel(), 20000).converged
    ref = _static_reference([PageRank(damping=0.6)], CSR, 32, seed=0)
    np.testing.assert_allclose(sess.result(h2), ref[0], rtol=1e-3, atol=1e-5)
    # the already-converged survivor is untouched by the newcomer's run
    np.testing.assert_allclose(
        sess.result(h1),
        _static_reference([PersonalizedPageRank(source=3)], CSR, 32, seed=0)[0],
        rtol=1e-3, atol=1e-5)


def test_capacity_growth_preserves_running_jobs():
    sess = GraphSession(CSR, 32, capacity=1, seed=2)
    h0 = sess.submit(PageRank())
    sess.run(TwoLevel(), 4)
    h1 = sess.submit(PersonalizedPageRank(source=50))   # doubles capacity
    assert sess.capacity == 2
    h2 = sess.submit(PersonalizedPageRank(source=120))  # doubles again
    assert sess.capacity == 4
    assert sess.run(TwoLevel(), 20000).converged
    algs = [PageRank(), PersonalizedPageRank(source=50),
            PersonalizedPageRank(source=120)]
    ref = _static_reference(algs, CSR, 32, seed=2)
    for h, r in zip((h0, h1, h2), ref):
        np.testing.assert_allclose(sess.result(h), r, rtol=1e-3, atol=1e-5)


# -- heterogeneous sessions: mixed-semiring jobs over one shared CSR --------
# (replaces test_mixed_view_submission_rejected: mixed graph views are now
# the point — each view is built lazily and block-aligned, and one staging
# of a selected block serves both semiring pushes)


def _solo(alg, policy, seed=5):
    s = GraphSession(CSR, 32, capacity=1, seed=seed)
    h = s.submit(alg)
    m = s.run(policy, 20000)
    assert m.converged
    return s.result(h), m


@pytest.mark.parametrize("policy_cls", [TwoLevel, Fused],
                         ids=["two_level", "fused"])
def test_heterogeneous_session_matches_solo_fixpoints(policy_cls):
    """{PageRank, SSSP} in ONE session: the min-plus job's fixpoint is
    schedule-invariant (exact), the plus-times job converges to its solo
    fixpoint within tolerance."""
    pr_ref, _ = _solo(PageRank(), policy_cls())
    ss_ref, _ = _solo(SSSP(source=0), policy_cls())
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    h_pr = sess.submit(PageRank())
    h_ss = sess.submit(SSSP(source=0))
    assert len(sess.groups) == 2            # two block-aligned graph views
    m = sess.run(policy_cls(), 20000)
    assert m.converged
    np.testing.assert_array_equal(sess.result(h_ss), ss_ref)
    np.testing.assert_allclose(sess.result(h_pr), pr_ref,
                               rtol=1e-3, atol=1e-5)


def test_heterogeneous_shared_staging_beats_split_sessions():
    """The cross-family CAJS claim: one staging per selected block serves
    BOTH families, so hetero tile loads < the per-family sessions' sum."""
    sess = GraphSession(CSR, 32, capacity=2, seed=5)
    h = [sess.submit(a) for a in
         (PageRank(), SSSP(source=0), PersonalizedPageRank(source=7),
          SSSP(source=17))]
    m = sess.run(TwoLevel(), 20000)
    assert m.converged
    split = 0
    for fam in ([PageRank(), PersonalizedPageRank(source=7)],
                [SSSP(source=0), SSSP(source=17)]):
        s = GraphSession(CSR, 32, capacity=2, seed=5)
        for a in fam:
            s.submit(a)
        mf = s.run(TwoLevel(), 20000)
        assert mf.converged
        split += mf.tile_loads
    assert m.tile_loads < split
    assert all(sess.converged(hh) for hh in h)


def test_heterogeneous_mid_run_submit_detach_and_slot_independence():
    """Arrival of a DIFFERENT family mid-run; per-view slots may collide
    numerically (they are distinct handles); detach+resubmit in one view
    never perturbs the other view's survivors."""
    ss_ref, _ = _solo(SSSP(source=0), TwoLevel(), seed=2)
    sess = GraphSession(CSR, 32, capacity=1, seed=2)
    h_pr = sess.submit(PageRank())
    sess.run(TwoLevel(), max_supersteps=5)
    h_ss = sess.submit(SSSP(source=0))          # new view arrives mid-run
    assert h_pr.slot == h_ss.slot == 0          # per-view axes
    assert sess.job_index(h_pr) != sess.job_index(h_ss)
    assert sess.run(TwoLevel(), 20000).converged
    np.testing.assert_array_equal(sess.result(h_ss), ss_ref)
    res_pr = sess.detach(h_pr)                  # frees only the PT slot
    assert sess.num_active == 1
    h_katz = sess.submit(Katz())                # third view, new group
    assert len(sess.groups) == 3
    assert sess.run(TwoLevel(), 20000).converged
    with pytest.raises(KeyError):
        sess.result(h_pr)
    np.testing.assert_array_equal(sess.result(h_ss), ss_ref)  # untouched
    katz_ref, _ = _solo(Katz(), TwoLevel(), seed=2)
    np.testing.assert_allclose(sess.result(h_katz), katz_ref,
                               rtol=1e-3, atol=1e-5)
    assert res_pr.shape == (CSR.n,)


def test_heterogeneous_unconverged_counts_layout():
    sess = GraphSession(CSR, 32, capacity=2, seed=0)
    h_pr = sess.submit(PageRank())
    h_ss = sess.submit(SSSP(source=0))
    counts = sess.unconverged_counts()
    assert counts.shape == (sess.total_capacity,) == (4,)
    assert counts[sess.job_index(h_pr)] > 0
    assert counts[sess.job_index(h_ss)] > 0     # the source vertex pends
    sess.run(TwoLevel(), 20000)
    assert (sess.unconverged_counts() == 0).all()


HETERO_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, PersonalizedPageRank, SSSP, BFS
from repro.core import GraphSession, TwoLevel, Fused
from repro.dist.graph import make_job_mesh
from repro.graph import rmat_graph

assert len(jax.devices()) == 4
csr = rmat_graph(200, 5, seed=13)
algs = [PageRank(), PersonalizedPageRank(source=11),
        SSSP(source=0), SSSP(source=42)]

for policy, tag in ((TwoLevel(), "TWO-LEVEL"), (Fused(), "FUSED")):
    ref = GraphSession(csr, 16, capacity=4, seed=5)
    rh = [ref.submit(a) for a in algs]
    assert ref.run(policy, 20000).converged

    mesh = make_job_mesh(4)
    sess = GraphSession(csr, 16, capacity=4, seed=5)
    h = [sess.submit(a) for a in algs[:2]]
    sess.run(policy, max_supersteps=4, mesh=mesh)   # MP family arrives later
    h += [sess.submit(a) for a in algs[2:]]
    m = sess.run(policy, 20000, mesh=mesh)
    assert m.converged
    for g in sess.view_groups():                    # every view sharded
        assert g.values.sharding.spec[0] == "jobs", g.values.sharding
    for hh, rr in zip(h, rh):
        if hh.alg.semiring == "min_plus":           # schedule-invariant
            np.testing.assert_array_equal(sess.result(hh), ref.result(rr))
        else:
            np.testing.assert_allclose(sess.result(hh), ref.result(rr),
                                       rtol=1e-3, atol=1e-5)
    print(tag + "-HETERO-MESH-OK")
"""


def test_heterogeneous_session_mesh_matches_unsharded():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    pythonpath = src + os.pathsep + os.environ.get("PYTHONPATH", "")
    result = subprocess.run(
        [sys.executable, "-c", HETERO_MESH_SCRIPT],
        capture_output=True, text=True, timeout=480,
        env={**os.environ, "PYTHONPATH": pythonpath.rstrip(os.pathsep)})
    for marker in ("TWO-LEVEL-HETERO-MESH-OK", "FUSED-HETERO-MESH-OK"):
        assert marker in result.stdout, result.stderr[-2000:]


@pytest.mark.parametrize("policy", [Independent(), AllBlocks()],
                         ids=["independent", "all_blocks"])
def test_baseline_policies_reach_the_same_fixpoint(policy):
    algs = [PageRank(), PersonalizedPageRank(source=7)]
    sess = GraphSession(CSR, 32, capacity=2, seed=9)
    handles = [sess.submit(a) for a in algs]
    assert sess.run(policy, 20000).converged
    ref = _static_reference(algs, CSR, 32, seed=9)
    for h, r in zip(handles, ref):
        np.testing.assert_allclose(sess.result(h), r, rtol=1e-3, atol=1e-5)


def test_fused_metrics_are_populated_and_comparable():
    """Satellite: run_fused used to leave job_block_pushes at 0."""
    algs = [PageRank(damping=d) for d in (0.85, 0.7)]
    m_f = ConcurrentEngine(make_run(algs, CSR, 32), seed=11).run_fused(20000)
    m_h = ConcurrentEngine(make_run(algs, CSR, 32),
                           seed=11).run_two_level(20000)
    assert m_f.converged and m_h.converged
    assert m_f.job_block_pushes > 0
    # same definition of a (job, block) processing event as the host driver
    assert m_f.job_block_pushes <= m_f.supersteps * len(algs) * 1000
    # per-job iteration counts reflect that the 0.7-damping job finishes first
    assert m_f.iterations_per_job[1] < m_f.iterations_per_job[0]


SESSION_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.algorithms import PageRank, PersonalizedPageRank
from repro.core import ConcurrentEngine, GraphSession, TwoLevel, Fused, make_run
from repro.dist.graph import make_job_mesh
from repro.graph import rmat_graph

assert len(jax.devices()) == 4
csr = rmat_graph(200, 5, seed=13)
algs = [PageRank(), PageRank(damping=0.7),
        PersonalizedPageRank(source=11), PersonalizedPageRank(source=42)]
ref_eng = ConcurrentEngine(make_run(algs, csr, 16), seed=5)
assert ref_eng.run_two_level(20000).converged
ref = ref_eng.results()

for policy, tag in ((TwoLevel(), "TWO-LEVEL"), (Fused(), "FUSED")):
    mesh = make_job_mesh(4)
    sess = GraphSession(csr, 16, capacity=4, seed=5)
    h = [sess.submit(a) for a in algs[:2]]
    sess.run(policy, max_supersteps=4, mesh=mesh)   # arrivals mid-run
    h += [sess.submit(a) for a in algs[2:]]
    m = sess.run(policy, 20000, mesh=mesh)
    assert m.converged
    assert sess.values.sharding.spec[0] == "jobs", sess.values.sharding
    got = np.stack([sess.result(hh) for hh in h])
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-5)
    print(tag + "-MESH-SESSION-OK")
"""


def test_session_mesh_mid_run_submit_matches_static():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    pythonpath = src + os.pathsep + os.environ.get("PYTHONPATH", "")
    result = subprocess.run(
        [sys.executable, "-c", SESSION_MESH_SCRIPT],
        capture_output=True, text=True, timeout=480,
        env={**os.environ, "PYTHONPATH": pythonpath.rstrip(os.pathsep)})
    for marker in ("TWO-LEVEL-MESH-SESSION-OK", "FUSED-MESH-SESSION-OK"):
        assert marker in result.stdout, result.stderr[-2000:]
