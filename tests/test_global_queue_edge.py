"""Regression tests for De_Gl_Priority (core.global_q.global_queue) edge
cases and the serve-scheduler path that feeds it (added alongside the
dead-code cleanup in serve/concurrent.py::schedule_step)."""

import numpy as np

from repro.core.global_q import global_queue
from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                    RequestStream)


# --- global_queue edges ------------------------------------------------------

def test_all_empty_job_queues():
    jq = [np.empty(0, dtype=np.int64) for _ in range(4)]
    assert len(global_queue(jq, num_blocks=16, q=4)) == 0


def test_no_job_queues_at_all():
    assert len(global_queue([], num_blocks=16, q=4)) == 0


def test_alpha_one_no_reserved_slots():
    # alpha=1.0: the whole queue comes from cumulative priority; job C's
    # singleton head (block 9) only enters if cumulative weight earns it
    jq = [np.array([1, 2, 3, 4]), np.array([1, 2, 3, 4]), np.array([9])]
    gq = global_queue(jq, num_blocks=12, q=4, alpha=1.0)
    assert len(gq) <= 4
    assert len(set(gq.tolist())) == len(gq)
    # blocks 1..4 carry weight 2q+.. vs block 9's single q: top slot is 1
    assert gq[0] == 1


def test_alpha_one_still_fills_from_heads_when_short():
    # alpha=1.0 but only 2 distinct candidate blocks for q=4: the queue is
    # allowed to come up short, never padded with converged blocks
    jq = [np.array([3]), np.array([5])]
    gq = global_queue(jq, num_blocks=8, q=4, alpha=1.0)
    assert set(gq.tolist()) == {3, 5}


def test_duplicate_heads_across_jobs_counted_once_in_queue():
    # every job heads the same block: it must appear exactly once, first
    jq = [np.array([7, 1]), np.array([7, 2]), np.array([7, 3])]
    gq = global_queue(jq, num_blocks=10, q=4)
    assert gq[0] == 7
    assert list(gq).count(7) == 1
    assert len(set(gq.tolist())) == len(gq)


def test_queue_longer_than_q_never_returned():
    jq = [np.arange(9), np.arange(9)[::-1].copy()]
    gq = global_queue(jq, num_blocks=9, q=3, alpha=0.5)
    assert len(gq) <= 3


def test_reserved_slot_rotation_terminates_on_exhausted_queues():
    # queues shorter than the reserve depth: the fill loop must not spin
    jq = [np.array([0]), np.array([1])]
    gq = global_queue(jq, num_blocks=4, q=4, alpha=0.25)
    assert set(gq.tolist()) == {0, 1}


# --- serve scheduler feeding the same policy --------------------------------

def test_schedule_step_all_streams_empty():
    sched = ConcurrentServeScheduler(n_groups=4, batch_budget=4, seed=0)
    sched.add_stream(RequestStream(1))
    sched.add_stream(RequestStream(2))
    assert sched.schedule_step() == []


def test_schedule_step_budget_overflow_fills_from_any_group():
    sched = ConcurrentServeScheduler(n_groups=4, batch_budget=3, seed=0)
    s = RequestStream(1)
    sched.add_stream(s)
    for g in range(4):
        s.add(Request(1, g, urgency=1.0, tokens_left=5))
    admitted = sched.schedule_step()
    assert len(admitted) == 3
    assert len(s.waiting) == 1


def test_schedule_step_duplicate_hot_group_across_streams():
    sched = ConcurrentServeScheduler(n_groups=8, batch_budget=2, seed=0)
    s1, s2 = RequestStream(1), RequestStream(2)
    sched.add_stream(s1)
    sched.add_stream(s2)
    s1.add(Request(1, 5, urgency=9.0, tokens_left=5))
    s2.add(Request(2, 5, urgency=9.0, tokens_left=5))
    admitted = sched.schedule_step()
    # the shared hot group serves both streams within budget, one each
    assert len(admitted) == 2
    assert {r.stream_id for r in admitted} == {1, 2}
    assert all(r.group == 5 for r in admitted)


def _populated_scheduler(stream_order):
    sched = ConcurrentServeScheduler(n_groups=6, batch_budget=5, seed=0)
    streams = {sid: RequestStream(sid) for sid in (1, 2, 3)}
    for sid in stream_order:
        sched.add_stream(streams[sid])
    for sid, s in streams.items():
        for i in range(4):
            s.add(Request(sid, (sid + i) % 6, urgency=float(sid + i),
                          tokens_left=5))
    return sched


def test_schedule_step_independent_of_stream_insertion_order():
    """Admission was dict-insertion-order dependent; it must now depend only
    on sorted stream ids (same RNG stream, same request set -> same batch)."""
    a = _populated_scheduler([1, 2, 3]).schedule_step()
    b = _populated_scheduler([3, 1, 2]).schedule_step()
    key = [(r.stream_id, r.group, r.urgency) for r in a]
    assert key == [(r.stream_id, r.group, r.urgency) for r in b]
    assert len(a) == 5


def test_schedule_step_zero_budget_admits_nothing():
    sched = ConcurrentServeScheduler(n_groups=4, batch_budget=0, seed=0)
    s = RequestStream(1)
    sched.add_stream(s)
    s.add(Request(1, 0, urgency=1.0, tokens_left=5))
    assert sched.schedule_step() == []
    assert len(s.waiting) == 1


def test_schedule_step_drains_fifo_within_a_group():
    """Linear index-based drain must keep per-(stream, group) FIFO order."""
    sched = ConcurrentServeScheduler(n_groups=2, batch_budget=4, seed=0)
    s = RequestStream(1)
    sched.add_stream(s)
    for urg in (1.0, 2.0, 3.0):
        s.add(Request(1, 0, urgency=urg, tokens_left=5))
    admitted = sched.schedule_step()
    assert [r.urgency for r in admitted] == [1.0, 2.0, 3.0]
    assert s.waiting == []


def test_schedule_step_mixed_family_streams_share_one_admission_pass():
    """Heterogeneous streams (the serve analogue of mixed-semiring graph
    jobs): families never partition admission — one global queue spans all
    streams, the shared hot group serves BOTH families in one batch, and
    the per-family mix is reported."""
    sched = ConcurrentServeScheduler(n_groups=8, batch_budget=4, seed=0)
    s_pr = RequestStream(1, family="pagerank")
    s_route = RequestStream(2, family="sssp")
    sched.add_stream(s_pr)
    sched.add_stream(s_route)
    for i in range(3):
        s_pr.add(Request(1, 3, urgency=5.0, tokens_left=10))
        s_route.add(Request(2, 3, urgency=4.0, tokens_left=10))
    s_route.add(Request(2, 6, urgency=0.1, tokens_left=10))
    admitted = sched.schedule_step()
    assert len(admitted) == 4
    # the shared hot group 3 dominates and serves both families
    assert sum(r.group == 3 for r in admitted) >= 3
    assert {r.stream_id for r in admitted} == {1, 2}
    mix = sched.last_admitted_by_family
    assert set(mix) == {"pagerank", "sssp"}
    assert sum(mix.values()) == 4


def test_request_stream_default_family_back_compat():
    s = RequestStream(7)
    assert s.family == "default"
