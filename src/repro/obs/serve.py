"""Serve-layer latency instrumentation (ROADMAP item 3's measurement half).

``ConcurrentServeScheduler`` owns a ``ServeMetrics``: every
``schedule_step`` records per-stream wait time (enqueue -> admission, in
wall seconds AND scheduler steps — the step count is deterministic, so
tests can pin it), per-family queue depth after admission, admitted batch
sizes and global-queue occupancy.  ``complete(request)`` closes the loop
with service time (admission -> completion).  ``summary()`` surfaces
p50/p99 percentiles — the job-latency distribution an SLO-aware admission
policy (Hauck et al., PAPERS.md) needs as its input signal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = ["LatencyStats", "ServeMetrics", "percentile_summary"]


def percentile_summary(samples: List[float]) -> dict:
    """{count, mean, p50, p99, max} of a sample list (empty -> zeros)."""
    if not samples:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    a = np.asarray(samples, dtype=np.float64)
    return {"count": int(a.size), "mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)), "max": float(a.max())}


@dataclasses.dataclass
class LatencyStats:
    """An appendable latency sample set with percentile summaries."""

    samples: List[float] = dataclasses.field(default_factory=list)

    def add(self, value: float) -> None:
        self.samples.append(float(value))

    def summary(self) -> dict:
        return percentile_summary(self.samples)


class ServeMetrics:
    """What the serve scheduler observed; one instance per scheduler."""

    def __init__(self):
        self.wait_steps = LatencyStats()      # enqueue -> admit, in steps
        self.wait_s = LatencyStats()          # enqueue -> admit, wall time
        self.service_s = LatencyStats()       # admit -> complete, wall time
        self.wait_steps_by_stream: Dict[int, LatencyStats] = {}
        self.queue_depth_by_family: Dict[str, List[int]] = {}
        self.admitted_per_step: List[int] = []
        self.gq_occupancy: List[int] = []
        self.steps = 0

    # -- recording hooks (called by ConcurrentServeScheduler) ----------------

    def on_seen(self, req, step: int) -> None:
        """First schedule_step that saw this waiting request."""
        if getattr(req, "_seen_step", None) is None:
            req._seen_step = step
            req._enqueue_ts = getattr(req, "_enqueue_ts",
                                      time.perf_counter())

    def on_admit(self, req, step: int) -> None:
        seen = getattr(req, "_seen_step", step)
        self.wait_steps.add(step - seen)
        self.wait_steps_by_stream.setdefault(
            req.stream_id, LatencyStats()).add(step - seen)
        now = time.perf_counter()
        self.wait_s.add(now - getattr(req, "_enqueue_ts", now))
        req._admit_ts = now

    def on_complete(self, req, service_s: Optional[float] = None) -> None:
        if service_s is None:
            service_s = time.perf_counter() - getattr(
                req, "_admit_ts", time.perf_counter())
        self.service_s.add(service_s)

    def on_step(self, admitted: int, depth_by_family: Dict[str, int],
                gq_occupancy: int) -> None:
        self.steps += 1
        self.admitted_per_step.append(int(admitted))
        self.gq_occupancy.append(int(gq_occupancy))
        for fam, depth in depth_by_family.items():
            self.queue_depth_by_family.setdefault(fam, []).append(int(depth))

    # -- reporting -----------------------------------------------------------

    def summary(self) -> dict:
        """p50/p99 wait & service latency + queue pressure, JSON-ready."""
        return {
            "steps": self.steps,
            "wait_steps": self.wait_steps.summary(),
            "wait_s": self.wait_s.summary(),
            "service_s": self.service_s.summary(),
            "wait_steps_by_stream": {
                str(sid): st.summary()
                for sid, st in sorted(self.wait_steps_by_stream.items())},
            "queue_depth_by_family": {
                fam: {"mean": float(np.mean(d)) if d else 0.0,
                      "max": int(max(d)) if d else 0}
                for fam, d in sorted(self.queue_depth_by_family.items())},
            "admitted": percentile_summary(
                [float(x) for x in self.admitted_per_step]),
            "gq_occupancy": percentile_summary(
                [float(x) for x in self.gq_occupancy]),
        }
