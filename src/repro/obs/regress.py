"""BENCH-trajectory regression gate: ``python -m repro.obs.regress``.

The committed ``BENCH_*.json`` records ARE the repo's performance
trajectory — fig_sync's warm us_per_call, fig_trace's telemetry overhead,
fig_graphscale's halo traffic, fig_serve's latency/throughput curves.
This gate compares freshly produced records against them with per-metric
tolerance bands and exits nonzero when the trajectory regresses, so a PR
cannot silently trade away what an earlier PR measured in.

Metric classes (unlisted metrics are informational and never gated):

  timing    one-sided relative band, default +15% (``--timing-rtol``);
            wall-clock is machine-sensitive, so ``--skip-timing`` drops
            the class entirely (CI compares counters only)
  counter   deterministic under the benchmark seeds — exact by default
            (``rtol=0``), a few carry a small band where float32
            accumulation order can wiggle (halo_bytes)

Direction matters: for most metrics bigger is worse (time, loads,
syncs, supersteps, latency); for completed/throughput smaller is worse.
Only the worse direction fails — getting faster is not a regression.

Exit codes: 0 clean, 1 regression detected, 2 usage/load error.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

__all__ = ["TolSpec", "METRIC_SPECS", "compare_rows", "compare_docs",
           "load_bench_dir", "run_gate", "main"]


@dataclasses.dataclass(frozen=True)
class TolSpec:
    """One gated metric: its class, band, and which direction is worse."""

    kind: str                 # "timing" | "counter"
    rtol: float = 0.0         # one-sided relative band
    atol: float = 1e-9        # absolute slack (floats that should be exact)
    worse: str = "higher"     # "higher" | "lower"


METRIC_SPECS: Dict[str, TolSpec] = {
    # timing (machine-sensitive; skippable)
    "us_per_call": TolSpec("timing", rtol=0.15),
    # deterministic counters — exact under the benchmark seeds
    "supersteps": TolSpec("counter"),
    "tile_loads": TolSpec("counter"),
    "tile_pair_loads": TolSpec("counter"),
    "job_block_pushes": TolSpec("counter"),
    "host_syncs": TolSpec("counter"),
    "series_len": TolSpec("counter"),
    "inc_tile_loads": TolSpec("counter"),
    "restart_tile_loads": TolSpec("counter"),
    "inc_supersteps": TolSpec("counter"),
    "restart_supersteps": TolSpec("counter"),
    "pair_tiles": TolSpec("counter"),
    "max_shard_pair_tiles": TolSpec("counter"),
    # float32 accumulation order can wiggle the last bits across BLAS
    "halo_bytes": TolSpec("counter", rtol=0.01),
    # serve-front SLIs (fig_serve): deterministic in ticks
    "arrivals": TolSpec("counter"),
    "admitted": TolSpec("counter", worse="lower"),
    "completed": TolSpec("counter", worse="lower"),
    "p50_latency_ticks": TolSpec("counter", atol=1e-6),
    "p99_latency_ticks": TolSpec("counter", atol=1e-6),
    "throughput_per_tick": TolSpec("counter", atol=1e-6, worse="lower"),
}


@dataclasses.dataclass
class Violation:
    mode: str
    row: str
    metric: str
    baseline: float
    fresh: float
    limit: float
    kind: str

    def __str__(self) -> str:
        arrow = (">" if METRIC_SPECS[self.metric].worse == "higher"
                 else "<")
        return (f"[{self.mode}/{self.row}] {self.metric}: "
                f"{self.fresh:g} {arrow} allowed {self.limit:g} "
                f"(baseline {self.baseline:g}, {self.kind})")


def _limit(base: float, spec: TolSpec) -> float:
    band = abs(base) * spec.rtol + spec.atol
    return base + band if spec.worse == "higher" else base - band


def compare_rows(mode: str, base_row: dict, fresh_row: dict, *,
                 skip_timing: bool = False,
                 timing_rtol: Optional[float] = None) -> List[Violation]:
    """Gate every spec'd metric present (numerically) in BOTH rows."""
    out: List[Violation] = []
    name = str(base_row.get("name", "?"))
    for metric, spec in METRIC_SPECS.items():
        if spec.kind == "timing":
            if skip_timing:
                continue
            if timing_rtol is not None:
                spec = dataclasses.replace(spec, rtol=timing_rtol)
        b, f = base_row.get(metric), fresh_row.get(metric)
        if not isinstance(b, (int, float)) or isinstance(b, bool):
            continue
        if not isinstance(f, (int, float)) or isinstance(f, bool):
            continue
        limit = _limit(float(b), spec)
        bad = (float(f) > limit if spec.worse == "higher"
               else float(f) < limit)
        if bad:
            out.append(Violation(mode, name, metric, float(b), float(f),
                                 limit, spec.kind))
    return out


def compare_docs(base_doc: dict, fresh_doc: dict, *,
                 skip_timing: bool = False,
                 timing_rtol: Optional[float] = None,
                 require_all: bool = False
                 ) -> Tuple[List[Violation], List[str]]:
    """Match rows by name; returns (violations, warnings)."""
    mode = str(base_doc.get("mode", "?"))
    fresh_rows = {str(r.get("name")): r
                  for r in fresh_doc.get("records", [])}
    violations: List[Violation] = []
    warnings: List[str] = []
    for base_row in base_doc.get("records", []):
        name = str(base_row.get("name"))
        fresh_row = fresh_rows.get(name)
        if fresh_row is None:
            msg = f"[{mode}] row {name!r} missing from fresh records"
            if require_all:
                violations.append(Violation(mode, name, "<row>", 1.0, 0.0,
                                            1.0, "missing"))
            warnings.append(msg)
            continue
        violations.extend(compare_rows(mode, base_row, fresh_row,
                                       skip_timing=skip_timing,
                                       timing_rtol=timing_rtol))
    return violations, warnings


def load_bench_dir(path: str, modes: Optional[List[str]] = None
                   ) -> Dict[str, dict]:
    """All BENCH_<mode>.json docs in `path`, keyed by mode."""
    docs: Dict[str, dict] = {}
    for fn in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        with open(fn) as f:
            doc = json.load(f)
        mode = str(doc.get("mode",
                           os.path.basename(fn)[len("BENCH_"):-len(".json")]))
        if modes and mode not in modes:
            continue
        docs[mode] = doc
    return docs


def run_gate(baseline_dir: str, fresh_dir: str, *,
             modes: Optional[List[str]] = None, skip_timing: bool = False,
             timing_rtol: Optional[float] = None, require_all: bool = False
             ) -> dict:
    """The gate as a callable (the CLI is a thin shell around this)."""
    baseline = load_bench_dir(baseline_dir, modes)
    if not baseline:
        raise FileNotFoundError(
            f"no BENCH_*.json records under {baseline_dir!r}"
            + (f" for modes {modes}" if modes else ""))
    fresh = load_bench_dir(fresh_dir, modes)
    violations: List[Violation] = []
    warnings: List[str] = []
    compared: List[str] = []
    for mode, base_doc in sorted(baseline.items()):
        fresh_doc = fresh.get(mode)
        if fresh_doc is None:
            msg = f"[{mode}] no fresh record in {fresh_dir!r}"
            if require_all:
                violations.append(Violation(mode, "<doc>", "<doc>", 1.0,
                                            0.0, 1.0, "missing"))
            warnings.append(msg)
            continue
        compared.append(mode)
        v, w = compare_docs(base_doc, fresh_doc, skip_timing=skip_timing,
                            timing_rtol=timing_rtol,
                            require_all=require_all)
        violations.extend(v)
        warnings.extend(w)
    return {"compared_modes": compared,
            "violations": violations,
            "warnings": warnings,
            "ok": not violations}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="gate fresh BENCH_*.json records against the "
                    "committed perf trajectory")
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed BENCH_*.json "
                         "(default: repo root)")
    ap.add_argument("--fresh", default=None,
                    help="dir holding freshly produced records "
                         "(default: --baseline, i.e. a self-gate)")
    ap.add_argument("--modes", default=None,
                    help="comma-separated mode filter "
                         "(e.g. fig_sync,fig_trace)")
    ap.add_argument("--skip-timing", action="store_true",
                    help="gate deterministic counters only")
    ap.add_argument("--timing-rtol", type=float, default=None,
                    help="override the timing band (default 0.15)")
    ap.add_argument("--require-all", action="store_true",
                    help="a baseline mode/row missing from fresh is a "
                         "failure, not a warning")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the verdict as JSON")
    args = ap.parse_args(argv)

    try:
        result = run_gate(
            args.baseline, args.fresh or args.baseline,
            modes=args.modes.split(",") if args.modes else None,
            skip_timing=args.skip_timing, timing_rtol=args.timing_rtol,
            require_all=args.require_all)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"regress: error: {e}", file=sys.stderr)
        return 2

    for w in result["warnings"]:
        print(f"regress: warning: {w}")
    print(f"regress: compared modes: "
          f"{', '.join(result['compared_modes']) or '(none)'}")
    for v in result["violations"]:
        print(f"regress: REGRESSION {v}")
    verdict = "OK" if result["ok"] else \
        f"FAIL ({len(result['violations'])} regression(s))"
    print(f"regress: {verdict}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"ok": result["ok"],
                       "compared_modes": result["compared_modes"],
                       "warnings": result["warnings"],
                       "violations": [dataclasses.asdict(v)
                                      for v in result["violations"]]},
                      f, indent=2)
            f.write("\n")
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
