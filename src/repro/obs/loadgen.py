"""Deterministic open-loop load generation for the serve front.

The paper's claim — two-level scheduling accelerates the convergence of
CONCURRENT jobs — is only testable under sustained traffic.  This module
supplies it, open-loop (Hauck et al., PAPERS.md; the arrival schedule is
fixed up front and never reacts to service time, so a slow scheduler
builds queue instead of quietly throttling its own offered load):

  generate_arrivals  seeded Poisson base rate modulated by a diurnal
                     burst envelope; hundreds of tenants, each pinned to
                     one algorithm family drawn from a weighted mix
  OpenLoopHarness    drives a long-lived GraphSession and a
                     ConcurrentServeScheduler pair tick by tick: inject
                     arrivals -> schedule_step() admits -> each admitted
                     request submits a REAL algorithm job into the shared
                     session -> supersteps advance -> converged jobs
                     complete() and detach.  Optionally interleaves
                     seeded `UpdateBatch` graph mutations and forwards
                     the dirty blocks to `notify_group_update`, closing
                     the update loop across BOTH layers.

Every random draw comes from `np.random.default_rng(cfg.seed)` (RPA004)
and every latency is counted in scheduler TICKS, so two runs with one
seed produce bit-identical admission and completion sequences — the
property the fig_serve benchmark and the regression gate stand on.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.algorithms import (BFS, Katz, PageRank, PersonalizedPageRank,
                              SSSP, WCC)

__all__ = ["LoadgenConfig", "Arrival", "generate_arrivals",
           "OpenLoopHarness", "FAMILY_FACTORIES"]


# family name -> factory(source_vertex) for the job an admitted request
# submits; source-free families ignore the argument
FAMILY_FACTORIES = {
    "pagerank": lambda src: PageRank(),
    "ppr": lambda src: PersonalizedPageRank(source=src),
    "sssp": lambda src: SSSP(source=src),
    "bfs": lambda src: BFS(source=src),
    "wcc": lambda src: WCC(),
    "katz": lambda src: Katz(),
}


@dataclasses.dataclass(frozen=True)
class LoadgenConfig:
    """Open-loop traffic shape (everything derives from `seed`).

    ticks            arrival horizon in scheduler ticks
    base_rate        mean arrivals per tick (Poisson)
    burst_amplitude  diurnal envelope: rate(t) = base_rate *
                     max(0, 1 + amplitude * sin(2*pi*t / burst_period))
    n_tenants        tenant population; each tenant is pinned to one
                     algorithm family at generation time
    families         (name, weight) mix the tenants draw from; names must
                     be FAMILY_FACTORIES keys
    update_every     interleave one seeded UpdateBatch every N ticks
                     (0 = static graph)
    """

    seed: int = 0
    ticks: int = 400
    base_rate: float = 0.5
    burst_amplitude: float = 0.6
    burst_period: int = 200
    n_tenants: int = 100
    families: Tuple[Tuple[str, float], ...] = (
        ("pagerank", 0.35), ("ppr", 0.25), ("sssp", 0.25), ("bfs", 0.15))
    update_every: int = 0
    update_inserts: int = 8
    update_deletes: int = 4

    def __post_init__(self):
        if self.ticks < 1 or self.n_tenants < 1:
            raise ValueError("ticks and n_tenants must be >= 1")
        if self.base_rate <= 0:
            raise ValueError(f"base_rate must be > 0: {self.base_rate}")
        for name, w in self.families:
            if name not in FAMILY_FACTORIES:
                raise ValueError(f"unknown family {name!r} "
                                 f"(have {sorted(FAMILY_FACTORIES)})")
            if w <= 0:
                raise ValueError(f"family weight must be > 0: {name}={w}")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: fixed before the run, never rescheduled."""

    tick: int       # when it enters its tenant's waiting queue
    tenant: int     # RequestStream id
    family: str     # the tenant's algorithm family
    group: int      # request group == graph block id
    source: int     # source vertex for source-parameterized families
    urgency: float  # higher = more urgent (scheduler P_mean input)


def generate_arrivals(cfg: LoadgenConfig, n_groups: int,
                      n_vertices: int) -> List[Arrival]:
    """The full arrival schedule, bit-deterministic under cfg.seed."""
    if n_groups < 1 or n_vertices < 1:
        raise ValueError("n_groups and n_vertices must be >= 1")
    rng = np.random.default_rng(cfg.seed)
    names = [n for n, _ in cfg.families]
    weights = np.asarray([w for _, w in cfg.families], dtype=np.float64)
    weights = weights / weights.sum()
    tenant_family = rng.choice(len(names), size=cfg.n_tenants, p=weights)
    arrivals: List[Arrival] = []
    for t in range(cfg.ticks):
        envelope = 1.0 + cfg.burst_amplitude * math.sin(
            2.0 * math.pi * t / max(1, cfg.burst_period))
        rate = cfg.base_rate * max(0.0, envelope)
        for _ in range(int(rng.poisson(rate))):
            tenant = int(rng.integers(cfg.n_tenants))
            arrivals.append(Arrival(
                tick=t, tenant=tenant,
                family=names[int(tenant_family[tenant])],
                group=int(rng.integers(n_groups)),
                source=int(rng.integers(n_vertices)),
                urgency=float(np.round(rng.uniform(0.1, 1.0), 6))))
    return arrivals


class OpenLoopHarness:
    """Drive a GraphSession + ConcurrentServeScheduler under open loop.

    `max_running` is the inter-job parallelism knob (Hauck et al.'s
    trade-off axis): at most that many admitted jobs share the session's
    supersteps concurrently; the admission budget each tick is the free
    headroom.  One tick = one `schedule_step()` (the deterministic wait /
    latency clock) + `supersteps_per_tick` shared supersteps when any job
    is live + one convergence poll.  The arrival schedule is precomputed;
    nothing about service time feeds back into it."""

    def __init__(self, sess, sched, cfg: LoadgenConfig, *,
                 policy=None, max_running: int = 8,
                 supersteps_per_tick: int = 1,
                 drain_ticks: int = 50_000):
        from repro.core.policy import TwoLevel
        # before the first submit the session has no scheduler yet; the
        # block count is still fixed by (n, block_size)
        num_blocks = (sess.scheduler.num_blocks if sess.scheduler
                      else -(-int(sess._csr.n) // int(sess.block_size)))
        if sched.n_groups != num_blocks:
            raise ValueError(
                f"scheduler n_groups ({sched.n_groups}) must equal the "
                f"session's block count ({num_blocks}) — "
                "request groups ARE graph blocks")
        if max_running < 1:
            raise ValueError(f"max_running must be >= 1: {max_running}")
        self.sess = sess
        self.sched = sched
        self.cfg = cfg
        self.policy = TwoLevel() if policy is None else policy
        self.max_running = int(max_running)
        self.supersteps_per_tick = int(supersteps_per_tick)
        self.drain_ticks = int(drain_ticks)
        self.arrivals = generate_arrivals(
            cfg, n_groups=sched.n_groups, n_vertices=int(sess._csr.n))
        # deterministic run records (the determinism property is asserted
        # on these two sequences)
        self.admission_log: List[tuple] = []
        self.completion_log: List[tuple] = []
        self.ticks_run = 0
        self.supersteps_run = 0
        self.updates_applied = 0
        self._counters = {"tile_loads": 0, "tile_pair_loads": 0,
                          "job_block_pushes": 0, "host_syncs": 0,
                          "halo_bytes": 0.0}

    # -- internals -----------------------------------------------------------

    def _ensure_stream(self, arr: Arrival):
        from repro.serve.concurrent import RequestStream
        if arr.tenant not in self.sched.streams:
            self.sched.add_stream(RequestStream(arr.tenant,
                                                family=arr.family))

    def _inject(self, tick: int, cursor: int) -> int:
        from repro.serve.concurrent import Request
        while cursor < len(self.arrivals) \
                and self.arrivals[cursor].tick <= tick:
            arr = self.arrivals[cursor]
            self._ensure_stream(arr)
            req = Request(stream_id=arr.tenant, group=arr.group,
                          urgency=arr.urgency, tokens_left=1)
            req._arrival = arr
            self.sched.streams[arr.tenant].add(req)
            cursor += 1
        return cursor

    def _apply_update(self, tick: int) -> None:
        from repro.graph.generators import mutation_stream
        batch = mutation_stream(
            self.sess._csr, n_batches=1,
            inserts_per_batch=self.cfg.update_inserts,
            deletes_per_batch=self.cfg.update_deletes,
            seed=self.cfg.seed + 7919 * (tick + 1))[0]
        self.sess.apply_updates(batch)
        self.updates_applied += 1
        boost = getattr(self.sess, "_dirty_boost", None)
        if boost is not None:
            dirty = np.nonzero(np.asarray(boost) > 0)[0]
            if dirty.size:
                self.sched.notify_group_update(dirty.tolist())

    def _accumulate(self, m) -> None:
        self.supersteps_run += int(m.supersteps)
        self._counters["tile_loads"] += int(m.tile_loads)
        self._counters["tile_pair_loads"] += int(m.tile_pair_loads)
        self._counters["job_block_pushes"] += int(m.job_block_pushes)
        self._counters["host_syncs"] += int(m.host_syncs)
        self._counters["halo_bytes"] += float(m.halo_bytes)

    # -- the drive loop ------------------------------------------------------

    def run(self) -> dict:
        """Run arrivals + drain; returns the deterministic summary."""
        running: Dict[int, tuple] = {}   # id(req) -> (req, handle, tick)
        cursor = 0
        tick = 0
        total = len(self.arrivals)
        while True:
            horizon_done = tick >= self.cfg.ticks
            if not horizon_done:
                cursor = self._inject(tick, cursor)
                if self.cfg.update_every and tick > 0 \
                        and tick % self.cfg.update_every == 0:
                    self._apply_update(tick)
            waiting = sum(len(s.waiting)
                          for s in self.sched.streams.values())
            if horizon_done and not running and not waiting:
                break
            if horizon_done and tick >= self.cfg.ticks + self.drain_ticks:
                break   # bounded drain: report whatever is still in flight
            # admission budget = free inter-job headroom this tick; the
            # step runs even at 0 so the wait/latency clock keeps ticking
            self.sched.batch_budget = max(
                0, self.max_running - len(running))
            for req in self.sched.schedule_step():
                arr = req._arrival
                alg = FAMILY_FACTORIES[arr.family](arr.source)
                handle = self.sess.submit(alg)
                running[id(req)] = (req, handle, tick)
                self.admission_log.append(
                    (tick, arr.tick, arr.tenant, arr.family, arr.group))
            if running:
                m = self.sess.run(self.policy,
                                  max_supersteps=self.supersteps_per_tick)
                self._accumulate(m)
                counts = self.sess.unconverged_counts()
                for key in sorted(
                        running,
                        key=lambda k: self.sess.job_index(running[k][1])):
                    req, handle, t_admit = running[key]
                    if counts[self.sess.job_index(handle)] == 0:
                        # the deterministic clock: service time in ticks
                        self.sched.complete(
                            req, service_s=float(tick + 1 - t_admit))
                        self.sess.detach(handle)
                        arr = req._arrival
                        self.completion_log.append(
                            (tick + 1, arr.tenant, arr.family,
                             tick + 1 - arr.tick))
                        del running[key]
            tick += 1
        self.ticks_run = tick
        return self.summary()

    # -- reporting -----------------------------------------------------------

    def summary(self) -> dict:
        from repro.obs.serve import percentile_summary
        lat_all = [float(c[3]) for c in self.completion_log]
        by_family: Dict[str, List[float]] = {}
        for _, _, fam, lat in self.completion_log:
            by_family.setdefault(fam, []).append(float(lat))
        return {
            "arrivals": len(self.arrivals),
            "admitted": len(self.admission_log),
            "completed": len(self.completion_log),
            "ticks": self.ticks_run,
            "supersteps": self.supersteps_run,
            "updates_applied": self.updates_applied,
            "max_running": self.max_running,
            "throughput_per_tick": (
                round(len(self.completion_log) / self.ticks_run, 6)
                if self.ticks_run else 0.0),
            "latency_ticks": percentile_summary(lat_all),
            "latency_by_family": {
                fam: percentile_summary(v)
                for fam, v in sorted(by_family.items())},
            "counters": {k: (round(v, 3) if isinstance(v, float) else v)
                         for k, v in self._counters.items()},
        }
