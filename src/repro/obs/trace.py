"""Structured trace events, exported as Chrome/Perfetto trace-event JSON.

A ``TraceRecorder`` collects the discrete story of a session — job
submit/detach, run and superstep spans, apply_updates batches, overlay
compactions, serve admissions — as Trace Event Format records
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):

  ph="X"  complete span (ts + dur)
  ph="i"  instant event
  ph="C"  counter track (per-superstep telemetry series)
  ph="M"  metadata (process/thread names, emitted at export)

``export(path)`` writes ``{"traceEvents": [...]}`` — loadable in
chrome://tracing and https://ui.perfetto.dev as-is.  Timestamps are
microseconds on a perf_counter clock anchored at recorder creation.

Recording is cheap (an appended dict per event) but still gated on
``enabled`` so telemetry-off sessions pay literally nothing; a disabled
recorder's export writes an empty-but-valid trace.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, List, Optional

__all__ = ["TraceRecorder", "validate_trace_events"]

# phases this recorder emits (export-time schema guarantee)
_PHASES = ("X", "i", "C", "M")

REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


class TraceRecorder:
    """Append-only trace-event collector with a session-local clock."""

    def __init__(self, enabled: bool = True, *, pid: int = 1):
        self.enabled = enabled
        self.pid = pid
        self.events: List[dict] = []
        self._t0 = time.perf_counter()
        self._thread_names: Dict[int, str] = {1: "session"}

    # -- clock ---------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since recorder creation (the trace timebase)."""
        return (time.perf_counter() - self._t0) * 1e6

    # -- event emitters ------------------------------------------------------

    def _emit(self, ev: dict) -> None:
        self.events.append(ev)

    def instant(self, name: str, cat: str = "session",
                ts_us: Optional[float] = None, tid: int = 1, **args) -> None:
        """One instant event (ph='i'), e.g. a job submit or a compaction."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self.now_us() if ts_us is None else ts_us,
                    "pid": self.pid, "tid": tid, "args": args})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "session", tid: int = 1, **args) -> None:
        """A finished span (ph='X') with explicit start/duration."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "X", "ts": ts_us,
                    "dur": max(dur_us, 0.0), "pid": self.pid, "tid": tid,
                    "args": args})

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "session", tid: int = 1, **args):
        """Context manager emitting one complete span around the body."""
        if not self.enabled:
            yield
            return
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, self.now_us() - t0, cat=cat, tid=tid,
                          **args)

    def counter(self, name: str, values: Dict[str, float],
                ts_us: Optional[float] = None, cat: str = "telemetry",
                tid: int = 1) -> None:
        """One counter sample (ph='C'); each key renders as a track."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "C",
                    "ts": self.now_us() if ts_us is None else ts_us,
                    "pid": self.pid, "tid": tid,
                    "args": {k: float(v) for k, v in values.items()}})

    def name_thread(self, tid: int, name: str) -> None:
        self._thread_names[tid] = name

    # -- export --------------------------------------------------------------

    def _metadata(self) -> List[dict]:
        meta = [{"name": "process_name", "ph": "M", "ts": 0.0, "pid": self.pid,
                 "tid": 1, "args": {"name": "repro.GraphSession"}}]
        for tid, name in sorted(self._thread_names.items()):
            meta.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                         "pid": self.pid, "tid": tid, "args": {"name": name}})
        return meta

    def to_json(self) -> dict:
        # ts-sorted: chrome://tracing tolerates disorder, Perfetto's JSON
        # importer is stricter about counter tracks
        events = self._metadata() + sorted(self.events,
                                           key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace-event JSON file; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    def clear(self) -> None:
        self.events.clear()


def validate_trace_events(doc: dict) -> int:
    """Schema-check an exported trace document; returns the event count.

    Raises ValueError on the first malformed event — used by tests and the
    fig_trace benchmark to prove the export loads in Chrome/Perfetto.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must have a traceEvents list")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(events):
        for k in REQUIRED_KEYS:
            if k not in ev:
                raise ValueError(f"event {i} missing key {k!r}: {ev}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {i} missing dur: {ev}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has invalid ts: {ev['ts']!r}")
    return len(events)
