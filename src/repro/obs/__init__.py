"""repro.obs — session-wide observability (telemetry, traces, serve SLIs).

Three parts, one opt-in switch:

  telemetry  - ``GraphSession(telemetry=True | TelemetryConfig(...))``
               records a fixed-schema per-superstep ``TelemetrySeries``
               (returned on ``RunMetrics.telemetry``); on the device
               backend the series rides the scan carry, so a
               ``steps_per_sync=inf`` run still syncs exactly once.
  trace      - every session owns a ``TraceRecorder`` (``session.trace``)
               collecting submit/detach, superstep spans, apply_updates
               batches and compactions; ``session.trace.export(path)``
               writes Chrome/Perfetto trace-event JSON.
  serve      - ``ConcurrentServeScheduler.metrics`` records per-stream
               wait/service time and per-family queue depth with p50/p99
               summaries (the SLO signal of ROADMAP item 3).

On top of those, the serve-front SLO layer (ROADMAP item 3's production
half): ``loadgen`` generates deterministic open-loop arrival traffic and
drives a GraphSession + ConcurrentServeScheduler pair; ``slo`` tracks
sliding-window SLIs per family/tenant against declared ``SLOTarget``s and
snapshots every metrics source through a ``MetricsRegistry`` (JSON +
Prometheus text); ``python -m repro.obs.regress`` gates fresh benchmark
records against the committed BENCH_*.json trajectory.

Telemetry off (the default) compiles to the exact pre-observability
programs: the jitted superstep carries no buffers and fixpoints are
bitwise identical (pinned in tests/test_obs.py).
"""

from repro.obs.telemetry import (TelemetryConfig, TelemetrySeries,
                                 HostSeriesBuilder, device_buffers,
                                 device_write, series_from_device,
                                 SERIES_FIELDS, GROUP_FIELDS)
from repro.obs.trace import TraceRecorder, validate_trace_events
from repro.obs.serve import LatencyStats, ServeMetrics, percentile_summary
from repro.obs.slo import (SlidingWindowLatency, SLOTarget, SLOTracker,
                           MetricsRegistry, validate_registry_snapshot,
                           REGISTRY_SCHEMA)
from repro.obs.loadgen import (LoadgenConfig, Arrival, generate_arrivals,
                               OpenLoopHarness)

__all__ = [
    "TelemetryConfig", "TelemetrySeries", "HostSeriesBuilder",
    "device_buffers", "device_write", "series_from_device",
    "SERIES_FIELDS", "GROUP_FIELDS",
    "TraceRecorder", "validate_trace_events",
    "LatencyStats", "ServeMetrics", "percentile_summary",
    "SlidingWindowLatency", "SLOTarget", "SLOTracker",
    "MetricsRegistry", "validate_registry_snapshot", "REGISTRY_SCHEMA",
    "LoadgenConfig", "Arrival", "generate_arrivals", "OpenLoopHarness",
]
