"""Per-superstep telemetry: a fixed-schema time series over one run().

The paper's two claims — CAJS removes redundant data access, MPDS
accelerates convergence — are only honest as CURVES: when in a run the
cache-sharing wins happen, how global-queue occupancy and per-family
residuals evolve, where a live update batch re-ignites work.  A
``TelemetrySeries`` records, per superstep:

  active_jobs       [K]    jobs with pending work this superstep
  tile_loads        [K]    adjacency-block stagings this superstep
  job_block_pushes  [K]    (job, block) processing events this superstep
  tile_pair_loads   [K]    nonzero block-pair stagings this superstep (the
                           CAJS sharing denominator; see RunMetrics)
  halo_bytes        [K]    frontier bytes exchanged across block shards this
                           superstep (0 off the 2D mesh)
  gq_occupancy      [K]    staged-selection occupancy (shared policies:
                           global-queue length <= q; independent: total
                           per-job queue entries)
  dirty_blocks      [K]    update-affected blocks boosted this superstep
                           (nonzero only on the first superstep after
                           apply_updates)
  unconverged       [K, G] unconverged-vertex count per view group
  max_residual      [K, G] max vertex priority per view group (plus-times:
                           max |delta| above tolerance; min-plus: max
                           1/(1+dist) over pending vertices)

Collection is OPT-IN via ``GraphSession(telemetry=...)`` and costs nothing
when off: the host driver skips the bookkeeping and the device driver
compiles the buffers out of the cached superstep entirely (the jit-cache
key carries the telemetry capacity, so on/off sessions never share or
invalidate each other's compilation).

On the device path the series rides the scan carry as preallocated
``[capacity]`` buffers written at index min(superstep, capacity-1), so
``TwoLevel(backend="device", steps_per_sync=inf)`` returns the FULL series
at exactly one host sync.  Runs longer than ``capacity`` supersteps keep
converging correctly; the series is marked ``truncated`` and the overflow
steps collapse into the last row.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np
import jax.numpy as jnp

__all__ = ["TelemetryConfig", "TelemetrySeries", "HostSeriesBuilder",
           "device_buffers", "device_write", "series_from_device",
           "SERIES_FIELDS", "GROUP_FIELDS"]

# the fixed schema: per-superstep scalars ...
SERIES_FIELDS = ("active_jobs", "tile_loads", "job_block_pushes",
                 "gq_occupancy", "dirty_blocks", "tile_pair_loads",
                 "halo_bytes")
# ... and per-(superstep, view-group) columns
GROUP_FIELDS = ("unconverged", "max_residual")

DEFAULT_CAPACITY = 4096


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """What ``GraphSession(telemetry=...)`` turns on.

    capacity      device-path buffer length (finite so the series can ride
                  a while_loop carry; ~30 bytes/superstep)
    trace         record structured trace events on ``session.trace``
                  (submit/detach, superstep spans, apply_updates batches,
                  compactions) for Chrome/Perfetto export
    jax_profiler  additionally wrap scheduling dispatches in
                  jax.profiler.TraceAnnotation spans (visible in a
                  jax.profiler trace; off by default — it is only useful
                  under an active profiler session)
    """

    capacity: int = DEFAULT_CAPACITY
    trace: bool = True
    jax_profiler: bool = False

    @staticmethod
    def coerce(value: Union[None, bool, "TelemetryConfig"]
               ) -> Optional["TelemetryConfig"]:
        """None/False -> disabled; True -> defaults; a config -> itself."""
        if value is None or value is False:
            return None
        if value is True:
            return TelemetryConfig()
        if isinstance(value, TelemetryConfig):
            return value
        raise TypeError(
            f"telemetry must be None, bool or TelemetryConfig: {value!r}")


@dataclasses.dataclass
class TelemetrySeries:
    """One run()'s per-superstep series (numpy, host-side)."""

    view_keys: Tuple[tuple, ...]
    active_jobs: np.ndarray        # [K] int64
    tile_loads: np.ndarray         # [K] int64
    job_block_pushes: np.ndarray   # [K] int64
    gq_occupancy: np.ndarray       # [K] int64
    dirty_blocks: np.ndarray       # [K] int64
    tile_pair_loads: np.ndarray    # [K] int64
    halo_bytes: np.ndarray         # [K] float64
    unconverged: np.ndarray        # [K, G] int64
    max_residual: np.ndarray       # [K, G] float32
    truncated: bool = False        # device buffer overflowed (capacity < K)

    def __len__(self) -> int:
        return int(self.active_jobs.shape[0])

    @property
    def num_groups(self) -> int:
        return int(self.unconverged.shape[1])

    def to_dict(self) -> dict:
        """JSON-ready dict (used by the trace exporter and benchmarks)."""
        d = {"schema": list(SERIES_FIELDS) + list(GROUP_FIELDS),
             "supersteps": len(self),
             "view_keys": [list(map(str, k)) for k in self.view_keys],
             "truncated": self.truncated}
        for f in SERIES_FIELDS:
            d[f] = getattr(self, f).tolist()
        d["halo_bytes"] = [round(float(x), 6) for x in self.halo_bytes]
        d["unconverged"] = self.unconverged.tolist()
        d["max_residual"] = [[round(float(x), 8) for x in row]
                             for row in self.max_residual]
        return d


class HostSeriesBuilder:
    """Per-superstep appender for the host driver (python lists)."""

    def __init__(self, view_keys: Sequence[tuple]):
        self.view_keys = tuple(view_keys)
        self._rows: List[tuple] = []

    def append(self, active_jobs: int, tile_loads: int,
               job_block_pushes: int, gq_occupancy: int, dirty_blocks: int,
               unconverged: Sequence[int],
               max_residual: Sequence[float],
               tile_pair_loads: int = 0, halo_bytes: float = 0.0) -> None:
        self._rows.append((int(active_jobs), int(tile_loads),
                           int(job_block_pushes), int(gq_occupancy),
                           int(dirty_blocks),
                           int(tile_pair_loads), float(halo_bytes),
                           tuple(int(u) for u in unconverged),
                           tuple(float(r) for r in max_residual)))

    def build(self) -> TelemetrySeries:
        g = len(self.view_keys)
        k = len(self._rows)
        cols = list(zip(*self._rows)) if k else [()] * 9
        return TelemetrySeries(
            view_keys=self.view_keys,
            active_jobs=np.asarray(cols[0], dtype=np.int64),
            tile_loads=np.asarray(cols[1], dtype=np.int64),
            job_block_pushes=np.asarray(cols[2], dtype=np.int64),
            gq_occupancy=np.asarray(cols[3], dtype=np.int64),
            dirty_blocks=np.asarray(cols[4], dtype=np.int64),
            tile_pair_loads=np.asarray(cols[5], dtype=np.int64),
            halo_bytes=np.asarray(cols[6], dtype=np.float64),
            unconverged=np.asarray(cols[7], dtype=np.int64).reshape(k, g),
            max_residual=np.asarray(cols[8], dtype=np.float32).reshape(k, g))


# ---------------------------------------------------------------------------
# device-path buffers (ride the scan/while_loop carry)
# ---------------------------------------------------------------------------


def device_buffers(capacity: int, n_groups: int):
    """Preallocated [capacity] buffers for the jitted superstep carry."""
    z = jnp.zeros
    return (z(capacity, jnp.int32),               # active_jobs
            z(capacity, jnp.int32),               # tile_loads
            z(capacity, jnp.int32),               # job_block_pushes
            z(capacity, jnp.int32),               # gq_occupancy
            z(capacity, jnp.int32),               # dirty_blocks
            z(capacity, jnp.int32),               # tile_pair_loads
            z(capacity, jnp.float32),             # halo_bytes
            z((capacity, n_groups), jnp.int32),   # unconverged
            z((capacity, n_groups), jnp.float32))  # max_residual


def device_write(bufs, idx, active_jobs, tile_loads, job_block_pushes,
                 gq_occupancy, dirty_blocks, unconverged, max_residual,
                 tile_pair_loads=0, halo_bytes=0.0):
    """Write superstep `idx`'s row (traced; idx pre-clamped by the caller).

    Overflow rows alias the LAST slot (`.set` keeps the newest write), so
    a truncated series still ends at the run's final state.
    """
    a, t, p, o, d, pl, h, u, r = bufs
    scalars = (active_jobs, tile_loads, job_block_pushes, gq_occupancy,
               dirty_blocks, tile_pair_loads)
    a, t, p, o, d, pl = (b.at[idx].set(jnp.asarray(v, jnp.int32))
                         for b, v in zip((a, t, p, o, d, pl), scalars))
    h = h.at[idx].set(jnp.asarray(halo_bytes, jnp.float32))
    u = u.at[idx].set(jnp.asarray(unconverged, jnp.int32))
    r = r.at[idx].set(jnp.asarray(max_residual, jnp.float32))
    return (a, t, p, o, d, pl, h, u, r)


def series_from_device(bufs, supersteps: int,
                       view_keys: Sequence[tuple]) -> TelemetrySeries:
    """Slice the carried buffers down to the executed supersteps."""
    cap = int(bufs[0].shape[0])
    k = min(int(supersteps), cap)
    a, t, p, o, d, pl, h, u, r = (np.asarray(b)[:k] for b in bufs)
    return TelemetrySeries(
        view_keys=tuple(view_keys),
        active_jobs=a.astype(np.int64), tile_loads=t.astype(np.int64),
        job_block_pushes=p.astype(np.int64),
        gq_occupancy=o.astype(np.int64), dirty_blocks=d.astype(np.int64),
        tile_pair_loads=pl.astype(np.int64), halo_bytes=h.astype(np.float64),
        unconverged=u.astype(np.int64), max_residual=r.astype(np.float32),
        truncated=int(supersteps) > cap)
