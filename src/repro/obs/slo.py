"""SLO tracking and a metrics registry for the serve front (ROADMAP item 3).

``ServeMetrics`` (repro.obs.serve) answers "what happened since the
scheduler was born"; an operator needs "are we inside our targets RIGHT
NOW".  This module adds that second view without duplicating the first:

  SlidingWindowLatency  a ``LatencyStats`` whose sample set is the last
                        `window` observations only
  SLOTarget             declared per-family objectives: p50/p99 job
                        latency (in scheduler steps, enqueue->complete),
                        per-request deadlines, minimum throughput, maximum
                        queue depth
  SLOTracker            per-family AND per-tenant sliding-window SLIs,
                        evaluated against the declared targets; attach it
                        via ``ConcurrentServeScheduler(slo=...)`` and it
                        rides the same on_seen/on_admit/on_complete hooks
                        (and the same ``req._seen_step`` stamps) as
                        ServeMetrics
  MetricsRegistry       one snapshot() over every registered source
                        (ServeMetrics, SLOTracker, TelemetrySeries,
                        RunMetrics, plain dicts) to schema-validated JSON
                        or Prometheus text exposition

Latencies are counted in SCHEDULER STEPS, not wall seconds: steps are
deterministic under a fixed seed, so the fig_serve benchmark curves —
and the regression gate anchored on them — reproduce bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.obs.serve import LatencyStats, percentile_summary

__all__ = ["SlidingWindowLatency", "SLOTarget", "SLOTracker",
           "MetricsRegistry", "validate_registry_snapshot",
           "REGISTRY_SCHEMA"]

REGISTRY_SCHEMA = "repro.obs.registry/v1"

_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.:-]*$")


class SlidingWindowLatency(LatencyStats):
    """``LatencyStats`` over the most recent `window` samples.

    Extends (not re-implements) the base class: ``summary()`` and the
    ``samples`` list keep their meaning; only retention changes."""

    def __init__(self, window: int = 512):
        super().__init__()
        if window < 1:
            raise ValueError(f"window must be >= 1: {window}")
        self.window = int(window)

    def add(self, value: float) -> None:
        super().add(value)
        if len(self.samples) > self.window:
            del self.samples[: len(self.samples) - self.window]


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Declared objectives for one algorithm family (``"*"`` = catch-all).

    All latencies are in scheduler steps (enqueue -> complete).  ``None``
    disables that clause.  ``min_throughput`` is completions per step over
    the tracker's sliding window; ``deadline_steps`` is a PER-REQUEST
    deadline — each completion past it counts one violation."""

    family: str = "*"
    p50_latency_steps: Optional[float] = None
    p99_latency_steps: Optional[float] = None
    deadline_steps: Optional[float] = None
    min_throughput: Optional[float] = None
    max_queue_depth: Optional[int] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SLOTracker:
    """Sliding-window SLIs per family and per tenant, judged vs targets.

    Wire it with ``ConcurrentServeScheduler(slo=tracker)``; the scheduler
    calls the hooks below alongside its ServeMetrics.  The tracker shares
    the metrics' ``req._seen_step`` stamp (idempotent first-seen), so the
    two views never disagree on when a request entered the system."""

    def __init__(self, targets: Iterable[SLOTarget] = (),
                 window: int = 512):
        self.window = int(window)
        self.targets: Tuple[SLOTarget, ...] = tuple(targets)
        by_fam = {}
        for t in self.targets:
            if t.family in by_fam:
                raise ValueError(f"duplicate SLOTarget family: {t.family}")
            by_fam[t.family] = t
        self._target_by_family: Dict[str, SLOTarget] = by_fam
        self.latency_by_family: Dict[str, SlidingWindowLatency] = {}
        self.latency_by_tenant: Dict[int, SlidingWindowLatency] = {}
        self.wait_by_family: Dict[str, SlidingWindowLatency] = {}
        # (completion step, family) pairs inside the window (throughput SLI)
        self._completions: deque = deque()
        self.queue_depth_by_family: Dict[str, deque] = {}
        self.deadline_violations: Dict[str, int] = {}
        self.completed: int = 0
        self.steps: int = 0

    # -- target resolution ---------------------------------------------------

    def target_for(self, family: str) -> Optional[SLOTarget]:
        """Exact family match first, else the ``"*"`` catch-all."""
        t = self._target_by_family.get(family)
        return t if t is not None else self._target_by_family.get("*")

    # -- recording hooks (called by ConcurrentServeScheduler) ----------------

    def on_seen(self, req, step: int) -> None:
        """Same first-seen stamp as ServeMetrics.on_seen (idempotent)."""
        if getattr(req, "_seen_step", None) is None:
            req._seen_step = step

    def on_admit(self, req, family: str, step: int) -> None:
        seen = getattr(req, "_seen_step", step)
        self.wait_by_family.setdefault(
            family, SlidingWindowLatency(self.window)).add(step - seen)

    def on_complete(self, req, family: str, step: int) -> None:
        seen = getattr(req, "_seen_step", step)
        latency = float(step - seen)
        self.latency_by_family.setdefault(
            family, SlidingWindowLatency(self.window)).add(latency)
        self.latency_by_tenant.setdefault(
            int(req.stream_id), SlidingWindowLatency(self.window)).add(
                latency)
        self._completions.append((int(step), family))
        self.completed += 1
        t = self.target_for(family)
        if t is not None and t.deadline_steps is not None \
                and latency > t.deadline_steps:
            self.deadline_violations[family] = (
                self.deadline_violations.get(family, 0) + 1)

    def on_step(self, step: int, depth_by_family: Dict[str, int]) -> None:
        self.steps = max(self.steps, int(step) + 1)
        for fam, depth in depth_by_family.items():
            dq = self.queue_depth_by_family.setdefault(
                fam, deque(maxlen=self.window))
            dq.append(int(depth))
        floor = int(step) - self.window
        while self._completions and self._completions[0][0] <= floor:
            self._completions.popleft()

    # -- SLIs ----------------------------------------------------------------

    def throughput(self, family: Optional[str] = None) -> float:
        """Completions per step over the sliding window."""
        span = max(1, min(self.steps, self.window))
        n = sum(1 for _, fam in self._completions
                if family is None or fam == family)
        return n / span

    def families(self) -> List[str]:
        return sorted(set(self.latency_by_family)
                      | set(self.wait_by_family)
                      | set(self.queue_depth_by_family))

    def _judge(self, family: str, lat: dict, thr: float,
               depth_max: int) -> Optional[dict]:
        t = self.target_for(family)
        if t is None:
            return None
        verdict = {"target": t.to_dict()}
        ok = True
        if t.p50_latency_steps is not None:
            verdict["p50_ok"] = lat["p50"] <= t.p50_latency_steps
            ok &= verdict["p50_ok"]
        if t.p99_latency_steps is not None:
            verdict["p99_ok"] = lat["p99"] <= t.p99_latency_steps
            ok &= verdict["p99_ok"]
        if t.min_throughput is not None:
            verdict["throughput_ok"] = thr >= t.min_throughput
            ok &= verdict["throughput_ok"]
        if t.max_queue_depth is not None:
            verdict["queue_depth_ok"] = depth_max <= t.max_queue_depth
            ok &= verdict["queue_depth_ok"]
        if t.deadline_steps is not None:
            verdict["deadline_violations"] = \
                self.deadline_violations.get(family, 0)
            ok &= verdict["deadline_violations"] == 0
        verdict["ok"] = bool(ok)
        return verdict

    def report(self) -> dict:
        """JSON-ready sliding-window SLI report + per-target verdicts."""
        fams = {}
        for fam in self.families():
            lat = self.latency_by_family.get(fam)
            lat_s = (lat.summary() if lat is not None
                     else percentile_summary([]))
            wait = self.wait_by_family.get(fam)
            depths = self.queue_depth_by_family.get(fam)
            thr = self.throughput(fam)
            depth_max = int(max(depths)) if depths else 0
            entry = {
                "latency_steps": lat_s,
                "wait_steps": (wait.summary() if wait is not None
                               else percentile_summary([])),
                "throughput_per_step": round(thr, 6),
                "queue_depth": {
                    "mean": (round(float(np.mean(depths)), 6)
                             if depths else 0.0),
                    "max": depth_max},
                "deadline_violations":
                    self.deadline_violations.get(fam, 0),
            }
            verdict = self._judge(fam, lat_s, thr, depth_max)
            if verdict is not None:
                entry["slo"] = verdict
            fams[fam] = entry
        return {
            "window": self.window,
            "steps": self.steps,
            "completed": self.completed,
            "throughput_per_step": round(self.throughput(), 6),
            "deadline_violations_total":
                sum(self.deadline_violations.values()),
            "families": fams,
            "tenants": {
                str(sid): st.summary()
                for sid, st in sorted(self.latency_by_tenant.items())},
        }


# ---------------------------------------------------------------------------
# the registry: one snapshot over every metrics source
# ---------------------------------------------------------------------------


def _resolve(source):
    """A source is a callable, a dict, or an object with report()/
    summary()/to_dict() — in that precedence order."""
    if callable(source) and not hasattr(source, "report") \
            and not hasattr(source, "summary") \
            and not hasattr(source, "to_dict"):
        return source()
    if isinstance(source, dict):
        return source
    for meth in ("report", "summary", "to_dict"):
        fn = getattr(source, meth, None)
        if callable(fn):
            return fn()
    if callable(source):
        return source()
    raise TypeError(
        f"unsupported registry source: {type(source).__name__} "
        "(want a dict, a callable, or report()/summary()/to_dict())")


def _check_payload(name: str, value, path: str) -> None:
    if isinstance(value, dict):
        for k, v in value.items():
            if not isinstance(k, str):
                raise ValueError(
                    f"source {name!r}: non-string key at {path}: {k!r}")
            _check_payload(name, v, f"{path}.{k}")
    elif isinstance(value, (list, tuple)):
        for i, v in enumerate(value):
            _check_payload(name, v, f"{path}[{i}]")
    elif isinstance(value, bool) or value is None \
            or isinstance(value, (int, str)):
        return
    elif isinstance(value, float):
        if not np.isfinite(value):
            raise ValueError(
                f"source {name!r}: non-finite float at {path}: {value}")
    else:
        raise ValueError(
            f"source {name!r}: non-JSON value at {path}: "
            f"{type(value).__name__}")


def validate_registry_snapshot(doc) -> int:
    """Schema check for a MetricsRegistry snapshot; raises ValueError on
    the first offence, returns the number of sources when valid."""
    if not isinstance(doc, dict):
        raise ValueError(f"snapshot must be a dict, got "
                         f"{type(doc).__name__}")
    if doc.get("schema") != REGISTRY_SCHEMA:
        raise ValueError(f"bad schema tag: {doc.get('schema')!r} "
                         f"(want {REGISTRY_SCHEMA!r})")
    sources = doc.get("sources")
    if not isinstance(sources, dict):
        raise ValueError("snapshot['sources'] must be a dict")
    for name, payload in sources.items():
        if not isinstance(name, str) or not _NAME_RE.match(name):
            raise ValueError(f"bad source name: {name!r}")
        if not isinstance(payload, dict):
            raise ValueError(
                f"source {name!r}: payload must be a dict, got "
                f"{type(payload).__name__}")
        _check_payload(name, payload, "$")
    return len(sources)


def _prom_name(*parts: str) -> str:
    return re.sub(r"[^A-Za-z0-9_]", "_", "_".join(parts)).strip("_")


def _prom_lines(name: str, value, out: List[tuple]) -> None:
    """Flatten numeric leaves to (metric_name, float) pairs; lists (the
    telemetry series columns) are summarized as _sum/_last, not exploded
    into thousands of exposition lines."""
    if isinstance(value, bool):
        out.append((name, 1.0 if value else 0.0))
    elif isinstance(value, (int, float)):
        if np.isfinite(value):
            out.append((name, float(value)))
    elif isinstance(value, dict):
        for k, v in value.items():
            _prom_lines(_prom_name(name, str(k)), v, out)
    elif isinstance(value, (list, tuple)):
        nums = [float(v) for v in value
                if isinstance(v, (int, float))
                and not isinstance(v, bool)]
        if nums and len(nums) == len(value):
            out.append((_prom_name(name, "sum"), float(sum(nums))))
            out.append((_prom_name(name, "last"), nums[-1]))
    # strings / None carry no numeric signal: skipped


class MetricsRegistry:
    """Named metrics sources -> one schema-tagged snapshot.

    register() accepts anything _resolve understands: ``ServeMetrics``
    (summary), ``SLOTracker`` (report), ``TelemetrySeries`` / ``RunMetrics``
    (to_dict), plain dicts, or zero-arg callables re-evaluated per
    snapshot (register a live ``lambda: sess.run_metrics.to_dict()`` and
    every snapshot sees current values)."""

    def __init__(self):
        self._sources: Dict[str, object] = {}

    def register(self, name: str, source) -> None:
        if not _NAME_RE.match(name or ""):
            raise ValueError(f"bad source name: {name!r}")
        if name in self._sources:
            raise ValueError(f"source already registered: {name!r}")
        self._sources[name] = source

    def unregister(self, name: str) -> None:
        self._sources.pop(name, None)

    def names(self) -> List[str]:
        return sorted(self._sources)

    def snapshot(self) -> dict:
        doc = {"schema": REGISTRY_SCHEMA,
               "sources": {name: _resolve(self._sources[name])
                           for name in self.names()}}
        validate_registry_snapshot(doc)
        return doc

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def export(self, path) -> dict:
        """Write the JSON snapshot to `path`; returns the snapshot."""
        doc = self.snapshot()
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        return doc

    def to_prometheus(self) -> str:
        """Prometheus text exposition (gauges; numeric leaves only)."""
        doc = self.snapshot()
        lines: List[str] = []
        for name, payload in doc["sources"].items():
            flat: List[tuple] = []
            _prom_lines(_prom_name("repro", name), payload, flat)
            for metric, value in flat:
                lines.append(f"# TYPE {metric} gauge")
                val = (f"{value:.6g}" if value != int(value)
                       else str(int(value)))
                lines.append(f"{metric} {val}")
        return "\n".join(lines) + "\n"
