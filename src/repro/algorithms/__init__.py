from repro.algorithms.base import Algorithm, PLUS_TIMES, MIN_PLUS
from repro.algorithms.pagerank import PageRank, PersonalizedPageRank, Katz
from repro.algorithms.sssp import SSSP, BFS, WCC

__all__ = [
    "Algorithm", "PLUS_TIMES", "MIN_PLUS",
    "PageRank", "PersonalizedPageRank", "Katz",
    "SSSP", "BFS", "WCC",
]
