"""Delta-based accumulative iterative algorithms (PrIter / paper Eq. 3).

Two semirings cover the paper's algorithm families:

  PLUS_TIMES : v <- v + delta;   new_delta[dst] += push_scale * delta[src] * w
               (PageRank, PPR, Katz, Adsorption, ...)
  MIN_PLUS   : v <- min(v, cand);  cand[dst] = min_src(delta[src] + w)
               (SSSP, BFS, connected components via 0-weight label prop, ...)

State layout is blocked to match `BlockedGraph`:
  values [B_N, Vb]  and  deltas [B_N, Vb]   (per job; engine adds a J axis).

For MIN_PLUS, `deltas` holds the pending-propagation distance (the value at
the time the vertex last improved) and +inf when nothing is pending.

Vertex priority must be POSITIVE with 0 == converged (see DESIGN.md: the
paper's negative SSSP priority breaks its own epsilon/total formulas, so we
use the monotone transform 1/(1+dist)).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.graph.structure import BlockedGraph

PLUS_TIMES = "plus_times"
MIN_PLUS = "min_plus"

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """Base class; subclasses override init/vertex_priority as needed."""

    name: str = "abstract"
    semiring: str = PLUS_TIMES
    tolerance: float = 1e-6     # |delta| < tol  ==> vertex converged (plus-times)

    def get_push_scale(self) -> float:
        """Multiplies deltas before the push (PageRank damping, Katz alpha)."""
        return 1.0

    # ---- state -------------------------------------------------------------
    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    # graph build parameters this algorithm requires
    graph_fill: float = 0.0
    graph_normalize: str | None = None
    graph_symmetrize: bool = False

    # ---- priority ----------------------------------------------------------
    def vertex_priority(self, values: jnp.ndarray,
                        deltas: jnp.ndarray) -> jnp.ndarray:
        """Positive priority per vertex; exactly 0 for converged vertices."""
        if self.semiring == PLUS_TIMES:
            p = jnp.abs(deltas)
            return jnp.where(p >= self.tolerance, p, 0.0)
        # MIN_PLUS: pending vertices carry finite delta
        return jnp.where(jnp.isfinite(deltas), 1.0 / (1.0 + deltas), 0.0)

    def unconverged(self, values: jnp.ndarray,
                    deltas: jnp.ndarray) -> jnp.ndarray:
        if self.semiring == PLUS_TIMES:
            return jnp.abs(deltas) >= self.tolerance
        return jnp.isfinite(deltas)

    # ---- final extraction ----------------------------------------------------
    def result(self, values: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
        """Algorithm result per vertex (values plus any unfolded deltas)."""
        if self.semiring == PLUS_TIMES:
            return values + deltas
        return values


def _blocked_full(g: BlockedGraph, value: float) -> jnp.ndarray:
    return jnp.full((g.num_blocks, g.block_size), value, dtype=jnp.float32)
