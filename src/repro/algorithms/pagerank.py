"""PLUS_TIMES family: PageRank / PPR / Katz in delta-accumulative form.

Paper Eq. 3:   P^k = P^{k-1} + dP^k ;   dP^{k+1}_j = sum_i d * dP^k_i / |N(i)|

With tiles normalized by out-degree, one push of block b is
  contrib[dst] = push_scale * (delta[b] @ tile[b, k])
and the pushed delta folds into values.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.algorithms.base import Algorithm, PLUS_TIMES, _blocked_full
from repro.graph.structure import BlockedGraph


@dataclasses.dataclass(frozen=True)
class PageRank(Algorithm):
    name: str = "pagerank"
    semiring: str = PLUS_TIMES
    damping: float = 0.85
    tolerance: float = 1e-6
    graph_normalize: str | None = "out_degree"

    def get_push_scale(self) -> float:
        return self.damping

    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        values = _blocked_full(g, 0.0)
        deltas = jnp.where(g.vertex_mask, 1.0 - self.damping, 0.0)
        return values, deltas.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class PersonalizedPageRank(Algorithm):
    """PPR from a single source vertex (rooted random walk with restart)."""

    name: str = "ppr"
    semiring: str = PLUS_TIMES
    damping: float = 0.85
    source: int = 0
    tolerance: float = 1e-7
    graph_normalize: str | None = "out_degree"

    def get_push_scale(self) -> float:
        return self.damping

    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        values = _blocked_full(g, 0.0)
        deltas = _blocked_full(g, 0.0)
        b, u = divmod(self.source, g.block_size)
        deltas = deltas.at[b, u].set(1.0 - self.damping)
        return values, deltas


@dataclasses.dataclass(frozen=True)
class Katz(Algorithm):
    """Katz centrality: c = sum_k alpha^k (A^T)^k beta."""

    name: str = "katz"
    semiring: str = PLUS_TIMES
    alpha: float = 0.05
    beta: float = 1.0
    tolerance: float = 1e-6
    graph_normalize: str | None = None  # raw adjacency

    def get_push_scale(self) -> float:
        return self.alpha

    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        values = _blocked_full(g, 0.0)
        deltas = jnp.where(g.vertex_mask, self.beta, 0.0)
        return values, deltas.astype(jnp.float32)
