"""MIN_PLUS family: SSSP / BFS / WCC in delta (frontier) form.

State: values = best distance (or best label for WCC); deltas = pending
distance (finite only where the vertex improved since it was last pushed).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.algorithms.base import Algorithm, MIN_PLUS, _blocked_full
from repro.graph.structure import BlockedGraph


@dataclasses.dataclass(frozen=True)
class SSSP(Algorithm):
    name: str = "sssp"
    semiring: str = MIN_PLUS
    source: int = 0
    graph_fill: float = float("inf")
    graph_normalize: str | None = None

    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        values = _blocked_full(g, float("inf"))
        deltas = _blocked_full(g, float("inf"))
        b, u = divmod(self.source, g.block_size)
        values = values.at[b, u].set(0.0)
        deltas = deltas.at[b, u].set(0.0)
        return values, deltas


@dataclasses.dataclass(frozen=True)
class BFS(SSSP):
    """Hop distance: SSSP over unit weights."""

    name: str = "bfs"
    graph_normalize: str | None = "unit"


@dataclasses.dataclass(frozen=True)
class WCC(Algorithm):
    """Weakly connected components = min-label propagation over the
    symmetrized graph with 0-weight edges; label(v) converges to the minimum
    vertex id in v's component."""

    name: str = "wcc"
    semiring: str = MIN_PLUS
    graph_fill: float = float("inf")
    graph_normalize: str | None = "zero"
    graph_symmetrize: bool = True

    def init(self, g: BlockedGraph) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ids = jnp.arange(g.n_padded, dtype=jnp.float32).reshape(
            g.num_blocks, g.block_size)
        ids = jnp.where(g.vertex_mask, ids, jnp.inf)
        return ids, ids

    def vertex_priority(self, values, deltas):
        # every pending vertex counts equally; labels are not magnitudes
        return jnp.where(jnp.isfinite(deltas), 1.0, 0.0)
