"""Legacy concurrent-engine API, now a thin shim over GraphSession.

`make_run` + `ConcurrentEngine.run_two_level/run_fused/run_independent/
run_all_blocks` predate the job-lifecycle redesign: they declare a FIXED
job set up-front and run it to a joint fixpoint.  They are kept as a
compatibility surface — each run_* call drives a GraphSession under the
matching SchedulePolicy with capacity == J (no padding) and a freshly
reset scheduler RNG, which makes the shim bit-identical to the historical
loops.  New code should use repro.core.session.GraphSession directly
(dynamic submit/detach, pluggable policies); see docs/API.md.

Metrics: `tile_loads` counts block stagings (HBM->VMEM transfers of
adjacency tiles).  In two_level/all_blocks a staged tile serves all J jobs;
independent pays J separate stagings — the paper's memory-access
redundancy, measurable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np
import jax.numpy as jnp

from repro.algorithms.base import Algorithm
from repro.core.do_select import DEFAULT_SAMPLES
from repro.core.global_q import DEFAULT_ALPHA
from repro.core.policy import (RunMetrics, SchedulePolicy, TwoLevel, Fused,
                               Independent, AllBlocks)
from repro.core.push import compute_pairs, push_plus_one, push_min_one
from repro.core.scheduler import PRITER_C, optimal_queue_length
from repro.core.session import GraphSession
from repro.graph.structure import BlockedGraph, build_blocked, CSRGraph

__all__ = [
    "ConcurrentEngine", "ConcurrentRun", "RunMetrics", "make_run",
    "optimal_queue_length", "PRITER_C",
    "push_plus_one", "push_min_one", "compute_pairs",
]


@dataclasses.dataclass
class ConcurrentRun:
    """J jobs of the same semiring sharing one BlockedGraph view."""

    algs: List[Algorithm]
    graph: BlockedGraph
    values: jnp.ndarray   # [J, B_N, Vb]
    deltas: jnp.ndarray   # [J, B_N, Vb]
    push_scale: jnp.ndarray  # [J]

    @property
    def num_jobs(self) -> int:
        return len(self.algs)


def make_run(algs: Sequence[Algorithm], csr: CSRGraph,
             block_size: int) -> ConcurrentRun:
    """Build the shared graph view + stacked job states.

    All jobs must share (semiring, graph_fill, graph_normalize,
    graph_symmetrize) — the Seraph-style shared-data premise.
    """
    a0 = algs[0]
    for a in algs:
        if (a.semiring, a.graph_fill, a.graph_normalize, a.graph_symmetrize) != \
           (a0.semiring, a0.graph_fill, a0.graph_normalize, a0.graph_symmetrize):
            raise ValueError("concurrent jobs must share one graph view")
    g_csr = csr.symmetrized() if a0.graph_symmetrize else csr
    g = build_blocked(g_csr, block_size, fill=a0.graph_fill,
                      normalize=a0.graph_normalize)
    vals, dels = [], []
    for a in algs:
        v, d = a.init(g)
        vals.append(v)
        dels.append(d)
    return ConcurrentRun(
        algs=list(algs), graph=g,
        values=jnp.stack(vals), deltas=jnp.stack(dels),
        push_scale=jnp.asarray([a.get_push_scale() for a in algs],
                               dtype=jnp.float32))


class ConcurrentEngine:
    """Runs a ConcurrentRun to convergence under a chosen schedule (shim)."""

    def __init__(self, run: ConcurrentRun, *,
                 c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES,
                 seed: int = 0,
                 use_pallas: bool = False):
        self.session = GraphSession.from_run(
            run, c=c, alpha=alpha, samples=samples, seed=seed,
            use_pallas=use_pallas)
        self.run = run

    # configuration lives on the session/scheduler; these properties keep the
    # historical attributes readable AND writable (mutating eng.alpha between
    # run_* calls used to take effect, so delegate instead of copying)

    @property
    def q(self) -> int:
        return self.session.q

    @property
    def seed(self) -> int:
        return self.session.seed

    @seed.setter
    def seed(self, value: int) -> None:
        self.session.seed = value

    @property
    def alpha(self) -> float:
        return self.session.alpha

    @alpha.setter
    def alpha(self, value: float) -> None:
        self.session.alpha = value

    @property
    def samples(self) -> int:
        return self.session.samples

    @samples.setter
    def samples(self, value: int) -> None:
        self.session.samples = value

    @property
    def use_pallas(self) -> bool:
        return self.session.use_pallas

    @use_pallas.setter
    def use_pallas(self, value: bool) -> None:
        self.session.use_pallas = value

    def _drive(self, policy: SchedulePolicy, max_supersteps: int,
               mesh=None) -> RunMetrics:
        # historical behaviour: every run_* call restarted its RNG from seed
        self.session.scheduler.reset()
        m = self.session.run(policy, max_supersteps, mesh=mesh)
        self.run = dataclasses.replace(
            self.run, values=self.session.values, deltas=self.session.deltas,
            push_scale=self.session.push_scale)
        return m

    def run_two_level(self, max_supersteps: int = 100000, *,
                      mesh=None, backend: str = "host",
                      steps_per_sync=1) -> RunMetrics:
        """The paper's schedule: MPDS (DO queues + global queue) + CAJS push.

        mesh: optional jax.sharding.Mesh (e.g. dist.graph.make_job_mesh());
        J jobs are sharded across its devices, each device staging selected
        blocks once for its local jobs (per-device CAJS).
        backend="device" moves both scheduling levels into one jitted
        superstep; steps_per_sync then sets how many supersteps run per
        host round-trip (see docs/API.md, "Scheduler backends")."""
        return self._drive(
            TwoLevel(backend=backend, steps_per_sync=steps_per_sync),
            max_supersteps, mesh)

    def run_independent(self, max_supersteps: int = 100000) -> RunMetrics:
        """Per-job queues processed separately (paper Fig. 3 'current mode')."""
        return self._drive(Independent(), max_supersteps)

    def run_all_blocks(self, max_supersteps: int = 100000) -> RunMetrics:
        """Non-prioritized synchronous baseline: all blocks, shared staging."""
        return self._drive(AllBlocks(), max_supersteps)

    def run_fused(self, max_supersteps: int = 100000, *,
                  mesh=None, steps_per_sync=None) -> RunMetrics:
        """Beyond-paper: entire two-level loop in one on-device while_loop
        (`Fused` is TwoLevel(backend="device", steps_per_sync=inf)).

        mesh: optional Mesh; shards the job axis as in run_two_level.  The
        whole while_loop then runs SPMD with job state partitioned and one
        scalar all-reduce per superstep for the convergence test.  A finite
        steps_per_sync instead returns to host every K supersteps."""
        k = math.inf if steps_per_sync is None else steps_per_sync
        return self._drive(Fused(steps_per_sync=k), max_supersteps, mesh)

    # -- results ---------------------------------------------------------------

    def results(self) -> np.ndarray:
        """[J, n_real] per-job algorithm results."""
        r = self.run
        out = []
        for j, a in enumerate(r.algs):
            res = a.result(r.values[j], r.deltas[j])
            out.append(np.asarray(res).reshape(-1)[:r.graph.n_real])
        return np.stack(out)
