"""Concurrent multi-job engine: CAJS + MPDS over a shared BlockedGraph.

Execution modes (all produce identical fixpoints, different schedules):

  "two_level"   - the paper: per-job DO queues -> global queue -> one staging
                  of each selected block serves ALL jobs (CAJS).  Scheduling
                  decisions on host (faithful Job Controller), pushes on
                  device.
  "fused"       - beyond-paper: the whole loop (priority pairs, DO-order
                  top-q, global accumulation, push, convergence test) is a
                  single lax.while_loop on device; no host round-trips.
  "independent" - redundancy baseline: each job selects and processes its own
                  queue (per-job tile staging), modelling the paper's Fig. 3
                  "current mode" of concurrent access.
  "all_blocks"  - non-prioritized baseline: every block, every superstep
                  (classic synchronous engine shared across jobs).

Metrics: `tile_loads` counts block stagings (HBM->VMEM transfers of adjacency
tiles).  In two_level/all_blocks a staged tile serves all J jobs; independent
pays J separate stagings — the paper's memory-access redundancy, measurable.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm, PLUS_TIMES, MIN_PLUS
from repro.core import priority as prio
from repro.core.do_select import do_select, DEFAULT_SAMPLES
from repro.core.global_q import global_queue, DEFAULT_ALPHA
from repro.graph.structure import BlockedGraph, build_blocked, CSRGraph

PRITER_C = 100.0  # paper §5.1: q = C * B_N / sqrt(V_N), C = 100


def optimal_queue_length(num_blocks: int, n_vertices: int,
                         c: float = PRITER_C) -> int:
    q = int(c * num_blocks / math.sqrt(max(n_vertices, 1)))
    return max(1, min(q, num_blocks))


# ---------------------------------------------------------------------------
# single-job pushes (vmapped over jobs by the engine)
# ---------------------------------------------------------------------------

def _block_mask(sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                num_blocks: int) -> jnp.ndarray:
    """[q] ids + validity mask -> dense [B_N] bool, scatter-hazard free."""
    m = jnp.zeros((num_blocks,), dtype=jnp.bool_)
    return m.at[sel_ids].max(sel_mask > 0)


def push_plus_one(values: jnp.ndarray, deltas: jnp.ndarray,
                  tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                  sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                  push_scale: jnp.ndarray):
    """One job, PLUS_TIMES semiring. values/deltas [B_N, Vb]."""
    consumed = _block_mask(sel_ids, sel_mask, values.shape[0])[:, None]
    raw = jnp.where(consumed, deltas, 0.0)
    # mask padded selection slots: a padded slot aliases block 0 and must not
    # re-push block 0's delta when block 0 is itself selected
    d_sel = raw[sel_ids] * push_scale * sel_mask[:, None]  # [q, Vb]
    t_sel = tiles[sel_ids]                                # [q, K, Vb, Vb]
    contrib = jnp.einsum("qv,qkvw->qkw", d_sel, t_sel)    # [q, K, Vb]
    values = values + raw
    deltas = deltas - raw
    dst = nbr_ids[sel_ids].reshape(-1)                    # [q*K]
    deltas = deltas.at[dst].add(
        contrib.reshape(-1, contrib.shape[-1]), mode="drop")
    return values, deltas


def push_min_one(values: jnp.ndarray, deltas: jnp.ndarray,
                 tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                 sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                 push_scale: jnp.ndarray):
    """One job, MIN_PLUS semiring (push_scale unused, kept for signature)."""
    del push_scale
    bn = values.shape[0]
    consumed = _block_mask(sel_ids, sel_mask, bn)[:, None]
    d_sel = jnp.where(consumed, deltas, jnp.inf)[sel_ids]   # [q, Vb]
    d_sel = jnp.where(sel_mask[:, None] > 0, d_sel, jnp.inf)
    deltas = jnp.where(consumed, jnp.inf, deltas)
    t_sel = tiles[sel_ids]                                   # [q, K, Vb, Vb]
    nbr_sel = nbr_ids[sel_ids]                               # [q, K]

    def body(carry, inp):
        values, deltas = carry
        t_k, dst_k = inp                                     # [q,Vb,Vb], [q]
        contrib = jnp.min(d_sel[:, :, None] + t_k, axis=1)   # [q, Vb]
        old = values[dst_k]
        values = values.at[dst_k].min(contrib)
        new = values[dst_k]
        improved = new < old
        deltas = deltas.at[dst_k].min(jnp.where(improved, new, jnp.inf))
        return (values, deltas), None

    (values, deltas), _ = jax.lax.scan(
        body, (values, deltas),
        (jnp.swapaxes(t_sel, 0, 1), jnp.swapaxes(nbr_sel, 0, 1)))
    return values, deltas


def compute_pairs(alg: Algorithm, values: jnp.ndarray, deltas: jnp.ndarray):
    """[J, B_N, Vb] -> (node_un [J,B_N], p_mean [J,B_N])."""
    p = alg.vertex_priority(values, deltas)
    return prio.block_pairs(p)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunMetrics:
    supersteps: int = 0
    tile_loads: int = 0            # adjacency-block stagings (HBM->VMEM)
    job_block_pushes: int = 0      # (job, block) processing events
    iterations_per_job: Optional[np.ndarray] = None
    converged: bool = False


@dataclasses.dataclass
class ConcurrentRun:
    """J jobs of the same semiring sharing one BlockedGraph view."""

    algs: List[Algorithm]
    graph: BlockedGraph
    values: jnp.ndarray   # [J, B_N, Vb]
    deltas: jnp.ndarray   # [J, B_N, Vb]
    push_scale: jnp.ndarray  # [J]

    @property
    def num_jobs(self) -> int:
        return len(self.algs)


def make_run(algs: Sequence[Algorithm], csr: CSRGraph,
             block_size: int) -> ConcurrentRun:
    """Build the shared graph view + stacked job states.

    All jobs must share (semiring, graph_fill, graph_normalize,
    graph_symmetrize) — the Seraph-style shared-data premise.
    """
    a0 = algs[0]
    for a in algs:
        if (a.semiring, a.graph_fill, a.graph_normalize, a.graph_symmetrize) != \
           (a0.semiring, a0.graph_fill, a0.graph_normalize, a0.graph_symmetrize):
            raise ValueError("concurrent jobs must share one graph view")
    g_csr = csr.symmetrized() if a0.graph_symmetrize else csr
    g = build_blocked(g_csr, block_size, fill=a0.graph_fill,
                      normalize=a0.graph_normalize)
    vals, dels = [], []
    for a in algs:
        v, d = a.init(g)
        vals.append(v)
        dels.append(d)
    return ConcurrentRun(
        algs=list(algs), graph=g,
        values=jnp.stack(vals), deltas=jnp.stack(dels),
        push_scale=jnp.asarray([a.get_push_scale() for a in algs],
                               dtype=jnp.float32))


class ConcurrentEngine:
    """Runs a ConcurrentRun to convergence under a chosen schedule."""

    def __init__(self, run: ConcurrentRun, *,
                 c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES,
                 seed: int = 0,
                 use_pallas: bool = False):
        self.run = run
        self.alpha = alpha
        self.samples = samples
        self.seed = seed
        self.use_pallas = use_pallas
        g = run.graph
        self.q = optimal_queue_length(g.num_blocks, g.n_real, c)
        self._push_one = (push_plus_one if run.algs[0].semiring == PLUS_TIMES
                          else push_min_one)
        if use_pallas:
            from repro.kernels.mj_spmm import ops as mj_ops
            self._push_shared_fn = partial(
                mj_ops.push_shared, semiring=run.algs[0].semiring)
        self._jit_cache = {}

    # -- jitted primitives --------------------------------------------------

    def _pairs(self):
        key = "pairs"
        if key not in self._jit_cache:
            alg = self.run.algs[0]
            self._jit_cache[key] = jax.jit(
                lambda v, d: compute_pairs(alg, v, d))
        return self._jit_cache[key]

    def _push_shared(self):
        """All jobs process the same selected blocks (CAJS)."""
        key = ("push_shared", self.use_pallas)
        if key not in self._jit_cache:
            if self.use_pallas:
                fn = self._push_shared_fn
                self._jit_cache[key] = jax.jit(
                    lambda v, d, t, n, si, sm, ps: fn(v, d, t, n, si, sm, ps))
            else:
                push = self._push_one
                self._jit_cache[key] = jax.jit(jax.vmap(
                    push, in_axes=(0, 0, None, None, None, None, 0)))
        return self._jit_cache[key]

    def _push_indep(self):
        """Each job processes its own selection (redundancy baseline)."""
        key = "push_indep"
        if key not in self._jit_cache:
            push = self._push_one
            self._jit_cache[key] = jax.jit(jax.vmap(
                push, in_axes=(0, 0, None, None, 0, 0, 0)))
        return self._jit_cache[key]

    def _unconverged_counts(self):
        key = "counts"
        if key not in self._jit_cache:
            alg = self.run.algs[0]
            self._jit_cache[key] = jax.jit(
                lambda v, d: jnp.sum(alg.unconverged(v, d), axis=(1, 2)))
        return self._jit_cache[key]

    # -- runs ----------------------------------------------------------------

    def _place(self, mesh) -> None:
        """Shard the job axis over `mesh` (repro.dist.graph): tiles
        replicated per device, values/deltas job-sharded.  Scheduling below
        is unchanged — SPMD partitions the vmapped pushes along the job axis,
        so per-job arithmetic (and the fixpoint) is identical."""
        if mesh is None:
            return
        from repro.dist.graph import shard_run
        self.run = shard_run(self.run, mesh)

    def run_two_level(self, max_supersteps: int = 100000, *,
                      mesh=None) -> RunMetrics:
        """The paper's schedule: MPDS (host DO + global queue) + CAJS push.

        mesh: optional jax.sharding.Mesh (e.g. dist.graph.make_job_mesh());
        J jobs are sharded across its devices, each device staging selected
        blocks once for its local jobs (per-device CAJS)."""
        self._place(mesh)
        r, g = self.run, self.run.graph
        rng = np.random.default_rng(self.seed)
        m = RunMetrics(iterations_per_job=np.zeros(r.num_jobs, dtype=np.int64))
        pairs_fn, push_fn = self._pairs(), self._push_shared()
        counts_fn = self._unconverged_counts()
        values, deltas = r.values, r.deltas
        q = self.q
        for step in range(max_supersteps):
            counts = np.asarray(counts_fn(values, deltas))
            active = counts > 0
            m.iterations_per_job[active] += 1
            if not active.any():
                m.converged = True
                break
            node_un, p_mean = map(np.asarray, pairs_fn(values, deltas))
            queues = [do_select(node_un[j], p_mean[j], q, rng, self.samples)
                      if active[j] else np.empty(0, dtype=np.int64)
                      for j in range(r.num_jobs)]
            gq = global_queue(queues, g.num_blocks, q, self.alpha)
            if len(gq) == 0:
                m.converged = True
                break
            sel = np.zeros(q, dtype=np.int32)
            msk = np.zeros(q, dtype=np.float32)
            sel[:len(gq)] = gq[:q]
            msk[:len(gq)] = 1.0
            values, deltas = push_fn(values, deltas, g.tiles, g.nbr_ids,
                                     jnp.asarray(sel), jnp.asarray(msk),
                                     r.push_scale)
            m.supersteps += 1
            m.tile_loads += int(len(gq))
            # CAJS: staged once, dispatched only to jobs unconverged on the block
            m.job_block_pushes += int((node_un[:, gq] > 0).sum())
        self.run = dataclasses.replace(r, values=values, deltas=deltas)
        return m

    def run_independent(self, max_supersteps: int = 100000) -> RunMetrics:
        """Per-job queues processed separately (paper Fig. 3 'current mode')."""
        r, g = self.run, self.run.graph
        rng = np.random.default_rng(self.seed)
        m = RunMetrics(iterations_per_job=np.zeros(r.num_jobs, dtype=np.int64))
        pairs_fn, push_fn = self._pairs(), self._push_indep()
        counts_fn = self._unconverged_counts()
        values, deltas = r.values, r.deltas
        q = self.q
        for step in range(max_supersteps):
            counts = np.asarray(counts_fn(values, deltas))
            active = counts > 0
            m.iterations_per_job[active] += 1
            if not active.any():
                m.converged = True
                break
            node_un, p_mean = map(np.asarray, pairs_fn(values, deltas))
            sel = np.zeros((r.num_jobs, q), dtype=np.int32)
            msk = np.zeros((r.num_jobs, q), dtype=np.float32)
            for j in range(r.num_jobs):
                if not active[j]:
                    continue
                qj = do_select(node_un[j], p_mean[j], q, rng, self.samples)
                sel[j, :len(qj)] = qj[:q]
                msk[j, :len(qj)] = 1.0
                m.tile_loads += int(len(qj))       # each job stages its own
                m.job_block_pushes += int(len(qj))
            values, deltas = push_fn(values, deltas, g.tiles, g.nbr_ids,
                                     jnp.asarray(sel), jnp.asarray(msk),
                                     r.push_scale)
            m.supersteps += 1
        self.run = dataclasses.replace(r, values=values, deltas=deltas)
        return m

    def run_all_blocks(self, max_supersteps: int = 100000) -> RunMetrics:
        """Non-prioritized synchronous baseline: all blocks, shared staging."""
        r, g = self.run, self.run.graph
        m = RunMetrics(iterations_per_job=np.zeros(r.num_jobs, dtype=np.int64))
        push_fn = self._push_shared()
        counts_fn = self._unconverged_counts()
        values, deltas = r.values, r.deltas
        sel = jnp.arange(g.num_blocks, dtype=jnp.int32)
        msk = jnp.ones(g.num_blocks, dtype=jnp.float32)
        for step in range(max_supersteps):
            counts = np.asarray(counts_fn(values, deltas))
            active = counts > 0
            m.iterations_per_job[active] += 1
            if not active.any():
                m.converged = True
                break
            values, deltas = push_fn(values, deltas, g.tiles, g.nbr_ids,
                                     sel, msk, r.push_scale)
            m.supersteps += 1
            m.tile_loads += g.num_blocks
            m.job_block_pushes += g.num_blocks * int(active.sum())
        self.run = dataclasses.replace(r, values=values, deltas=deltas)
        return m

    def run_fused(self, max_supersteps: int = 100000, *,
                  mesh=None) -> RunMetrics:
        """Beyond-paper: entire two-level loop in one on-device while_loop.

        mesh: optional Mesh; shards the job axis as in run_two_level.  The
        whole while_loop then runs SPMD with job state partitioned and one
        scalar all-reduce per superstep for the convergence test."""
        self._place(mesh)
        r, g = self.run, self.run.graph
        alg = r.algs[0]
        q, alpha = self.q, self.alpha
        push = self._push_one
        n_res = max(0, q - int(math.ceil(alpha * q)))  # reserved head slots

        def body(carry):
            it, values, deltas, loads = carry
            node_un, p_mean = compute_pairs(alg, values, deltas)
            score = prio.do_score(node_un, p_mean)          # [J, B_N]
            topv, topi = jax.lax.top_k(score, q)            # per-job queues
            valid = jnp.isfinite(topv)
            w = jnp.arange(q, 0, -1, dtype=jnp.float32) * valid
            gpri = jnp.zeros((g.num_blocks,), jnp.float32)
            gpri = gpri.at[topi.reshape(-1)].add(w.reshape(-1))
            # reserve: force per-job heads into the queue (device analogue of
            # the paper's (1-alpha)q individual-head slots)
            if n_res > 0:
                heads = topi[:, 0]
                head_valid = valid[:, 0]
                gpri = gpri.at[heads].add(
                    jnp.where(head_valid, 1e12, 0.0))
            gv, gsel = jax.lax.top_k(gpri, q)
            gmask = (gv > 0.0).astype(jnp.float32)
            values, deltas = jax.vmap(
                push, in_axes=(0, 0, None, None, None, None, 0))(
                values, deltas, g.tiles, g.nbr_ids,
                gsel.astype(jnp.int32), gmask, r.push_scale)
            return it + 1, values, deltas, loads + jnp.sum(gmask)

        def cond(carry):
            it, values, deltas, _ = carry
            un = jnp.sum(alg.unconverged(values, deltas))
            return (un > 0) & (it < max_supersteps)

        it, values, deltas, loads = jax.lax.while_loop(
            cond, body, (jnp.int32(0), r.values, r.deltas, jnp.float32(0)))
        self.run = dataclasses.replace(r, values=values, deltas=deltas)
        m = RunMetrics()
        m.supersteps = int(it)
        m.tile_loads = int(loads)
        m.converged = bool(int(it) < max_supersteps)
        m.iterations_per_job = np.full(r.num_jobs, int(it), dtype=np.int64)
        return m

    # -- results ---------------------------------------------------------------

    def results(self) -> np.ndarray:
        """[J, n_real] per-job algorithm results."""
        r = self.run
        out = []
        for j, a in enumerate(r.algs):
            res = a.result(r.values[j], r.deltas[j])
            out.append(np.asarray(res).reshape(-1)[:r.graph.n_real])
        return np.stack(out)
