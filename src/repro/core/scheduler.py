"""TwoLevelScheduler: the paper's scheduling core as a reusable object.

Priority pairs -> per-job DO queues (Function 2) -> global-queue synthesis
(Fig. 7).  The object is data-structure-agnostic on purpose: the graph
engine feeds it <Node_un, P_mean> pairs per (job, vertex-block) and the LM
serve scheduler feeds it pairs per (request-stream, request-group) — the
"interlayer" design of the paper means the policy core is shared verbatim
(DESIGN.md §4).

The scheduler owns the sampling RNG so repeated `select` calls advance one
reproducible stream; `reset()` restores the initial seed (the legacy
`ConcurrentEngine` shim resets per run_* call to stay bit-identical with
the historical per-call `default_rng(seed)` behaviour).
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.do_select import do_select, DEFAULT_SAMPLES
from repro.core.global_q import global_queue, DEFAULT_ALPHA

PRITER_C = 100.0  # paper §5.1: q = C * B_N / sqrt(V_N), C = 100


def optimal_queue_length(num_blocks: int, n_vertices: int,
                         c: float = PRITER_C) -> int:
    q = int(c * num_blocks / math.sqrt(max(n_vertices, 1)))
    return max(1, min(q, num_blocks))


class TwoLevelScheduler:
    """Per-job DO queues + global-queue synthesis over `num_blocks` units."""

    def __init__(self, num_blocks: int, q: int, *,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES,
                 seed: int = 0):
        self.num_blocks = num_blocks
        self.q = q
        self.alpha = alpha
        self.samples = samples
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def reset(self, seed: Optional[int] = None) -> None:
        """Restore the RNG stream (optionally re-seeding)."""
        if seed is not None:
            self.seed = seed
        self.rng = np.random.default_rng(self.seed)

    # -- level 1: per-job DO queues (paper §4.2.2, Function 2) ---------------

    def job_queues(self, node_un: np.ndarray, p_mean: np.ndarray,
                   active: Optional[np.ndarray] = None,
                   q: Optional[int] = None) -> List[np.ndarray]:
        """[J, B_N] pairs -> per-job block queues, priority-descending.

        `active` masks jobs whose queue should be empty without consuming
        RNG draws (converged jobs / free session slots).
        """
        q = self.q if q is None else q
        return [do_select(node_un[j], p_mean[j], q, self.rng, self.samples)
                if active is None or active[j]
                else np.empty(0, dtype=np.int64)
                for j in range(node_un.shape[0])]

    # -- level 2: global queue (paper §4.2.3, Fig. 7) ------------------------

    def synthesize(self, queues: Sequence[np.ndarray],
                   q: Optional[int] = None) -> np.ndarray:
        q = self.q if q is None else q
        gq = global_queue(queues, self.num_blocks, q, self.alpha)
        # metrics honesty: callers stage (and count) exactly len(gq) blocks,
        # so the synthesis must never hand back more than fit in the queue
        assert len(gq) <= max(1, q), \
            f"global queue overflows its budget: {len(gq)} > {q}"
        return gq

    def select(self, node_un: np.ndarray, p_mean: np.ndarray,
               active: Optional[np.ndarray] = None,
               q: Optional[int] = None
               ) -> Tuple[List[np.ndarray], np.ndarray]:
        """Both levels at once: (per-job queues, global queue)."""
        queues = self.job_queues(node_un, p_mean, active, q)
        return queues, self.synthesize(queues, q)
