"""TwoLevelScheduler: the paper's scheduling core as a reusable object.

Priority pairs -> per-job DO queues (Function 2) -> global-queue synthesis
(Fig. 7).  The object is data-structure-agnostic on purpose: the graph
engine feeds it <Node_un, P_mean> pairs per (job, vertex-block) and the LM
serve scheduler feeds it pairs per (request-stream, request-group) — the
"interlayer" design of the paper means the policy core is shared verbatim
(DESIGN.md §4).

Both scheduling levels are BACKEND-PLUGGABLE:

  backend="host"   - numpy + the exact CBP comparator, sampling from the
                     scheduler-owned `numpy` RNG (the faithful paper
                     transcription; every `select` call advances one
                     reproducible stream, `reset()` restores it);
  backend="device" - the jnp analogues (do_select_device /
                     global_queue_device), sampling with `jax.random` keys
                     derived as fold_in(seed, call_index) so repeated calls
                     advance an equally reproducible stream.  The list
                     in/out interface is unchanged — callers such as the
                     serve scheduler switch backends without code changes.

The jitted superstep drivers (repro.core.policy) inline the same device
functions inside their compiled step rather than calling through this
object (an object call per superstep would reintroduce the host sync the
device backend exists to remove); this object remains the one home for the
scheduling parameters (q, alpha, samples, seed) either way.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.do_select import do_select, do_select_device, DEFAULT_SAMPLES
from repro.core.global_q import (global_queue, global_queue_device,
                                 DEFAULT_ALPHA)

PRITER_C = 100.0  # paper §5.1: q = C * B_N / sqrt(V_N), C = 100

BACKENDS = ("host", "device")


def optimal_queue_length(num_blocks: int, n_vertices: int,
                         c: float = PRITER_C) -> int:
    q = int(c * num_blocks / math.sqrt(max(n_vertices, 1)))
    return max(1, min(q, num_blocks))


class TwoLevelScheduler:
    """Per-job DO queues + global-queue synthesis over `num_blocks` units."""

    def __init__(self, num_blocks: int, q: int, *,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES,
                 seed: int = 0,
                 backend: str = "host"):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}: {backend}")
        self.num_blocks = num_blocks
        self.q = q
        self.alpha = alpha
        self.samples = samples
        self.seed = seed
        self.backend = backend
        self.rng = np.random.default_rng(seed)
        self._step = 0        # device-backend stream position (fold_in index)
        self._device_fns = {}  # jitted select/synthesis, keyed on (q, knobs)
        self.last_occupancy = 0  # |global queue| at the latest synthesize()

    def reset(self, seed: Optional[int] = None) -> None:
        """Restore the RNG stream (optionally re-seeding), both backends."""
        if seed is not None:
            self.seed = seed
        self.rng = np.random.default_rng(self.seed)
        self._step = 0

    def _next_key(self):
        """Next device sampling key: fold_in(seed, call_index) — one
        reproducible stream, mirroring the host RNG's advance-per-call."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self._step)
        self._step += 1
        return key

    # -- level 1: per-job DO queues (paper §4.2.2, Function 2) ---------------

    def job_queues(self, node_un: np.ndarray, p_mean: np.ndarray,
                   active: Optional[np.ndarray] = None,
                   q: Optional[int] = None) -> List[np.ndarray]:
        """[J, B_N] pairs -> per-job block queues, priority-descending.

        `active` masks jobs whose queue should be empty without consuming
        RNG draws (converged jobs / free session slots).
        """
        q = self.q if q is None else q
        if self.backend == "device":
            return self._job_queues_device(node_un, p_mean, active, q)
        return [do_select(node_un[j], p_mean[j], q, self.rng, self.samples)
                if active is None or active[j]
                else np.empty(0, dtype=np.int64)
                for j in range(node_un.shape[0])]

    def _job_queues_device(self, node_un, p_mean, active, q):
        # the jitted vmap is cached per (q, samples): repeated calls (the
        # serve scheduler invokes this every decode step) re-dispatch the
        # same executable instead of re-tracing a fresh lambda
        key = ("queues", q, self.samples)
        if key not in self._device_fns:
            samples = self.samples
            self._device_fns[key] = jax.jit(jax.vmap(
                lambda nu, pm, k: do_select_device(nu, pm, q, k, samples)))
        j = node_un.shape[0]
        keys = jax.random.split(self._next_key(), max(1, j))
        sel, msk = self._device_fns[key](
            jnp.asarray(node_un, jnp.float32),
            jnp.asarray(p_mean, jnp.float32), keys[:j])
        sel, msk = np.asarray(sel), np.asarray(msk)
        return [sel[i][msk[i] > 0].astype(np.int64)
                if active is None or active[i]
                else np.empty(0, dtype=np.int64)
                for i in range(j)]

    # -- level 2: global queue (paper §4.2.3, Fig. 7) ------------------------

    def synthesize(self, queues: Sequence[np.ndarray],
                   q: Optional[int] = None) -> np.ndarray:
        q = self.q if q is None else q
        if self.backend == "device":
            gq = self._synthesize_device(queues, q)
        else:
            gq = global_queue(queues, self.num_blocks, q, self.alpha)
        # metrics honesty: callers stage (and count) exactly len(gq) blocks,
        # so the synthesis must never hand back more than fit in the queue
        assert len(gq) <= max(1, q), \
            f"global queue overflows its budget: {len(gq)} > {q}"
        self.last_occupancy = int(len(gq))  # serve-layer occupancy series
        return gq

    def _synthesize_device(self, queues, q):
        key = ("synth", q, float(self.alpha))
        if key not in self._device_fns:
            nb, alpha = self.num_blocks, float(self.alpha)
            self._device_fns[key] = jax.jit(
                lambda s, m: global_queue_device(s, m, nb, q, alpha))
        j = max(1, len(queues))
        sel = np.zeros((j, q), dtype=np.int32)
        msk = np.zeros((j, q), dtype=np.float32)
        for i, jq in enumerate(queues):
            L = min(len(jq), q)
            sel[i, :L] = jq[:L]
            msk[i, :L] = 1.0
        gsel, gmsk = self._device_fns[key](jnp.asarray(sel),
                                           jnp.asarray(msk))
        gsel, gmsk = np.asarray(gsel), np.asarray(gmsk)
        return gsel[gmsk > 0].astype(np.int64)

    def select(self, node_un: np.ndarray, p_mean: np.ndarray,
               active: Optional[np.ndarray] = None,
               q: Optional[int] = None
               ) -> Tuple[List[np.ndarray], np.ndarray]:
        """Both levels at once: (per-job queues, global queue)."""
        queues = self.job_queues(node_un, p_mean, active, q)
        return queues, self.synthesize(queues, q)
