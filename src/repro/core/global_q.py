"""De_Gl_Priority: synthesize the global priority queue (paper §4.2.3, Fig. 7).

Each job's queue of length q_j assigns rank weights Pri = q, q-1, ..., 1 from
head to tail.  Cumulative Pri per block orders the global queue; the top
alpha*q blocks are taken by cumulative weight, and the remaining (1-alpha)*q
slots are reserved for blocks that top *individual* queues but miss the
global cut (round-robin over jobs, head-first).

Two implementations:

  global_queue          - host, numpy, list-of-queues in / ids out — the
                          faithful transcription (exact round-robin reserve);
  global_queue_device / - jittable jnp analogue over fixed-shape [J, q]
  accumulate_priority     queues: the same weighted scatter-add, then a
                          quota-respecting fill — ceil(alpha*q) slots go
                          strictly by cumulative weight, the (1-alpha)q
                          reserved slots go to the best not-yet-selected
                          job HEADS, and reserve slots no head claims fall
                          back to the next-best weighted blocks (the host
                          fills those from deeper queue depths; the sets
                          coincide whenever depth order and weight order
                          agree).  Unlike a naive head boost, many jobs
                          can never crowd the weighted slots out.
                          Agreement on the reserved-head-slot edge cases
                          is pinned by tests/test_device_scheduler.py.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

DEFAULT_ALPHA = 0.8   # paper default


def global_queue(job_queues: Sequence[np.ndarray], num_blocks: int, q: int,
                 alpha: float = DEFAULT_ALPHA) -> np.ndarray:
    """job_queues: per-job block ids, priority-descending.  Returns <=q ids."""
    q = max(1, q)
    pri = np.zeros(num_blocks, dtype=np.int64)
    for queue in job_queues:
        L = len(queue)
        if L == 0:
            continue
        # head gets Pri = q (paper assigns q..1 over the queue)
        weights = np.arange(q, q - L, -1, dtype=np.int64)
        np.add.at(pri, queue, np.maximum(weights, 1))

    candidates = np.nonzero(pri > 0)[0]
    if len(candidates) == 0:
        return np.empty(0, dtype=np.int64)

    n_global = min(max(1, int(np.ceil(alpha * q))), len(candidates), q)
    # exact partial selection; Function-2-style sampling is used on device in
    # the fused scheduler — here B_N is host-resident and small relative to V
    top = candidates[np.argsort(-pri[candidates], kind="stable")][:n_global]
    queue: List[int] = [int(b) for b in top]
    in_queue = set(queue)

    # reserved slots: round-robin over jobs, head of each queue first
    depth = 0
    while len(queue) < q:
        added = False
        for jq in job_queues:
            if depth < len(jq):
                b = int(jq[depth])
                if b not in in_queue:
                    queue.append(b)
                    in_queue.add(b)
                    added = True
                    if len(queue) >= q:
                        break
        depth += 1
        if not added and depth > max((len(jq) for jq in job_queues), default=0):
            break
    return np.asarray(queue, dtype=np.int64)


# --------------------------------------------------------------------------
# device synthesis: fixed-shape [J, q] queues -> dense priority -> top-q
# --------------------------------------------------------------------------


def reserved_slots(q: int, alpha: float = DEFAULT_ALPHA) -> int:
    """(1-alpha)q slots reserved for individual queue heads (Fig. 7).

    Mirrors the host cut exactly: the weighted tier keeps at least ONE
    slot (host: n_global = max(1, ceil(alpha*q))), so even alpha=0 never
    hands the whole queue to heads."""
    q = max(1, q)
    return max(0, q - max(1, int(math.ceil(alpha * q))))


def accumulate_priority(pri: jnp.ndarray, heads: jnp.ndarray,
                        sel: jnp.ndarray, msk: jnp.ndarray,
                        q: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter-add one batch of job queues into (pri, head-mask).

    sel/msk are [J, q] (fixed-shape DO queues, msk marks valid slots); head
    slots get Pri = q down to 1 at the tail, exactly the host weighting;
    `heads` ([B_N] bool) collects which blocks top an individual queue —
    the candidates for the reserved slots in `synthesize_topq`.  Call once
    per view group, accumulating into one (pri, heads), to synthesize
    across heterogeneous job groups."""
    w = jnp.arange(q, 0, -1, dtype=jnp.float32)[None, :] * msk
    pri = pri.at[sel.reshape(-1)].add(w.reshape(-1))
    heads = heads.at[sel[:, 0]].max(msk[:, 0] > 0)
    return pri, heads


def priority_topq(pri: jnp.ndarray, q: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense cumulative priority -> (gsel [q] int32, gmsk [q] float32)."""
    k = min(q, pri.shape[-1])
    gv, gsel = jax.lax.top_k(pri, k)
    gmsk = (gv > 0.0).astype(jnp.float32)
    gsel = jnp.where(gmsk > 0, gsel, 0).astype(jnp.int32)
    if k < q:
        gsel = jnp.pad(gsel, (0, q - k))
        gmsk = jnp.pad(gmsk, (0, q - k))
    return gsel, gmsk


def synthesize_topq(pri: jnp.ndarray, heads: jnp.ndarray, q: int,
                    alpha: float = DEFAULT_ALPHA
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fig. 7's two-tier cut over a dense priority, fixed [q] output.

    ceil(alpha*q) slots go by cumulative weight alone (the host
    guarantee: no number of competing heads can displace them); the
    (1-alpha)q reserved slots take the highest-priority not-yet-selected
    HEADS; reserve slots no head claims fall back to the next-best
    weighted blocks, so a saturated candidate set fills the queue exactly
    as the host's round-robin does."""
    n_res = reserved_slots(q, alpha)
    if n_res == 0:
        return priority_topq(pri, q)
    bn = pri.shape[-1]
    s1, m1 = priority_topq(pri, q - n_res)            # weighted slots
    taken = jnp.zeros((bn,), jnp.bool_).at[s1].max(m1 > 0)
    s2, m2 = priority_topq(                           # reserved: best heads
        jnp.where(heads & ~taken, pri, 0.0), n_res)
    taken = taken.at[s2].max(m2 > 0)
    s3, m3 = priority_topq(jnp.where(taken, 0.0, pri), n_res)
    m3 = m3 * (jnp.arange(n_res) < (n_res - jnp.sum(m2)))   # spare quota
    cand = jnp.concatenate([s1, s2, s3])
    cmsk = jnp.concatenate([m1, m2, m3])
    order = jnp.argsort(cmsk <= 0, stable=True)[:q]   # valid first, in order
    return cand[order].astype(jnp.int32), cmsk[order]


def global_queue_device(job_sel: jnp.ndarray, job_msk: jnp.ndarray,
                        num_blocks: int, q: int,
                        alpha: float = DEFAULT_ALPHA
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jittable De_Gl_Priority over fixed-shape [J, q] DO queues.

    Returns (gsel [q] int32, gmsk [q] float32): the same blocks the host
    `global_queue` selects whenever the candidate set fits the queue, and
    the cumulative-weight top with reserved per-job heads otherwise."""
    pri, heads = accumulate_priority(
        jnp.zeros((num_blocks,), jnp.float32),
        jnp.zeros((num_blocks,), jnp.bool_), job_sel, job_msk, q)
    return synthesize_topq(pri, heads, q, alpha)
