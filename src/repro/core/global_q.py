"""De_Gl_Priority: synthesize the global priority queue (paper §4.2.3, Fig. 7).

Each job's queue of length q_j assigns rank weights Pri = q, q-1, ..., 1 from
head to tail.  Cumulative Pri per block orders the global queue; the top
alpha*q blocks are taken by cumulative weight, and the remaining (1-alpha)*q
slots are reserved for blocks that top *individual* queues but miss the
global cut (round-robin over jobs, head-first).
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

DEFAULT_ALPHA = 0.8  # paper default


def global_queue(job_queues: Sequence[np.ndarray], num_blocks: int, q: int,
                 alpha: float = DEFAULT_ALPHA) -> np.ndarray:
    """job_queues: per-job block ids, priority-descending.  Returns <=q ids."""
    q = max(1, q)
    pri = np.zeros(num_blocks, dtype=np.int64)
    for queue in job_queues:
        L = len(queue)
        if L == 0:
            continue
        # head gets Pri = q (paper assigns q..1 over the queue)
        weights = np.arange(q, q - L, -1, dtype=np.int64)
        np.add.at(pri, queue, np.maximum(weights, 1))

    candidates = np.nonzero(pri > 0)[0]
    if len(candidates) == 0:
        return np.empty(0, dtype=np.int64)

    n_global = min(max(1, int(np.ceil(alpha * q))), len(candidates), q)
    # exact partial selection; Function-2-style sampling is used on device in
    # the fused scheduler — here B_N is host-resident and small relative to V
    top = candidates[np.argsort(-pri[candidates], kind="stable")][:n_global]
    queue: List[int] = [int(b) for b in top]
    in_queue = set(queue)

    # reserved slots: round-robin over jobs, head of each queue first
    depth = 0
    while len(queue) < q:
        added = False
        for jq in job_queues:
            if depth < len(jq):
                b = int(jq[depth])
                if b not in in_queue:
                    queue.append(b)
                    in_queue.add(b)
                    added = True
                    if len(queue) >= q:
                        break
        depth += 1
        if not added and depth > max((len(jq) for jq in job_queues), default=0):
            break
    return np.asarray(queue, dtype=np.int64)
