"""The paper's primary contribution: two-level scheduling (MPDS + CAJS).

Public surface (see docs/API.md for the migration guide):

  GraphSession / JobHandle      - job-lifecycle API (submit/run/result/detach)
  SchedulePolicy + TwoLevel,
  Fused, Independent, AllBlocks - pluggable schedules over a session
  TwoLevelScheduler             - the scheduling core (pairs -> DO queues ->
                                  global queue), shared with repro.serve
  ConcurrentEngine / make_run   - legacy fixed-job-set shim (kept working)
"""

from repro.core.priority import (block_pairs, cbp, counts_from_pairs,
                                 do_score, EPS_FACTOR)
from repro.core.do_select import do_select, do_select_device, DEFAULT_SAMPLES
from repro.core.global_q import (global_queue, global_queue_device,
                                 accumulate_priority, priority_topq,
                                 synthesize_topq, reserved_slots,
                                 DEFAULT_ALPHA)
from repro.core.scheduler import (TwoLevelScheduler, optimal_queue_length,
                                  PRITER_C)
from repro.core.push import push_plus_one, push_min_one, compute_pairs
from repro.core.policy import (RunMetrics, Selection, SchedulePolicy,
                               TwoLevel, Fused, Independent, AllBlocks,
                               POLICIES)
from repro.core.session import GraphSession, JobHandle
from repro.core.engine import ConcurrentEngine, ConcurrentRun, make_run
from repro.core.api import (initPtable, De_In_Priority, De_Gl_Priority,
                            Con_processing)

__all__ = [
    "block_pairs", "cbp", "counts_from_pairs", "do_score", "EPS_FACTOR",
    "do_select", "do_select_device", "DEFAULT_SAMPLES",
    "global_queue", "global_queue_device", "accumulate_priority",
    "priority_topq", "synthesize_topq", "reserved_slots", "DEFAULT_ALPHA",
    "TwoLevelScheduler", "optimal_queue_length", "PRITER_C",
    "push_plus_one", "push_min_one", "compute_pairs",
    "RunMetrics", "Selection", "SchedulePolicy",
    "TwoLevel", "Fused", "Independent", "AllBlocks", "POLICIES",
    "GraphSession", "JobHandle",
    "ConcurrentEngine", "ConcurrentRun", "make_run",
    "initPtable", "De_In_Priority", "De_Gl_Priority", "Con_processing",
]
