"""The paper's primary contribution: two-level scheduling (MPDS + CAJS)."""

from repro.core.priority import block_pairs, cbp, do_score, EPS_FACTOR
from repro.core.do_select import do_select, DEFAULT_SAMPLES
from repro.core.global_q import global_queue, DEFAULT_ALPHA
from repro.core.engine import (
    ConcurrentEngine, ConcurrentRun, RunMetrics, make_run,
    optimal_queue_length, push_plus_one, push_min_one, compute_pairs,
)
from repro.core.api import (initPtable, De_In_Priority, De_Gl_Priority,
                            Con_processing)

__all__ = [
    "block_pairs", "cbp", "do_score", "EPS_FACTOR",
    "do_select", "DEFAULT_SAMPLES",
    "global_queue", "DEFAULT_ALPHA",
    "ConcurrentEngine", "ConcurrentRun", "RunMetrics", "make_run",
    "optimal_queue_length", "push_plus_one", "push_min_one", "compute_pairs",
    "initPtable", "De_In_Priority", "De_Gl_Priority", "Con_processing",
]
