"""Pluggable schedule policies over a GraphSession.

One driver pair replaces the four historical near-duplicate engine loops.
A policy decides, per superstep, WHICH blocks are staged and WHO processes
them; the driver owns everything else (convergence test, metrics, the push
dispatch).  All policies reach the same per-job fixpoint — they differ
only in schedule and therefore in tile_loads / supersteps:

  TwoLevel    - the paper: per-job DO queues -> global queue -> one staging
                of each selected block serves ALL jobs (CAJS + MPDS).
  Independent - redundancy baseline: each job selects and stages its own
                queue (paper Fig. 3 "current mode").
  AllBlocks   - non-prioritized baseline: every block, every superstep.
  Fused       - alias for TwoLevel(backend="device", steps_per_sync=inf):
                the entire loop in one on-device while_loop.

Every policy runs on either BACKEND:

  backend="host"   - the faithful Job Controller: scheduling on host
                     (numpy + exact CBP), push on device; one scheduling
                     sync per superstep.
  backend="device" - both scheduling levels execute inside ONE jitted
                     superstep (device do_select sampling via jax.random
                     with the seed threaded through fold_in(step), global
                     synthesis as a weighted scatter-add with reserved
                     head slots), fused with the push into a single
                     dispatch.  `steps_per_sync=K` lax.scan's K supersteps
                     per host round-trip (convergence is still detected
                     exactly: a scanned step no-ops once all jobs
                     converge); `steps_per_sync=math.inf` turns the scan
                     into a lax.while_loop that only returns at the
                     fixpoint.  Compiled steps are cached on the session
                     (`session._device_step_fn`), keyed on view keys /
                     capacities / q / alpha / steps_per_sync, so repeated
                     run() calls and resubmissions never re-trace.

`RunMetrics.host_syncs` counts scheduling round-trips (host backend: one
per superstep including the final all-converged poll; device backend: one
per scan chunk / while_loop return) — the quantity `steps_per_sync`
amortizes, swept by `benchmarks/run.py fig_sync`.

Sessions are HETEROGENEOUS (repro.core.session): jobs live in per-graph-
view groups, but block ids are view-agnostic (every view is block-aligned
over the same CSR), so scheduling stays a single two-level decision over
all jobs' DO queues.  A shared policy stages each selected block ONCE per
superstep and dispatches it through every view's push (the plus-times and
the min-plus semiring in the same superstep) — `tile_loads` counts that
staging once, which is what makes the cross-family CAJS saving measurable.

Each policy composes with `mesh=` job-axis placement (repro.dist.graph):
partitioning the vmapped job axes never changes per-job arithmetic, so the
sharded run converges to the same fixpoint.

Metric layout: `RunMetrics.iterations_per_job` concatenates view groups in
creation order (`GraphSession.job_index(handle)` maps a handle to its row;
== handle.slot for single-view sessions).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import List, Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core.do_select import do_select_device
from repro.core.global_q import accumulate_priority, synthesize_topq
from repro.core.push import compute_pairs, indep_push_fn, shared_push_fn
from repro.obs.telemetry import (HostSeriesBuilder, TelemetrySeries,
                                 device_buffers, device_write,
                                 series_from_device)

HOST, DEVICE = "host", "device"


@dataclasses.dataclass
class RunMetrics:
    supersteps: int = 0
    tile_loads: int = 0            # adjacency-block stagings (HBM->VMEM)
    # real adjacency bytes: nonzero (src, dst) block pairs moved, summed
    # over pushed view groups (tile_pair_loads * Vb^2 * 4 bytes) — the
    # sparse BlockPairs refinement of tile_loads, which counts a staged
    # block once across views regardless of how many of its K ELL slots
    # are padding
    tile_pair_loads: int = 0
    job_block_pushes: int = 0      # (job, block) processing events
    host_syncs: int = 0            # scheduling host<->device round-trips
    # cross-shard frontier payload of a 2D (jobs x blocks) mesh run
    # (repro.dist.mesh2d): exchanged delta rows x Vb x itemsize, summed
    # over supersteps — proportional to frontier deltas, NEVER to whole
    # tiles; 0.0 off-mesh and on 1D job meshes (nothing block-crosses)
    halo_bytes: float = 0.0
    iterations_per_job: Optional[np.ndarray] = None
    converged: bool = False
    wall_time_s: float = 0.0       # driver wall time of this run()
    # evolving-graph counters (repro.stream), drained from the session's
    # apply_updates() calls since the previous run()
    updates_applied: int = 0       # edge insert/delete ops absorbed
    dirty_blocks: int = 0          # blocks marked update-affected
    reseed_fraction: float = 0.0   # re-seeded share of active job state
    # per-superstep series (repro.obs), only when the session was built
    # with telemetry=...; None otherwise
    telemetry: Optional[TelemetrySeries] = None

    def to_dict(self, include_telemetry: bool = False) -> dict:
        """Scalar record of this run — the ONE serialization used by the
        benchmark harness's JSON rows and the trace exporter's run spans
        (no ad-hoc string parsing in either)."""
        d = {"supersteps": int(self.supersteps),
             "tile_loads": int(self.tile_loads),
             "tile_pair_loads": int(self.tile_pair_loads),
             "job_block_pushes": int(self.job_block_pushes),
             "host_syncs": int(self.host_syncs),
             "halo_bytes": float(self.halo_bytes),
             "converged": bool(self.converged),
             "wall_time_s": round(float(self.wall_time_s), 6),
             "updates_applied": int(self.updates_applied),
             "dirty_blocks": int(self.dirty_blocks),
             "reseed_fraction": round(float(self.reseed_fraction), 6)}
        if include_telemetry and self.telemetry is not None:
            d["telemetry"] = self.telemetry.to_dict()
        return d


@dataclasses.dataclass
class Selection:
    """One superstep's staging decision.

    shared=True: `sel`/`msk` are [q] — ONE staging of each selected block
    serves every job in every view group (CAJS; tile_loads counted once).
    shared=False: `sel`/`msk` are per-group lists of [J_g, q] — each job
    stages its own queue (the redundancy baseline).

    Host policies fill it with numpy values; device policies return the
    same container holding tracers (consumed inside the jitted superstep).

    DTYPE CONTRACT for `tile_loads` / `job_block_pushes`: host `select`
    returns python `int`s; `device_select` returns int32 scalars (per-step
    values are tiny — the drivers coerce exactly once into their own
    accumulators, float32 on device so multi-million-superstep sums never
    wrap, int on host).  Pinned by tests/test_obs.py so telemetry series
    never silently mix dtypes.
    """

    sel: Union[np.ndarray, List[np.ndarray]]
    msk: Union[np.ndarray, List[np.ndarray]]
    shared: bool
    tile_loads: int
    job_block_pushes: int


class SchedulePolicy:
    """Base policy: subclasses implement `select` (host) / `device_select`.

    Both receive per-view-group lists (creation order): node_un[g] and
    p_mean[g] are [J_g, B_N], active[g] is [J_g] bool."""

    name = "abstract"
    needs_pairs = True  # driver computes <Node_un, P_mean> before select()

    def __init__(self, *, backend: str = HOST,
                 steps_per_sync: Union[int, float] = 1):
        if backend not in (HOST, DEVICE):
            raise ValueError(f"backend must be 'host' or 'device': {backend}")
        if backend == HOST:
            if steps_per_sync != 1:
                raise ValueError(
                    "host scheduling decides every superstep — "
                    "steps_per_sync requires backend='device'")
        elif steps_per_sync != math.inf and (
                steps_per_sync != int(steps_per_sync) or steps_per_sync < 1):
            raise ValueError(
                f"steps_per_sync must be a positive int or math.inf: "
                f"{steps_per_sync}")
        self.backend = backend
        self.steps_per_sync = steps_per_sync

    # -- selection hooks -----------------------------------------------------

    def select(self, sess, node_un: Optional[Sequence[np.ndarray]],
               p_mean: Optional[Sequence[np.ndarray]],
               active: Sequence[np.ndarray]) -> Optional[Selection]:
        """Host staging decision, or None when nothing is schedulable
        (the driver then declares convergence)."""
        raise NotImplementedError

    def device_select(self, node_uns, p_means, actives, key, *, q: int,
                      alpha: float, samples: int,
                      num_blocks: int) -> Selection:
        """Traced staging decision inside the jitted superstep.  `key` is
        this superstep's sampling key (already fold_in(step)-derived)."""
        raise NotImplementedError

    # -- driving -------------------------------------------------------------

    def run(self, sess, max_supersteps: int = 100000) -> RunMetrics:
        t0 = time.perf_counter()
        if self.backend == DEVICE:
            m = _run_device(self, sess, max_supersteps)
        else:
            m = _run_host(self, sess, max_supersteps)
        m.wall_time_s = time.perf_counter() - t0
        return m


def _profiler_span(sess, name: str):
    """jax.profiler annotation for one scheduling dispatch, opt-in via
    TelemetryConfig(jax_profiler=True); a no-op context otherwise."""
    cfg = getattr(sess, "telemetry", None)
    if cfg is not None and cfg.jax_profiler:
        return jax.profiler.TraceAnnotation(name)
    return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# host driver: counts fall out of the pairs dispatch; select on host
# ---------------------------------------------------------------------------


def _selection_occupancy(selection: Selection) -> int:
    """Staged-selection occupancy for telemetry: shared policies report the
    global-queue length (<= q), independent the total queue entries."""
    if selection.shared:
        return int(np.sum(np.asarray(selection.msk) > 0))
    return sum(int(np.sum(np.asarray(msk) > 0)) for msk in selection.msk)


def _run_host(policy: SchedulePolicy, sess,
              max_supersteps: int) -> RunMetrics:
    """Host driver: pairs -> select -> push, one scheduling sync per
    superstep.  The convergence counts are derived from the pairs
    (counts == node_un.sum(-1)), so policies that need pairs cost ONE
    device dispatch per group per superstep; AllBlocks keeps the cheaper
    counts-only reduction (needs_pairs=False fast path).

    Telemetry (repro.obs): with the session built telemetry=..., each
    superstep appends one row to a HostSeriesBuilder.  The max-residual
    column rides the SAME pairs/counts dispatch (with_resid variant), so
    telemetry never adds a host sync."""
    groups = sess.view_groups()
    offs = np.cumsum([0] + [g.capacity for g in groups])
    # on a 2D (jobs x blocks) mesh the push consumes the dst-partitioned
    # PairShards view instead (same global src_nnz, so the tile_pair_loads
    # accounting below is placement-agnostic)
    mesh2d = getattr(sess, "_mesh2d", None)
    if mesh2d is not None:
        grp_pairs = [sess._pair_shards(g) for g in groups]
    else:
        grp_pairs = [sess._pair_data(g) for g in groups]
    # host mirror of the per-source-block real-pair counts (explicit
    # device_get: the driver may run under the transfer sentinel)
    nnz_host = [np.asarray(x) for x in
                jax.device_get([p.src_nnz for p in grp_pairs])]
    m = RunMetrics(
        iterations_per_job=np.zeros(int(offs[-1]), dtype=np.int64))
    telemetry = getattr(sess, "telemetry", None) is not None
    if policy.needs_pairs:
        pairs_fns = [sess._pairs_fn(g, with_resid=telemetry)
                     for g in groups]
    else:
        counts_fns = [sess._counts_fn(g, with_resid=telemetry)
                      for g in groups]
    series = (HostSeriesBuilder([g.key for g in groups]) if telemetry
              else None)
    resids = [0.0] * len(groups)
    trace = getattr(sess, "trace", None)
    trace = trace if trace is not None and trace.enabled else None
    # a group observed fully converged stays converged for the rest of this
    # run (this driver never pushes an inactive group and no job can arrive
    # mid-run), so its per-superstep dispatch can be skipped outright; the
    # stand-in zeros are built on first skip only
    done = [None] * len(groups)
    bn = sess.scheduler.num_blocks
    # dirty-block priority injection (repro.stream): update-affected blocks
    # enter every job's DO queue boosted on the FIRST superstep after
    # apply_updates — only where the job actually has pending work there
    boost = sess._consume_dirty_boost()

    def _mark_done(gi):
        g = groups[gi]
        done[gi] = (np.zeros(g.capacity, dtype=bool),
                    np.zeros((g.capacity, bn), np.float32)
                    if policy.needs_pairs else None)

    for _ in range(max_supersteps):
        t_step = trace.now_us() if trace else 0.0
        dirty_n = int((boost > 0).sum()) if boost is not None else 0
        actives = []
        node_un = p_mean = None
        with _profiler_span(sess, "superstep.schedule"):
            if policy.needs_pairs:
                node_un, p_mean = [], []
                for gi, g in enumerate(groups):
                    if done[gi] is not None:
                        actives.append(done[gi][0])
                        node_un.append(done[gi][1])
                        p_mean.append(done[gi][1])
                        resids[gi] = 0.0
                        continue
                    out = pairs_fns[gi](g.values, g.deltas)
                    # the ONE intentional sync per group per superstep —
                    # explicit device_get keeps transfer_guard("disallow")
                    # clean (implicit float()/np coercions would trip it)
                    if telemetry:
                        nu, pm, rs = jax.device_get(out)
                        resids[gi] = float(rs)
                    else:
                        nu, pm = jax.device_get(out)
                    if boost is not None:
                        pm = pm + boost[None, :] * (nu > 0)
                    node_un.append(nu)
                    p_mean.append(pm)
                    actives.append(prio.counts_from_pairs(nu) > 0)
                    if not actives[gi].any():
                        _mark_done(gi)
            else:
                node_un = []
                for gi, g in enumerate(groups):
                    if done[gi] is not None:
                        actives.append(done[gi][0])
                        node_un.append(np.zeros(g.capacity,
                                                dtype=np.int32))
                        resids[gi] = 0.0
                        continue
                    out = counts_fns[gi](g.values, g.deltas)
                    if telemetry:
                        counts, rs = jax.device_get(out)
                        resids[gi] = float(rs)
                    else:
                        counts = jax.device_get(out)
                    node_un.append(counts)
                    actives.append(counts > 0)
                    if not actives[gi].any():
                        _mark_done(gi)
        for gi in range(len(groups)):
            m.iterations_per_job[offs[gi]:offs[gi + 1]][actives[gi]] += 1
        m.host_syncs += 1
        if not any(a.any() for a in actives):
            m.converged = True
            break
        boost = None
        selection = policy.select(
            sess, node_un if policy.needs_pairs else None, p_mean, actives)
        if selection is None:
            m.converged = True
            break
        # a fully-converged group is never pushed (matches the solo
        # session, which stops outright; for plus-times this also keeps
        # sub-tolerance residual mass where convergence left it)
        pair_step = 0
        with _profiler_span(sess, "superstep.push"):
            if selection.shared:
                sel = jnp.asarray(selection.sel)
                msk = jnp.asarray(selection.msk)
                sel_np = np.asarray(selection.sel)
                on_np = np.asarray(selection.msk) > 0
                for gi, g in enumerate(groups):
                    if not actives[gi].any():
                        continue
                    pair_step += int(nnz_host[gi][sel_np][on_np].sum())
                    g.values, g.deltas = sess._push_shared_fn(g)(
                        g.values, g.deltas, g.graph.tiles, g.graph.nbr_ids,
                        sel, msk, g.push_scale, g.overlay, grp_pairs[gi])
            else:
                for gi, g in enumerate(groups):
                    if not actives[gi].any():
                        continue
                    sel_np = np.asarray(selection.sel[gi])
                    on_np = np.asarray(selection.msk[gi]) > 0
                    pair_step += int((nnz_host[gi][sel_np] * on_np).sum())
                    args = (g.values, g.deltas, g.graph.tiles,
                            g.graph.nbr_ids,
                            jnp.asarray(selection.sel[gi]),
                            jnp.asarray(selection.msk[gi]), g.push_scale,
                            g.overlay)
                    if mesh2d is not None:   # 2D push needs the pair view
                        args = args + (grp_pairs[gi],)
                    g.values, g.deltas = sess._push_indep_fn(g)(*args)
        m.tile_pair_loads += pair_step
        halo_step = 0.0
        if mesh2d is not None:
            from repro.dist.mesh2d import host_halo_bytes
            halo_step = host_halo_bytes(mesh2d, groups, selection, actives)
            m.halo_bytes += halo_step
        if series is not None:
            # everything but pair_step/halo_step is a pre-push read; the
            # row is appended post-push only so those two can join it
            series.append(
                active_jobs=sum(int(a.sum()) for a in actives),
                tile_loads=int(selection.tile_loads),
                job_block_pushes=int(selection.job_block_pushes),
                gq_occupancy=_selection_occupancy(selection),
                dirty_blocks=dirty_n,
                unconverged=[int(np.sum(nu)) for nu in node_un],
                max_residual=resids,
                tile_pair_loads=pair_step, halo_bytes=halo_step)
        m.supersteps += 1
        # dtype contract: host selections carry python ints (coerced once)
        m.tile_loads += int(selection.tile_loads)
        m.job_block_pushes += int(selection.job_block_pushes)
        if trace:
            trace.complete("superstep", t_step, trace.now_us() - t_step,
                           cat="superstep", tid=2, step=m.supersteps - 1,
                           tile_loads=int(selection.tile_loads))
    if series is not None:
        m.telemetry = series.build()
    return m


# ---------------------------------------------------------------------------
# device driver: ONE jitted superstep, K supersteps per host round-trip
# ---------------------------------------------------------------------------


def build_device_step(policy: SchedulePolicy, sess):
    """Compile the session's superstep for `policy` into one jitted step
    function.  Returned callable:

        step_fn(state, scales, tiles, nbrs, overlays, pairs, max_steps,
                key) -> (state, unconverged_total)

    where state = (it, values_tuple, deltas_tuple, loads, pushes,
    pair_loads, iters_tuple, boost, telemetry_buffers) and `pairs` is the
    per-group `BlockPairs` tuple (the fused megakernel's adjacency view;
    `pair_loads` accumulates the real block pairs moved by pushed
    groups).  Finite steps_per_sync runs a lax.scan of that
    many gated supersteps (a step no-ops — and counts nothing — once all
    jobs converge or the budget is spent); steps_per_sync=inf runs a
    lax.while_loop to the fixpoint.  Graph tiles / neighbour ids / push
    scales — and each view's delta-COO overlay, so live update batches
    (repro.stream) never retrace — are ARGUMENTS, not closure constants:
    one compilation serves every run() call, resubmission, update batch,
    and mesh placement (jax re-specializes on sharding, not on values).
    `boost` is the dirty-block priority injection: [B_N] added to every
    group's P_mean (where pending) on the first superstep after
    apply_updates, then zeroed in the carry.

    `telemetry_buffers` (repro.obs) is () when the session has no
    telemetry — the series is COMPILED OUT, the program is bit-identical
    to the pre-observability superstep — and otherwise a tuple of
    preallocated [capacity] arrays written at min(it, capacity-1) each
    superstep, so a steps_per_sync=inf run returns the full per-superstep
    series at its single host sync.  The session's jit-cache key carries
    the capacity (0 when off), so toggling telemetry never invalidates or
    re-traces the other variant.  Cache via session._device_step_fn."""
    groups = sess.view_groups()
    n_groups = len(groups)
    algs = [g.alg for g in groups]
    q = int(sess.q)
    alpha = float(sess.alpha)
    samples = int(sess.samples)
    bn = int(sess.scheduler.num_blocks)
    k_sync = policy.steps_per_sync
    needs_pairs = policy.needs_pairs
    tel_cfg = getattr(sess, "telemetry", None)
    tel_cap = int(tel_cfg.capacity) if tel_cfg is not None else 0

    shared_push = [shared_push_fn(g.semiring, g.push_one, sess.use_pallas)
                   for g in groups]
    indep_push = [indep_push_fn(g.push_one) for g in groups]

    def unconverged_total(vs, ds):
        tot = jnp.int32(0)
        for gi in range(n_groups):
            tot = tot + jnp.sum(
                algs[gi].unconverged(vs[gi], ds[gi]).astype(jnp.int32))
        return tot

    def superstep(carry, scales, tiles, nbrs, ovs, prs, key):
        it, vs, ds, loads, pushes, pair_loads, iters, boost, tel = carry
        node_uns, p_means, actives = [], [], []
        for gi in range(n_groups):
            if needs_pairs:
                nu, pm = compute_pairs(algs[gi], vs[gi], ds[gi])
                pm = pm + boost[None, :] * (nu > 0)
            else:   # Node_un alone suffices (AllBlocks): cheaper reduce
                un = algs[gi].unconverged(vs[gi], ds[gi])
                nu = jnp.sum(un, axis=-1).astype(jnp.float32)
                pm = None
            node_uns.append(nu)
            p_means.append(pm)
            actives.append(prio.counts_from_pairs(nu) > 0)
        selection = policy.device_select(
            node_uns, p_means, actives, jax.random.fold_in(key, it),
            q=q, alpha=alpha, samples=samples, num_blocks=bn)
        new_vs, new_ds, new_iters = [], [], []
        pair_step = jnp.float32(0)
        for gi in range(n_groups):
            if selection.shared:
                v2, d2 = shared_push[gi](
                    vs[gi], ds[gi], tiles[gi], nbrs[gi],
                    selection.sel, selection.msk, scales[gi], ovs[gi],
                    prs[gi])
                pair_cnt = jnp.sum(prs[gi].src_nnz[selection.sel]
                                   * (selection.msk > 0))
            else:
                v2, d2 = indep_push[gi](
                    vs[gi], ds[gi], tiles[gi], nbrs[gi],
                    selection.sel[gi], selection.msk[gi], scales[gi],
                    ovs[gi])
                pair_cnt = jnp.sum(prs[gi].src_nnz[selection.sel[gi]]
                                   * (selection.msk[gi] > 0))
            # a fully-converged group is never pushed, exactly as in the
            # host driver: freezing it keeps sub-tolerance plus-times
            # residual mass where convergence left it (min-plus pushes
            # are exact no-ops either way)
            keep = jnp.any(actives[gi])
            new_vs.append(jnp.where(keep, v2, vs[gi]))
            new_ds.append(jnp.where(keep, d2, ds[gi]))
            new_iters.append(iters[gi] + actives[gi].astype(jnp.int32))
            pair_step = pair_step + (keep.astype(jnp.float32)
                                     * pair_cnt.astype(jnp.float32))
        if tel_cap:
            # the per-superstep series rides the carry: int32 rows written
            # at min(it, cap-1); pure reads of the pre-push state plus the
            # push loop's pair_step, so the push math — and the fixpoint —
            # is bitwise telemetry-off
            idx = jnp.minimum(it, tel_cap - 1)
            if selection.shared:
                occ = jnp.sum(selection.msk > 0).astype(jnp.int32)
            else:
                occ = sum(jnp.sum(msk > 0).astype(jnp.int32)
                          for msk in selection.msk)
            tel = device_write(
                tel, idx,
                sum(jnp.sum(a.astype(jnp.int32)) for a in actives),
                selection.tile_loads, selection.job_block_pushes, occ,
                jnp.sum(boost > 0).astype(jnp.int32),
                jnp.stack([jnp.sum(nu).astype(jnp.int32)
                           for nu in node_uns]),
                jnp.stack([jnp.max(algs[gi].vertex_priority(vs[gi],
                                                            ds[gi]))
                           for gi in range(n_groups)]),
                tile_pair_loads=pair_step.astype(jnp.int32))
        # dtype contract: device selections carry int32 scalars; the carry
        # accumulates in float32 (int32 would wrap on billion-push runs,
        # float32 only rounds past 2^24)
        return (it + 1, tuple(new_vs), tuple(new_ds),
                loads + selection.tile_loads.astype(jnp.float32),
                pushes + selection.job_block_pushes.astype(jnp.float32),
                pair_loads + pair_step,
                tuple(new_iters),
                jnp.zeros_like(boost),   # injection consumed: one superstep
                tel)

    def step_fn(state, scales, tiles, nbrs, ovs, prs, max_steps, key):
        def body(c):
            return superstep(c, scales, tiles, nbrs, ovs, prs, key)

        def live(c):
            return (unconverged_total(c[1], c[2]) > 0) & (c[0] < max_steps)

        if k_sync == math.inf:
            state = jax.lax.while_loop(live, body, state)
        else:
            def gated(c, _):
                return jax.lax.cond(live(c), body, lambda x: x, c), None
            state, _ = jax.lax.scan(gated, state, None, length=int(k_sync))
        return state, unconverged_total(state[1], state[2])

    return jax.jit(step_fn)


def _run_device(policy: SchedulePolicy, sess,
                max_supersteps: int) -> RunMetrics:
    """Device driver: call the cached jitted step, sync once per chunk.

    The sampling stream mirrors the host scheduler RNG's semantics: keys
    are fold_in(fold_in(PRNGKey(seed), stream_pos), step), where
    stream_pos is the scheduler's persistent position — advanced here by
    the supersteps consumed — so repeated run()/step() calls keep drawing
    fresh samples (and the legacy engine shim's per-call reset() restores
    the historical restart).  Within a run the trajectory is invariant to
    steps_per_sync (superstep t draws the same key regardless of
    chunking), so tile_loads/supersteps are identical across cadences."""
    if getattr(sess, "_mesh2d", None) is not None:
        from repro.dist.mesh2d import run_device_2d
        return run_device_2d(policy, sess, max_supersteps)
    groups = sess.view_groups()
    step_fn = sess._device_step_fn(policy)
    boost = sess._consume_dirty_boost()
    bn = sess.scheduler.num_blocks
    tel_cfg = getattr(sess, "telemetry", None)
    tel_cap = int(tel_cfg.capacity) if tel_cfg is not None else 0
    trace = getattr(sess, "trace", None)
    trace = trace if trace is not None and trace.enabled else None
    state = (jnp.int32(0),
             tuple(g.values for g in groups),
             tuple(g.deltas for g in groups),
             jnp.float32(0), jnp.float32(0), jnp.float32(0),
             tuple(jnp.zeros(g.capacity, jnp.int32) for g in groups),
             jnp.zeros(bn, jnp.float32) if boost is None
             else jnp.asarray(boost, jnp.float32),
             device_buffers(tel_cap, len(groups)) if tel_cap else ())
    scales = tuple(g.push_scale for g in groups)
    tiles = tuple(g.graph.tiles for g in groups)
    nbrs = tuple(g.graph.nbr_ids for g in groups)
    ovs = tuple(g.overlay for g in groups)
    prs = tuple(sess._pair_data(g) for g in groups)
    # the budget the device compares against must be the SAME clamped
    # value the host loop tests, or a >int32 budget could spin forever
    budget = int(min(max_supersteps, np.iinfo(np.int32).max))
    max_steps = jnp.int32(budget)
    key = jax.random.fold_in(jax.random.PRNGKey(sess.seed),
                             sess.scheduler._step)
    m = RunMetrics()
    while True:
        t_chunk = trace.now_us() if trace else 0.0
        with _profiler_span(sess, "device_chunk"):
            state, un = step_fn(state, scales, tiles, nbrs, ovs, prs,
                                max_steps, key)
            # the ONE host sync of the chunk: explicit, batched, and the
            # only transfer a transfer_guard("disallow") run will see
            it_h, un_h = map(int, jax.device_get((state[0], un)))
        m.host_syncs += 1
        if trace:
            trace.complete("device_chunk", t_chunk,
                           trace.now_us() - t_chunk, cat="superstep", tid=2,
                           sync=m.host_syncs - 1, supersteps_done=it_h)
        if un_h == 0 or it_h >= budget:
            break
    sess.scheduler._step += it_h
    for gi, g in enumerate(groups):
        g.values, g.deltas = state[1][gi], state[2][gi]
    m.supersteps = it_h
    loads_h, pushes_h, pair_loads_h, iters_h = jax.device_get(
        (state[3], state[4], state[5], state[6]))
    m.tile_loads = int(loads_h)
    m.job_block_pushes = int(pushes_h)
    m.tile_pair_loads = int(pair_loads_h)
    m.converged = un_h == 0
    m.iterations_per_job = np.concatenate(
        [np.asarray(x, dtype=np.int64) for x in iters_h])
    if tel_cap:
        m.telemetry = series_from_device(state[8], it_h,
                                         [g.key for g in groups])
    return m


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def _group_queues_device(nu, pm, key, gi, q, samples):
    """One view group's DO queues on device: per-job sampling keys derived
    fold_in(superstep key, group index) then split over the job axis, so
    every (policy, group, job, step) draws from one reproducible stream."""
    keys = jax.random.split(jax.random.fold_in(key, gi), nu.shape[0])
    return jax.vmap(
        lambda n, p, k: do_select_device(n, p, q, k, samples))(nu, pm, keys)


class TwoLevel(SchedulePolicy):
    """The paper's schedule: MPDS (DO queues + global queue) + CAJS push.

    The global queue is synthesized across ALL jobs' DO queues regardless
    of view (block ids are view-agnostic); one staging of each selected
    block then serves both semiring families in the same superstep.  With
    backend="device" both levels run inside the jitted superstep: per-job
    do_select_device sampling feeds one weighted scatter-add synthesis
    with reserved head slots."""

    name = "two_level"

    def select(self, sess, node_un, p_mean, active):
        sched = sess.scheduler
        queues = []
        for nu, pm, act in zip(node_un, p_mean, active):
            queues.extend(sched.job_queues(nu, pm, act))
        gq = sched.synthesize(queues)
        if len(gq) == 0:
            return None
        q = sess.q
        # metrics honesty: only the staged prefix counts (synthesize also
        # asserts len(gq) <= q, so this clamp is a guard, not a behaviour)
        gq = gq[:q]
        sel = np.zeros(q, dtype=np.int32)
        msk = np.zeros(q, dtype=np.float32)
        sel[:len(gq)] = gq
        msk[:len(gq)] = 1.0
        # CAJS: staged once, dispatched only to jobs unconverged on the block
        pushes = sum(int((nu[:, gq] > 0).sum()) for nu in node_un)
        return Selection(sel, msk, shared=True, tile_loads=int(len(gq)),
                         job_block_pushes=pushes)

    def device_select(self, node_uns, p_means, actives, key, *, q, alpha,
                      samples, num_blocks):
        pri = jnp.zeros((num_blocks,), jnp.float32)
        heads = jnp.zeros((num_blocks,), jnp.bool_)
        for gi, (nu, pm) in enumerate(zip(node_uns, p_means)):
            sel, msk = _group_queues_device(nu, pm, key, gi, q, samples)
            pri, heads = accumulate_priority(pri, heads, sel, msk, q)
        gsel, gmsk = synthesize_topq(pri, heads, q, alpha)
        # dtype contract (see Selection): per-step counters are int32; the
        # drivers accumulate in float32, which only rounds totals >2^24
        pushes = jnp.int32(0)
        for nu in node_uns:
            pushes = pushes + jnp.sum(
                ((nu[:, gsel] > 0) & (gmsk > 0)[None, :])
                .astype(jnp.int32))
        return Selection(gsel, gmsk, shared=True,
                         tile_loads=jnp.sum(gmsk > 0).astype(jnp.int32),
                         job_block_pushes=pushes)


class Independent(SchedulePolicy):
    """Per-job queues processed separately (paper Fig. 3 'current mode')."""

    name = "independent"

    def select(self, sess, node_un, p_mean, active):
        q = sess.q
        sels, msks = [], []
        loads = pushes = 0
        for nu, pm, act in zip(node_un, p_mean, active):
            j_cap = nu.shape[0]
            sel = np.zeros((j_cap, q), dtype=np.int32)
            msk = np.zeros((j_cap, q), dtype=np.float32)
            for j, qj in enumerate(sess.scheduler.job_queues(nu, pm, act)):
                if len(qj) == 0:
                    continue
                sel[j, :len(qj)] = qj[:q]
                msk[j, :len(qj)] = 1.0
                loads += int(len(qj))          # each job stages its own
                pushes += int(len(qj))
            sels.append(sel)
            msks.append(msk)
        return Selection(sels, msks, shared=False, tile_loads=loads,
                         job_block_pushes=pushes)

    def device_select(self, node_uns, p_means, actives, key, *, q, alpha,
                      samples, num_blocks):
        sels, msks = [], []
        loads = jnp.int32(0)
        for gi, (nu, pm) in enumerate(zip(node_uns, p_means)):
            sel, msk = _group_queues_device(nu, pm, key, gi, q, samples)
            sels.append(sel)
            msks.append(msk)
            loads = loads + jnp.sum(msk > 0).astype(jnp.int32)
        return Selection(sels, msks, shared=False, tile_loads=loads,
                         job_block_pushes=loads)


class AllBlocks(SchedulePolicy):
    """Non-prioritized synchronous baseline: all blocks, shared staging."""

    name = "all_blocks"
    needs_pairs = False

    def select(self, sess, node_un, p_mean, active):
        bn = sess.scheduler.num_blocks
        sel = np.arange(bn, dtype=np.int32)
        msk = np.ones(bn, dtype=np.float32)
        n_active = sum(int(a.sum()) for a in active)
        return Selection(sel, msk, shared=True, tile_loads=bn,
                         job_block_pushes=bn * n_active)

    def device_select(self, node_uns, p_means, actives, key, *, q, alpha,
                      samples, num_blocks):
        n_active = jnp.int32(0)
        for act in actives:
            n_active = n_active + jnp.sum(act.astype(jnp.int32))
        return Selection(jnp.arange(num_blocks, dtype=jnp.int32),
                         jnp.ones(num_blocks, jnp.float32), shared=True,
                         tile_loads=jnp.int32(num_blocks),
                         job_block_pushes=jnp.int32(num_blocks) * n_active)


class Fused(TwoLevel):
    """Beyond-paper alias: TwoLevel(backend="device", steps_per_sync=inf).

    The entire two-level loop — priority pairs, per-job DO sampling,
    global synthesis, push, convergence test — is one on-device
    lax.while_loop with no host round-trips until the fixpoint.  Its
    historical dedicated run() fork is gone: this class only pins the
    backend; pass a finite steps_per_sync to trade convergence-latency
    for mid-batch submit/detach opportunities."""

    name = "fused"

    def __init__(self, *, steps_per_sync: Union[int, float] = math.inf):
        super().__init__(backend=DEVICE, steps_per_sync=steps_per_sync)


POLICIES = {p.name: p for p in (TwoLevel, Fused, Independent, AllBlocks)}
