"""Pluggable schedule policies over a GraphSession.

One `step()/run()` driver replaces the four historical near-duplicate
engine loops.  A policy decides, per superstep, WHICH blocks are staged and
WHO processes them; the driver owns everything else (convergence test,
metrics, the push dispatch).  All policies reach the same per-job fixpoint
— they differ only in schedule and therefore in tile_loads / supersteps:

  TwoLevel    - the paper: per-job DO queues -> global queue -> one staging
                of each selected block serves ALL jobs (CAJS + MPDS).
                Scheduling on host (faithful Job Controller), push on device.
  Fused       - beyond-paper: the entire loop (priority pairs, top-q, global
                accumulation, push, convergence test) is a single
                lax.while_loop on device; no host round-trips.
  Independent - redundancy baseline: each job selects and stages its own
                queue (paper Fig. 3 "current mode").
  AllBlocks   - non-prioritized baseline: every block, every superstep.

Sessions are HETEROGENEOUS (repro.core.session): jobs live in per-graph-
view groups, but block ids are view-agnostic (every view is block-aligned
over the same CSR), so scheduling stays a single two-level decision over
all jobs' DO queues.  A shared policy stages each selected block ONCE per
superstep and dispatches it through every view's push (the plus-times and
the min-plus semiring in the same superstep) — `tile_loads` counts that
staging once, which is what makes the cross-family CAJS saving measurable.

Each policy composes with `mesh=` job-axis placement (repro.dist.graph):
partitioning the vmapped job axes never changes per-job arithmetic, so the
sharded run converges to the same fixpoint.

Metric layout: `RunMetrics.iterations_per_job` concatenates view groups in
creation order (`GraphSession.job_index(handle)` maps a handle to its row;
== handle.slot for single-view sessions).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core.push import compute_pairs


@dataclasses.dataclass
class RunMetrics:
    supersteps: int = 0
    tile_loads: int = 0            # adjacency-block stagings (HBM->VMEM)
    job_block_pushes: int = 0      # (job, block) processing events
    iterations_per_job: Optional[np.ndarray] = None
    converged: bool = False


@dataclasses.dataclass
class Selection:
    """One superstep's staging decision, produced by a host policy.

    shared=True: `sel`/`msk` are [q] — ONE staging of each selected block
    serves every job in every view group (CAJS; tile_loads counted once).
    shared=False: `sel`/`msk` are per-group lists of [J_g, q] — each job
    stages its own queue (the redundancy baseline)."""

    sel: Union[np.ndarray, List[np.ndarray]]
    msk: Union[np.ndarray, List[np.ndarray]]
    shared: bool
    tile_loads: int
    job_block_pushes: int


class SchedulePolicy:
    """Base host-driven policy: subclasses implement `select`.

    `select` receives per-view-group lists (creation order): node_un[g] and
    p_mean[g] are [J_g, B_N], active[g] is [J_g] bool."""

    name = "abstract"
    needs_pairs = True  # driver computes <Node_un, P_mean> before select()

    def select(self, sess, node_un: Optional[Sequence[np.ndarray]],
               p_mean: Optional[Sequence[np.ndarray]],
               active: Sequence[np.ndarray]) -> Optional[Selection]:
        """Return the staging decision, or None when nothing is schedulable
        (the driver then declares convergence)."""
        raise NotImplementedError

    def run(self, sess, max_supersteps: int = 100000) -> RunMetrics:
        """Generic host driver: counts -> pairs -> select -> push, across
        every view group each superstep."""
        groups = sess.view_groups()
        offs = np.cumsum([0] + [g.capacity for g in groups])
        m = RunMetrics(
            iterations_per_job=np.zeros(int(offs[-1]), dtype=np.int64))
        counts_fns = [sess._counts_fn(g) for g in groups]
        pairs_fns = ([sess._pairs_fn(g) for g in groups]
                     if self.needs_pairs else None)
        for _ in range(max_supersteps):
            actives = []
            for gi, g in enumerate(groups):
                counts = np.asarray(counts_fns[gi](g.values, g.deltas))
                act = counts > 0
                actives.append(act)
                m.iterations_per_job[offs[gi]:offs[gi + 1]][act] += 1
            if not any(a.any() for a in actives):
                m.converged = True
                break
            node_un = p_mean = None
            if self.needs_pairs:
                node_un, p_mean = [], []
                for gi, g in enumerate(groups):
                    if not actives[gi].any():   # no device pass needed:
                        z = np.zeros((g.capacity,   # converged pairs are 0
                                      sess.scheduler.num_blocks),
                                     dtype=np.float32)
                        node_un.append(z)
                        p_mean.append(z)
                        continue
                    nu, pm = map(np.asarray,
                                 pairs_fns[gi](g.values, g.deltas))
                    node_un.append(nu)
                    p_mean.append(pm)
            selection = self.select(sess, node_un, p_mean, actives)
            if selection is None:
                m.converged = True
                break
            # a fully-converged group is never pushed (matches the solo
            # session, which stops outright; for plus-times this also keeps
            # sub-tolerance residual mass where convergence left it)
            if selection.shared:
                sel = jnp.asarray(selection.sel)
                msk = jnp.asarray(selection.msk)
                for gi, g in enumerate(groups):
                    if not actives[gi].any():
                        continue
                    g.values, g.deltas = sess._push_shared_fn(g)(
                        g.values, g.deltas, g.graph.tiles, g.graph.nbr_ids,
                        sel, msk, g.push_scale)
            else:
                for gi, g in enumerate(groups):
                    if not actives[gi].any():
                        continue
                    g.values, g.deltas = sess._push_indep_fn(g)(
                        g.values, g.deltas, g.graph.tiles, g.graph.nbr_ids,
                        jnp.asarray(selection.sel[gi]),
                        jnp.asarray(selection.msk[gi]), g.push_scale)
            m.supersteps += 1
            m.tile_loads += selection.tile_loads
            m.job_block_pushes += selection.job_block_pushes
        return m


class TwoLevel(SchedulePolicy):
    """The paper's schedule: MPDS (host DO + global queue) + CAJS push.

    The global queue is synthesized across ALL jobs' DO queues regardless
    of view (block ids are view-agnostic); one staging of each selected
    block then serves both semiring families in the same superstep."""

    name = "two_level"

    def select(self, sess, node_un, p_mean, active):
        sched = sess.scheduler
        queues = []
        for nu, pm, act in zip(node_un, p_mean, active):
            queues.extend(sched.job_queues(nu, pm, act))
        gq = sched.synthesize(queues)
        if len(gq) == 0:
            return None
        q = sess.q
        # metrics honesty: only the staged prefix counts (synthesize also
        # asserts len(gq) <= q, so this clamp is a guard, not a behaviour)
        gq = gq[:q]
        sel = np.zeros(q, dtype=np.int32)
        msk = np.zeros(q, dtype=np.float32)
        sel[:len(gq)] = gq
        msk[:len(gq)] = 1.0
        # CAJS: staged once, dispatched only to jobs unconverged on the block
        pushes = sum(int((nu[:, gq] > 0).sum()) for nu in node_un)
        return Selection(sel, msk, shared=True, tile_loads=int(len(gq)),
                         job_block_pushes=pushes)


class Independent(SchedulePolicy):
    """Per-job queues processed separately (paper Fig. 3 'current mode')."""

    name = "independent"

    def select(self, sess, node_un, p_mean, active):
        q = sess.q
        sels, msks = [], []
        loads = pushes = 0
        for nu, pm, act in zip(node_un, p_mean, active):
            j_cap = nu.shape[0]
            sel = np.zeros((j_cap, q), dtype=np.int32)
            msk = np.zeros((j_cap, q), dtype=np.float32)
            for j, qj in enumerate(sess.scheduler.job_queues(nu, pm, act)):
                if len(qj) == 0:
                    continue
                sel[j, :len(qj)] = qj[:q]
                msk[j, :len(qj)] = 1.0
                loads += int(len(qj))          # each job stages its own
                pushes += int(len(qj))
            sels.append(sel)
            msks.append(msk)
        return Selection(sels, msks, shared=False, tile_loads=loads,
                         job_block_pushes=pushes)


class AllBlocks(SchedulePolicy):
    """Non-prioritized synchronous baseline: all blocks, shared staging."""

    name = "all_blocks"
    needs_pairs = False

    def select(self, sess, node_un, p_mean, active):
        bn = sess.scheduler.num_blocks
        sel = np.arange(bn, dtype=np.int32)
        msk = np.ones(bn, dtype=np.float32)
        n_active = sum(int(a.sum()) for a in active)
        return Selection(sel, msk, shared=True, tile_loads=bn,
                         job_block_pushes=bn * n_active)


class Fused(SchedulePolicy):
    """Beyond-paper: entire two-level loop in one on-device while_loop.

    Heterogeneous sessions run every view's while-loop body over one
    SHARED selection: per-group priority pairs feed one global top-q, then
    each group's semiring push (plus-times / min-plus) processes the same
    gsel — tile_loads counts that staging once, as in the host TwoLevel.
    Per-job push/iteration counters ride in the while_loop carry so
    RunMetrics stays comparable with the host policies."""

    name = "fused"
    needs_pairs = False

    def run(self, sess, max_supersteps: int = 100000) -> RunMetrics:
        groups = sess.view_groups()
        n_groups = len(groups)
        q, alpha = sess.q, sess.alpha
        bn = sess.scheduler.num_blocks
        algs = [g.alg for g in groups]
        graphs = [g.graph for g in groups]
        pushes_one = [g.push_one for g in groups]
        scales = [g.push_scale for g in groups]
        n_res = max(0, q - int(math.ceil(alpha * q)))  # reserved head slots

        def body(carry):
            it, vs, ds, loads, pushes, iters = carry
            node_uns = []
            gpri = jnp.zeros((bn,), jnp.float32)
            for gi in range(n_groups):
                node_un, p_mean = compute_pairs(algs[gi], vs[gi], ds[gi])
                node_uns.append(node_un)
                score = prio.do_score(node_un, p_mean)      # [J_g, B_N]
                topv, topi = jax.lax.top_k(score, q)        # per-job queues
                valid = jnp.isfinite(topv)
                w = jnp.arange(q, 0, -1, dtype=jnp.float32) * valid
                gpri = gpri.at[topi.reshape(-1)].add(w.reshape(-1))
                # reserve: force per-job heads into the queue (device
                # analogue of the paper's (1-alpha)q individual-head slots)
                if n_res > 0:
                    heads = topi[:, 0]
                    head_valid = valid[:, 0]
                    gpri = gpri.at[heads].add(
                        jnp.where(head_valid, 1e12, 0.0))
            gv, gsel = jax.lax.top_k(gpri, q)
            gmask = (gv > 0.0).astype(jnp.float32)
            new_vs, new_ds, new_iters = [], [], []
            for gi in range(n_groups):
                # metrics, same definitions as the host TwoLevel policy:
                # a (job, block) processing event needs the block selected
                # AND the job unconverged on it; a job iterates while any
                # block is hot.  float32 accumulator like `loads`: int32
                # would wrap on long runs (J*q per step), float32 only
                # rounds past 2^24
                pushes = pushes + jnp.sum(
                    ((node_uns[gi][:, gsel] > 0) & (gmask > 0)[None, :])
                    .astype(jnp.float32))
                new_iters.append(
                    iters[gi]
                    + jnp.any(node_uns[gi] > 0, axis=1).astype(jnp.int32))
                v2, d2 = jax.vmap(
                    pushes_one[gi],
                    in_axes=(0, 0, None, None, None, None, 0))(
                    vs[gi], ds[gi], graphs[gi].tiles, graphs[gi].nbr_ids,
                    gsel.astype(jnp.int32), gmask, scales[gi])
                new_vs.append(v2)
                new_ds.append(d2)
            # one staging of each selected block serves every view group
            return (it + 1, tuple(new_vs), tuple(new_ds),
                    loads + jnp.sum(gmask), pushes, tuple(new_iters))

        def cond(carry):
            it, vs, ds, _, _, _ = carry
            un = sum(jnp.sum(algs[gi].unconverged(vs[gi], ds[gi]))
                     for gi in range(n_groups))
            return (un > 0) & (it < max_supersteps)

        it, vs, ds, loads, pushes, iters = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0),
             tuple(g.values for g in groups),
             tuple(g.deltas for g in groups),
             jnp.float32(0), jnp.float32(0),
             tuple(jnp.zeros(g.capacity, jnp.int32) for g in groups)))
        for gi, g in enumerate(groups):
            g.values, g.deltas = vs[gi], ds[gi]
        m = RunMetrics()
        m.supersteps = int(it)
        m.tile_loads = int(loads)
        m.job_block_pushes = int(pushes)
        m.converged = bool(int(it) < max_supersteps)
        m.iterations_per_job = np.concatenate(
            [np.asarray(x, dtype=np.int64) for x in iters])
        return m


POLICIES = {p.name: p for p in (TwoLevel, Fused, Independent, AllBlocks)}
