"""Pluggable schedule policies over a GraphSession.

One `step()/run()` driver replaces the four historical near-duplicate
engine loops.  A policy decides, per superstep, WHICH blocks are staged and
WHO processes them; the driver owns everything else (convergence test,
metrics, the push dispatch).  All policies reach the same per-job fixpoint
— they differ only in schedule and therefore in tile_loads / supersteps:

  TwoLevel    - the paper: per-job DO queues -> global queue -> one staging
                of each selected block serves ALL jobs (CAJS + MPDS).
                Scheduling on host (faithful Job Controller), push on device.
  Fused       - beyond-paper: the entire loop (priority pairs, top-q, global
                accumulation, push, convergence test) is a single
                lax.while_loop on device; no host round-trips.
  Independent - redundancy baseline: each job selects and stages its own
                queue (paper Fig. 3 "current mode").
  AllBlocks   - non-prioritized baseline: every block, every superstep.

Each policy composes with `mesh=` job-axis placement (repro.dist.graph):
partitioning the vmapped job axis never changes per-job arithmetic, so the
sharded run converges to the same fixpoint.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core.push import compute_pairs


@dataclasses.dataclass
class RunMetrics:
    supersteps: int = 0
    tile_loads: int = 0            # adjacency-block stagings (HBM->VMEM)
    job_block_pushes: int = 0      # (job, block) processing events
    iterations_per_job: Optional[np.ndarray] = None
    converged: bool = False


@dataclasses.dataclass
class Selection:
    """One superstep's staging decision, produced by a host policy."""

    sel: np.ndarray          # [q] (shared staging) or [J, q] (per-job)
    msk: np.ndarray          # same shape, 1.0 = valid slot
    shared: bool             # True: one staging serves all jobs (CAJS)
    tile_loads: int
    job_block_pushes: int


class SchedulePolicy:
    """Base host-driven policy: subclasses implement `select`."""

    name = "abstract"
    needs_pairs = True  # driver computes <Node_un, P_mean> before select()

    def select(self, sess, node_un: Optional[np.ndarray],
               p_mean: Optional[np.ndarray],
               active: np.ndarray) -> Optional[Selection]:
        """Return the staging decision, or None when nothing is schedulable
        (the driver then declares convergence)."""
        raise NotImplementedError

    def run(self, sess, max_supersteps: int = 100000) -> RunMetrics:
        """Generic host driver: counts -> pairs -> select -> push."""
        g = sess.graph
        m = RunMetrics(
            iterations_per_job=np.zeros(sess.capacity, dtype=np.int64))
        pairs_fn = sess._pairs_fn()
        counts_fn = sess._counts_fn()
        values, deltas = sess.values, sess.deltas
        for _ in range(max_supersteps):
            counts = np.asarray(counts_fn(values, deltas))
            active = counts > 0
            m.iterations_per_job[active] += 1
            if not active.any():
                m.converged = True
                break
            node_un = p_mean = None
            if self.needs_pairs:
                node_un, p_mean = map(np.asarray, pairs_fn(values, deltas))
            selection = self.select(sess, node_un, p_mean, active)
            if selection is None:
                m.converged = True
                break
            push_fn = (sess._push_shared_fn() if selection.shared
                       else sess._push_indep_fn())
            values, deltas = push_fn(values, deltas, g.tiles, g.nbr_ids,
                                     jnp.asarray(selection.sel),
                                     jnp.asarray(selection.msk),
                                     sess.push_scale)
            m.supersteps += 1
            m.tile_loads += selection.tile_loads
            m.job_block_pushes += selection.job_block_pushes
        sess.values, sess.deltas = values, deltas
        return m


class TwoLevel(SchedulePolicy):
    """The paper's schedule: MPDS (host DO + global queue) + CAJS push."""

    name = "two_level"

    def select(self, sess, node_un, p_mean, active):
        gq = sess.scheduler.synthesize(
            sess.scheduler.job_queues(node_un, p_mean, active))
        if len(gq) == 0:
            return None
        q = sess.q
        sel = np.zeros(q, dtype=np.int32)
        msk = np.zeros(q, dtype=np.float32)
        sel[:len(gq)] = gq[:q]
        msk[:len(gq)] = 1.0
        # CAJS: staged once, dispatched only to jobs unconverged on the block
        return Selection(sel, msk, shared=True, tile_loads=int(len(gq)),
                         job_block_pushes=int((node_un[:, gq] > 0).sum()))


class Independent(SchedulePolicy):
    """Per-job queues processed separately (paper Fig. 3 'current mode')."""

    name = "independent"

    def select(self, sess, node_un, p_mean, active):
        q = sess.q
        j_cap = node_un.shape[0]
        sel = np.zeros((j_cap, q), dtype=np.int32)
        msk = np.zeros((j_cap, q), dtype=np.float32)
        loads = pushes = 0
        for j, qj in enumerate(
                sess.scheduler.job_queues(node_un, p_mean, active)):
            if len(qj) == 0:
                continue
            sel[j, :len(qj)] = qj[:q]
            msk[j, :len(qj)] = 1.0
            loads += int(len(qj))          # each job stages its own
            pushes += int(len(qj))
        return Selection(sel, msk, shared=False, tile_loads=loads,
                         job_block_pushes=pushes)


class AllBlocks(SchedulePolicy):
    """Non-prioritized synchronous baseline: all blocks, shared staging."""

    name = "all_blocks"
    needs_pairs = False

    def select(self, sess, node_un, p_mean, active):
        bn = sess.graph.num_blocks
        sel = np.arange(bn, dtype=np.int32)
        msk = np.ones(bn, dtype=np.float32)
        return Selection(sel, msk, shared=True, tile_loads=bn,
                         job_block_pushes=bn * int(active.sum()))


class Fused(SchedulePolicy):
    """Beyond-paper: entire two-level loop in one on-device while_loop.

    Per-job push/iteration counters ride in the while_loop carry so
    RunMetrics stays comparable with the host policies."""

    name = "fused"
    needs_pairs = False

    def run(self, sess, max_supersteps: int = 100000) -> RunMetrics:
        g = sess.graph
        alg = sess.view_alg
        q, alpha = sess.q, sess.alpha
        push = sess._push_one
        push_scale = sess.push_scale
        n_res = max(0, q - int(math.ceil(alpha * q)))  # reserved head slots

        def body(carry):
            it, values, deltas, loads, pushes, iters = carry
            node_un, p_mean = compute_pairs(alg, values, deltas)
            score = prio.do_score(node_un, p_mean)          # [J, B_N]
            topv, topi = jax.lax.top_k(score, q)            # per-job queues
            valid = jnp.isfinite(topv)
            w = jnp.arange(q, 0, -1, dtype=jnp.float32) * valid
            gpri = jnp.zeros((g.num_blocks,), jnp.float32)
            gpri = gpri.at[topi.reshape(-1)].add(w.reshape(-1))
            # reserve: force per-job heads into the queue (device analogue of
            # the paper's (1-alpha)q individual-head slots)
            if n_res > 0:
                heads = topi[:, 0]
                head_valid = valid[:, 0]
                gpri = gpri.at[heads].add(
                    jnp.where(head_valid, 1e12, 0.0))
            gv, gsel = jax.lax.top_k(gpri, q)
            gmask = (gv > 0.0).astype(jnp.float32)
            # metrics, same definitions as the host TwoLevel policy:
            # a (job, block) processing event needs the block selected AND
            # the job unconverged on it; a job iterates while any block is hot.
            # float32 accumulator like `loads`: int32 would wrap on long runs
            # (J*q per step), float32 only rounds past 2^24
            pushes = pushes + jnp.sum(
                ((node_un[:, gsel] > 0) & (gmask > 0)[None, :])
                .astype(jnp.float32))
            iters = iters + jnp.any(node_un > 0, axis=1).astype(jnp.int32)
            values, deltas = jax.vmap(
                push, in_axes=(0, 0, None, None, None, None, 0))(
                values, deltas, g.tiles, g.nbr_ids,
                gsel.astype(jnp.int32), gmask, push_scale)
            return (it + 1, values, deltas, loads + jnp.sum(gmask),
                    pushes, iters)

        def cond(carry):
            it, values, deltas, _, _, _ = carry
            un = jnp.sum(alg.unconverged(values, deltas))
            return (un > 0) & (it < max_supersteps)

        it, values, deltas, loads, pushes, iters = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), sess.values, sess.deltas, jnp.float32(0),
             jnp.float32(0), jnp.zeros(sess.capacity, jnp.int32)))
        sess.values, sess.deltas = values, deltas
        m = RunMetrics()
        m.supersteps = int(it)
        m.tile_loads = int(loads)
        m.job_block_pushes = int(pushes)
        m.converged = bool(int(it) < max_supersteps)
        m.iterations_per_job = np.asarray(iters, dtype=np.int64)
        return m


POLICIES = {p.name: p for p in (TwoLevel, Fused, Independent, AllBlocks)}
