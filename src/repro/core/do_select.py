"""Function 2: the DO algorithm — approximate top-q block selection.

Paper §4.2.2: instead of sorting all B_N blocks (O(B_N log B_N)), sample s
(default 500) pairs, sort the sample, estimate the q-th priority threshold as
the (q*s/B_N)-th sample, then one O(B_N) pass collects blocks above the
threshold; only those ~q blocks are sorted.  Total O(B_N) + O(q log q).

Two implementations share the structure:

  do_select        - host, numpy, exact CBP comparator (Function 1) —
                     the faithful transcription;
  do_select_device - jittable jnp analogue for the device-resident
                     scheduler: uniform sampling without replacement via
                     Gumbel top-k, the same cut-index threshold estimate,
                     ranking by the scalar `do_score` CBP surrogate.
                     Distributionally matches the host sampler (pinned by
                     tests/test_device_scheduler.py's frequency suite).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.priority import cbp, cbp_key_sort, do_score

DEFAULT_SAMPLES = 500  # paper default


def do_select(node_un: np.ndarray, p_mean: np.ndarray, q: int,
              rng: np.random.Generator, s: int = DEFAULT_SAMPLES) -> np.ndarray:
    """Return ~q block indices in CBP-descending order (Function 2).

    Converged blocks (node_un == 0) never enter the queue.
    """
    b_n = len(node_un)
    live = np.nonzero(node_un > 0)[0]
    if len(live) == 0:
        return np.empty(0, dtype=np.int64)
    q = max(1, min(q, len(live)))
    if len(live) <= q:           # queue covers everything that is unconverged
        order = cbp_key_sort(node_un[live], p_mean[live])
        return live[order]

    s_eff = min(s, len(live))
    samples = rng.choice(live, size=s_eff, replace=False)
    order = cbp_key_sort(node_un[samples], p_mean[samples])
    samples = samples[order]  # priority-descending

    # lower bound of the top-q priority estimated from the sample
    cutindex = min(int(q * s_eff / b_n), s_eff - 1)
    thresh = (float(node_un[samples[cutindex]]),
              float(p_mean[samples[cutindex]]))

    picked = [int(r) for r in live
              if cbp((float(node_un[r]), float(p_mean[r])), thresh)]
    if not picked:  # threshold estimate too aggressive; fall back to samples
        picked = [int(x) for x in samples[:q]]
    picked = np.asarray(picked, dtype=np.int64)
    order = cbp_key_sort(node_un[picked], p_mean[picked])
    return picked[order][:q]


def do_select_device(node_un: jnp.ndarray, p_mean: jnp.ndarray, q: int,
                     key: jax.Array, s: int = DEFAULT_SAMPLES):
    """Device Function 2 for ONE job: fixed-shape (sel [q], msk [q]).

    Mirrors `do_select` step for step so the two are distributionally
    interchangeable:
      * s live blocks are sampled uniformly without replacement (Gumbel
        top-k over uniform logits restricted to live blocks == the host's
        `rng.choice(live, s, replace=False)`);
      * the q-th priority threshold is estimated as the (q*s_eff/B_N)-th
        highest-scoring sample (same cut index as the host);
      * blocks at or above the threshold are ranked by `do_score`, the
        scalar CBP surrogate (the host ranks by the exact comparator).
    Converged blocks never enter the queue; when fewer than q blocks are
    live the queue is the whole live set, no sampling (also as the host).
    `msk` marks valid slots; invalid slots alias block 0 and must be
    masked by consumers (the push primitives already do).
    """
    b_n = node_un.shape[-1]
    k = min(q, b_n)
    score = do_score(node_un, p_mean)                  # -inf when converged
    live = node_un > 0
    n_live = jnp.sum(live.astype(jnp.int32))

    # uniform sample of s_eff live blocks, without replacement
    s_cap = max(1, min(int(s), b_n))
    gumbel = jnp.where(live, jax.random.gumbel(key, (b_n,)), -jnp.inf)
    _, samp_idx = jax.lax.top_k(gumbel, s_cap)
    s_eff = jnp.minimum(jnp.int32(s_cap), n_live)
    samp_scores = jnp.where(jnp.arange(s_cap) < s_eff,
                            score[samp_idx], -jnp.inf)
    samp_sorted = -jnp.sort(-samp_scores)              # descending

    # lower bound of the top-q priority estimated from the sample
    cut = jnp.clip((q * s_eff) // b_n, 0, jnp.maximum(s_eff - 1, 0))
    thresh = samp_sorted[cut]
    eligible = jnp.where(n_live <= k, live, live & (score >= thresh))

    topv, topi = jax.lax.top_k(jnp.where(eligible, score, -jnp.inf), k)
    msk = jnp.isfinite(topv).astype(jnp.float32)
    sel = jnp.where(msk > 0, topi, 0).astype(jnp.int32)
    if k < q:   # q beyond B_N: pad to the fixed [q] layout
        sel = jnp.pad(sel, (0, q - k))
        msk = jnp.pad(msk, (0, q - k))
    return sel, msk
