"""Function 2: the DO algorithm — approximate top-q block selection.

Paper §4.2.2: instead of sorting all B_N blocks (O(B_N log B_N)), sample s
(default 500) pairs, sort the sample, estimate the q-th priority threshold as
the (q*s/B_N)-th sample, then one O(B_N) pass collects blocks above the
threshold; only those ~q blocks are sorted.  Total O(B_N) + O(q log q).
"""

from __future__ import annotations

import numpy as np

from repro.core.priority import cbp, cbp_key_sort

DEFAULT_SAMPLES = 500  # paper default


def do_select(node_un: np.ndarray, p_mean: np.ndarray, q: int,
              rng: np.random.Generator, s: int = DEFAULT_SAMPLES) -> np.ndarray:
    """Return ~q block indices in CBP-descending order (Function 2).

    Converged blocks (node_un == 0) never enter the queue.
    """
    b_n = len(node_un)
    live = np.nonzero(node_un > 0)[0]
    if len(live) == 0:
        return np.empty(0, dtype=np.int64)
    q = max(1, min(q, len(live)))
    if len(live) <= q:           # queue covers everything that is unconverged
        order = cbp_key_sort(node_un[live], p_mean[live])
        return live[order]

    s_eff = min(s, len(live))
    samples = rng.choice(live, size=s_eff, replace=False)
    order = cbp_key_sort(node_un[samples], p_mean[samples])
    samples = samples[order]  # priority-descending

    # lower bound of the top-q priority estimated from the sample
    cutindex = min(int(q * s_eff / b_n), s_eff - 1)
    thresh = (float(node_un[samples[cutindex]]),
              float(p_mean[samples[cutindex]]))

    picked = [int(r) for r in live
              if cbp((float(node_un[r]), float(p_mean[r])), thresh)]
    if not picked:  # threshold estimate too aggressive; fall back to samples
        picked = [int(x) for x in samples[:q]]
    picked = np.asarray(picked, dtype=np.int64)
    order = cbp_key_sort(node_un[picked], p_mean[picked])
    return picked[order][:q]
