"""GraphSession: a long-lived job-lifecycle API over one shared graph.

The paper's premise is massive concurrent jobs ARRIVING AND LEAVING while
sharing one graph (its §4.4 API has `initPtable` for a "newly-arrived
job"), yet the historical engine API only ran a fixed job set to a joint
fixpoint.  A GraphSession owns the shared graph data and exposes:

  submit(alg) -> JobHandle     admit a job at ANY superstep
  run(policy, max_supersteps)  advance all active jobs under a SchedulePolicy
  step(policy)                 a single superstep
  converged(handle)            per-job convergence test
  result(handle)               per-job result extraction
  detach(handle)               release the job's slot for reuse

Sessions are HETEROGENEOUS: jobs from both semiring families (PageRank/
PPR/Katz under plus-times, SSSP/BFS/WCC under min-plus) coexist over one
shared CSR.  Internally the session keeps a registry of ViewGroups, one
per graph-view key `(semiring, fill, normalize, symmetrize)`.  Each view's
BlockedGraph is derived lazily from the shared CSR with the SAME block
size, so block id b names the same vertex range in every view — which is
what lets one scheduling decision (a set of block ids) drive every family
at once: the paper's CAJS staging of block b serves the plus-times push
and the min-plus push in the same superstep, and `RunMetrics.tile_loads`
counts that staging once.

Each group maintains a PADDED [J_view_cap, B_N, Vb] job axis plus an
active mask, so jitted push shapes stay stable across arrivals/departures:
free slots hold the semiring's inert state (delta 0 / +inf), which makes
them arithmetic no-ops in every policy — no re-tracing on submit/detach.
Slots are recycled; handle generations catch stale use.  A group's
capacity doubles (one re-trace) only when submissions exceed it.

`run(..., mesh=...)` composes any policy with job-axis placement from
repro.dist.graph (every view's tiles replicated, every group's job state
sharded over its own job axis).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm, PLUS_TIMES
from repro.core.policy import RunMetrics, SchedulePolicy, TwoLevel
from repro.core.push import (compute_pairs, indep_push_fn, push_plus_one,
                             push_min_one, shared_push_fn)
from repro.core.scheduler import (TwoLevelScheduler, optimal_queue_length,
                                  PRITER_C)
from repro.core.do_select import DEFAULT_SAMPLES
from repro.core.global_q import DEFAULT_ALPHA
from repro.graph.structure import (BlockedGraph, CSRGraph, TileOverlay,
                                   build_blocked, empty_overlay)
from repro.obs.telemetry import TelemetryConfig
from repro.obs.trace import TraceRecorder


@dataclasses.dataclass(frozen=True)
class JobHandle:
    """Ticket for a submitted job; stale after detach (generation check)."""

    slot: int
    gen: int
    alg: Algorithm
    view: Optional[tuple] = None   # graph-view key; derived from alg if None


def _view_key(alg: Algorithm) -> tuple:
    return (alg.semiring, alg.graph_fill, alg.graph_normalize,
            alg.graph_symmetrize)


@dataclasses.dataclass
class ViewGroup:
    """One graph view + the padded job axis of every job using it.

    `alg` is the view's exemplar (the first job submitted into it): it
    supplies the pair computation / convergence test / inert fill for the
    whole group, exactly as the pre-heterogeneous session used its first
    submitted algorithm.  All jobs in a group share the semiring by
    construction (the semiring is part of the view key).
    """

    key: tuple
    alg: Algorithm
    graph: BlockedGraph
    push_one: Callable
    values: jnp.ndarray       # [cap, B_N, Vb]
    deltas: jnp.ndarray       # [cap, B_N, Vb]
    push_scale: jnp.ndarray   # [cap]
    algs: List[Optional[Algorithm]]
    active: np.ndarray        # [cap] bool
    gens: List[int]
    # evolving-graph state (repro.stream): the bounded per-block delta-COO
    # staged alongside the tiles (capacity 0 until the first structural
    # insert), plus host mirrors of the blocked structure that
    # apply_updates needs to classify edits — built lazily on first use
    overlay: Optional[TileOverlay] = None
    pair_slot: Optional[Dict] = None   # {(src block, dst block): slot}
    ov_used: Optional[np.ndarray] = None   # [B_N, C] bool
    ov_entry: Optional[Dict] = None    # {(u, v) padded ids: (block, col)}
    # destination-sorted sparse block-pair view of `graph` (the fused
    # megakernel's adjacency + the real-bytes tile_pair_loads accounting)
    # — built lazily by session._pair_data, dropped to None whenever the
    # tiles change (stream structural edits, compaction)
    pairs: Optional[object] = None
    # dst-partitioned PairShards of `pairs` for a 2D (jobs x blocks) mesh
    # (repro.dist.mesh2d), cached as (source BlockPairs, mesh signature,
    # placed shards) — the strong reference makes the identity check safe
    # and a rebuild of `pairs` (compaction) auto-invalidates the partition
    pair_shards: Optional[tuple] = None

    @property
    def capacity(self) -> int:
        return len(self.algs)

    @property
    def semiring(self) -> str:
        return self.key[0]

    @property
    def num_active(self) -> int:
        return int(self.active.sum())


def _inert_state(semiring: str, g: BlockedGraph, n: int):
    """State for free slots: converged-everywhere, pushes are no-ops."""
    fill = 0.0 if semiring == PLUS_TIMES else jnp.inf
    shape = (n, g.num_blocks, g.block_size)
    return (jnp.full(shape, fill, dtype=jnp.float32),
            jnp.full(shape, fill, dtype=jnp.float32))


class GraphSession:
    """Owns the shared graph data + per-view padded, recyclable job axes."""

    def __init__(self, csr: Optional[CSRGraph] = None, block_size: int = 64,
                 *, capacity: int = 4, c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA, samples: int = DEFAULT_SAMPLES,
                 seed: int = 0, use_pallas: bool = False,
                 overlay_capacity: int = 32, telemetry=None):
        self._csr = csr
        # observability (repro.obs): telemetry=True / TelemetryConfig(...)
        # turns on per-superstep series + trace recording; None/False (the
        # default) compiles the exact pre-observability programs
        self.telemetry: Optional[TelemetryConfig] = \
            TelemetryConfig.coerce(telemetry)
        self.trace = TraceRecorder(
            enabled=self.telemetry is not None and self.telemetry.trace)
        self.trace.name_thread(2, "supersteps")
        self.block_size = block_size
        self._capacity0 = max(1, int(capacity))   # initial per-view capacity
        self.c = c
        self._alpha = alpha
        self._samples = samples
        self._seed = seed
        self.use_pallas = use_pallas
        # evolving graphs (repro.stream): per-block delta-COO budget a view
        # grows to on its first structural insert; a full block row triggers
        # compaction (BlockedGraph rebuilt from the updated CSR)
        self.overlay_capacity = max(1, int(overlay_capacity))
        self._dirty_boost: Optional[np.ndarray] = None  # [B_N] pending boost
        self._stream_pending = {"updates_applied": 0, "dirty_blocks": 0,
                                "reseed_num": 0, "reseed_den": 0}
        # view registry, populated lazily on submit (insertion-ordered; the
        # order defines the concatenated job-metric layout, see job_index)
        self.groups: Dict[tuple, ViewGroup] = {}
        self.scheduler: Optional[TwoLevelScheduler] = None
        self.q = 0
        self._jit_cache = {}
        # 2D (jobs x blocks) mesh placement (repro.dist.mesh2d.Mesh2DSpec)
        # or None; set by shard_session_2d, cleared by unshard_session —
        # reroutes the device superstep and the push functions while set
        self._mesh2d = None

    # alpha/samples/seed live canonically on the scheduler once it exists
    # (every policy must see one consistent value); before the first submit
    # they are held locally

    @property
    def alpha(self) -> float:
        return self.scheduler.alpha if self.scheduler else self._alpha

    @alpha.setter
    def alpha(self, value: float) -> None:
        self._alpha = value
        if self.scheduler:
            self.scheduler.alpha = value

    @property
    def samples(self) -> int:
        return self.scheduler.samples if self.scheduler else self._samples

    @samples.setter
    def samples(self, value: int) -> None:
        self._samples = value
        if self.scheduler:
            self.scheduler.samples = value

    @property
    def seed(self) -> int:
        return self.scheduler.seed if self.scheduler else self._seed

    @seed.setter
    def seed(self, value: int) -> None:
        self._seed = value
        if self.scheduler:
            self.scheduler.reset(value)  # re-seeds AND restarts the stream

    # -- view registry -------------------------------------------------------

    def view_groups(self) -> List[ViewGroup]:
        """All view groups in creation order (the metric layout order)."""
        return list(self.groups.values())

    @property
    def total_capacity(self) -> int:
        return sum(g.capacity for g in self.groups.values())

    @property
    def capacity(self) -> int:
        """Total padded slots across views (initial capacity pre-submit)."""
        return self.total_capacity if self.groups else self._capacity0

    def _sole_group(self) -> ViewGroup:
        if len(self.groups) != 1:
            raise ValueError(
                f"session holds {len(self.groups)} graph views; "
                "per-view state has no single values/deltas/graph — use "
                "view_groups()")
        return next(iter(self.groups.values()))

    # single-view compatibility surface (the legacy engine shim and all
    # homogeneous callers): delegates to the one group

    @property
    def graph(self):
        return next(iter(self.groups.values())).graph if self.groups else None

    @property
    def view_alg(self) -> Optional[Algorithm]:
        return next(iter(self.groups.values())).alg if self.groups else None

    @property
    def values(self):
        return self._sole_group().values

    @values.setter
    def values(self, v) -> None:
        self._sole_group().values = v

    @property
    def deltas(self):
        return self._sole_group().deltas

    @deltas.setter
    def deltas(self, d) -> None:
        self._sole_group().deltas = d

    @property
    def push_scale(self):
        return self._sole_group().push_scale

    @push_scale.setter
    def push_scale(self, p) -> None:
        self._sole_group().push_scale = p

    # -- construction from a legacy ConcurrentRun ---------------------------

    @classmethod
    def from_run(cls, run, *, c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES, seed: int = 0,
                 use_pallas: bool = False) -> "GraphSession":
        """Adopt a pre-built ConcurrentRun: one view, capacity == J, no
        padding, so the legacy engine shim stays bit-identical to the
        historical API."""
        sess = cls(None, run.graph.block_size, capacity=run.num_jobs,
                   c=c, alpha=alpha, samples=samples, seed=seed,
                   use_pallas=use_pallas)
        a0 = run.algs[0]
        sess._install_scheduler(run.graph)
        sess.groups[_view_key(a0)] = ViewGroup(
            key=_view_key(a0), alg=a0, graph=run.graph,
            push_one=(push_plus_one if a0.semiring == PLUS_TIMES
                      else push_min_one),
            values=run.values, deltas=run.deltas, push_scale=run.push_scale,
            algs=list(run.algs),
            active=np.ones(run.num_jobs, dtype=bool),
            gens=[0] * run.num_jobs,
            overlay=empty_overlay(run.graph.num_blocks))
        return sess

    # -- graph / scheduler initialisation ------------------------------------

    def _install_scheduler(self, g: BlockedGraph) -> None:
        """First view sets q + the scheduler; later views must be
        block-aligned (same B_N ⇒ block id b names the same vertex range in
        every view), which same-n/same-block-size construction guarantees."""
        if self.scheduler is None:
            self.q = optimal_queue_length(g.num_blocks, g.n_real, self.c)
            self.scheduler = TwoLevelScheduler(
                g.num_blocks, self.q, alpha=self.alpha, samples=self.samples,
                seed=self.seed)
        elif g.num_blocks != self.scheduler.num_blocks:
            raise ValueError(
                f"view is not block-aligned: {g.num_blocks} blocks != "
                f"{self.scheduler.num_blocks}")

    def _group_for(self, alg: Algorithm) -> ViewGroup:
        key = _view_key(alg)
        grp = self.groups.get(key)
        if grp is not None:
            return grp
        if self._csr is None:
            raise ValueError("GraphSession needs a CSRGraph to build from")
        g_csr = (self._csr.symmetrized() if alg.graph_symmetrize
                 else self._csr)
        g = build_blocked(g_csr, self.block_size, fill=alg.graph_fill,
                          normalize=alg.graph_normalize)
        self._install_scheduler(g)
        cap = self._capacity0
        values, deltas = _inert_state(alg.semiring, g, cap)
        grp = ViewGroup(
            key=key, alg=alg, graph=g,
            push_one=(push_plus_one if alg.semiring == PLUS_TIMES
                      else push_min_one),
            values=values, deltas=deltas,
            push_scale=jnp.ones(cap, dtype=jnp.float32),
            algs=[None] * cap, active=np.zeros(cap, dtype=bool),
            gens=[0] * cap,
            overlay=empty_overlay(g.num_blocks))
        self.groups[key] = grp
        return grp

    def _grow(self, grp: ViewGroup) -> None:
        extra = grp.capacity
        iv, idl = _inert_state(grp.semiring, grp.graph, extra)
        grp.values = jnp.concatenate([grp.values, iv])
        grp.deltas = jnp.concatenate([grp.deltas, idl])
        grp.push_scale = jnp.concatenate(
            [grp.push_scale, jnp.ones(extra, dtype=jnp.float32)])
        grp.algs.extend([None] * extra)
        grp.gens.extend([0] * extra)
        grp.active = np.concatenate(
            [grp.active, np.zeros(extra, dtype=bool)])

    # -- job lifecycle -------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(g.num_active for g in self.groups.values())

    def submit(self, alg: Algorithm) -> JobHandle:
        """Admit a job at any superstep; recycles a free slot or grows its
        view group.  Jobs of a NEW graph view build that view lazily from
        the shared CSR and coexist with every already-running family."""
        grp = self._group_for(alg)
        free = np.nonzero(~grp.active)[0]
        if len(free) == 0:
            self._grow(grp)
            free = np.nonzero(~grp.active)[0]
        slot = int(free[0])
        v, d = alg.init(grp.graph)
        grp.values = grp.values.at[slot].set(v)
        grp.deltas = grp.deltas.at[slot].set(d)
        grp.push_scale = grp.push_scale.at[slot].set(alg.get_push_scale())
        grp.algs[slot] = alg
        grp.active[slot] = True
        self.trace.instant("submit", cat="job", alg=type(alg).__name__,
                           view=str(grp.key), slot=slot)
        return JobHandle(slot=slot, gen=grp.gens[slot], alg=alg, view=grp.key)

    def _handle_group(self, handle: JobHandle) -> ViewGroup:
        key = handle.view if handle.view is not None else _view_key(handle.alg)
        grp = self.groups.get(key)
        if grp is None or not (0 <= handle.slot < grp.capacity) \
                or grp.gens[handle.slot] != handle.gen \
                or not grp.active[handle.slot]:
            raise KeyError(f"stale or unknown job handle {handle}")
        return grp

    def job_index(self, handle: JobHandle) -> int:
        """Index of this job in the concatenated per-group layout used by
        `unconverged_counts()` and `RunMetrics.iterations_per_job` (view
        groups in creation order, slots within a group).  For a single-view
        session this equals `handle.slot`."""
        grp = self._handle_group(handle)
        off = 0
        for g in self.groups.values():
            if g is grp:
                return off + handle.slot
            off += g.capacity
        raise KeyError(f"unknown view for handle {handle}")

    def unconverged_counts(self) -> np.ndarray:
        """[total_capacity] unconverged-vertex count per slot, view groups
        concatenated in creation order (0 for free slots) — one device
        reduction per view; index by `job_index(handle)` to poll many
        handles (== handle.slot for single-view sessions)."""
        parts = [jax.device_get(self._counts_fn(g)(g.values, g.deltas))
                 for g in self.groups.values()]
        return (np.concatenate(parts) if parts
                else np.zeros(0, dtype=np.int32))

    def converged(self, handle: JobHandle) -> bool:
        grp = self._handle_group(handle)
        counts = jax.device_get(self._counts_fn(grp)(grp.values, grp.deltas))
        return bool(counts[handle.slot] == 0)

    def result(self, handle: JobHandle) -> np.ndarray:
        """[n_real] result for one job (valid at any superstep)."""
        grp = self._handle_group(handle)
        res = handle.alg.result(grp.values[handle.slot],
                                grp.deltas[handle.slot])
        return jax.device_get(res).reshape(-1)[:grp.graph.n_real]

    def detach(self, handle: JobHandle) -> np.ndarray:
        """Extract the job's result and free its slot for reuse."""
        res = self.result(handle)
        grp = self._handle_group(handle)
        slot = handle.slot
        iv, idl = _inert_state(grp.semiring, grp.graph, 1)
        grp.values = grp.values.at[slot].set(iv[0])
        grp.deltas = grp.deltas.at[slot].set(idl[0])
        grp.push_scale = grp.push_scale.at[slot].set(1.0)
        grp.algs[slot] = None
        grp.active[slot] = False
        grp.gens[slot] += 1
        self.trace.instant("detach", cat="job",
                           alg=type(handle.alg).__name__,
                           view=str(grp.key), slot=slot)
        return res

    # -- evolving graphs (repro.stream) --------------------------------------

    def apply_updates(self, batch) -> "RunMetrics":
        """Apply a live edge insert/delete/reweight batch while jobs run.

        The shared CSR is the source of truth: the batch updates it
        exactly, then every view group absorbs the change — in-place tile
        edits for block pairs that own a tile slot, the bounded per-block
        delta-COO overlay for structurally-new pairs (a full overlay row
        compacts the view: BlockedGraph rebuilt from the updated CSR,
        bit-identical to a from-scratch build) — and every job's state is
        invalidated just enough to converge to the NEW graph's fixpoint
        (see repro.stream.invalidate).  Affected blocks are remembered and
        injected as priority boosts into the next run()'s DO queues, so
        the two-level scheduler prioritizes update-affected data for all
        concurrent jobs at once.  Callable at any superstep between
        run()/step() calls; returns the accumulated stream counters (also
        drained into the next run()'s RunMetrics)."""
        from repro.stream.apply import apply_updates_to_session
        return apply_updates_to_session(self, batch)

    def compact(self) -> None:
        """Force compaction of every view: rebuild each BlockedGraph from
        the updated CSR (bit-identical to a from-scratch build) and empty
        the overlays.  Happens automatically when an overlay row fills."""
        from repro.stream.apply import compact_group
        if self._csr is None:
            raise ValueError(
                "compact needs the session-owned CSRGraph (sessions "
                "adopted from a legacy ConcurrentRun have none)")
        for grp in self.view_groups():
            compact_group(self, grp)

    def _consume_dirty_boost(self) -> Optional[np.ndarray]:
        """[B_N] pending priority injection for update-affected blocks, or
        None; consumed by the first superstep of the next run."""
        boost, self._dirty_boost = self._dirty_boost, None
        return boost

    def _drain_stream_stats(self, metrics) -> None:
        p = self._stream_pending
        metrics.updates_applied = p["updates_applied"]
        metrics.dirty_blocks = p["dirty_blocks"]
        metrics.reseed_fraction = (p["reseed_num"] / p["reseed_den"]
                                   if p["reseed_den"] else 0.0)
        self._stream_pending = {"updates_applied": 0, "dirty_blocks": 0,
                                "reseed_num": 0, "reseed_den": 0}

    # -- jitted primitives (shared by every policy), cached per view ---------

    def _device_step_fn(self, policy):
        """Compiled device superstep for `policy`, cached on the session.

        Keyed on everything that shapes the traced program: the policy's
        selection code (the `device_select` function itself plus
        needs_pairs, so `Fused()` and the literal
        `TwoLevel(backend="device", steps_per_sync=inf)` share one
        compilation while a subclass overriding `device_select` gets its
        own), steps_per_sync, the view keys (which algs/semirings
        participate), per-view capacities (array shapes), q, alpha,
        samples, the pallas toggle and the telemetry capacity (0 when
        off, so a telemetry-off session compiles the exact
        pre-observability program and an on/off pair never shares — or
        invalidates — a cache entry).  Repeated run() calls,
        submit/detach cycles at unchanged capacity, and re-placement on a
        mesh all REUSE the same compilation (jax re-specializes on
        shardings internally); only a genuinely new program shape — a new
        view, a capacity doubling, a different sync cadence — compiles
        again."""
        from repro.core.policy import build_device_step
        groups = self.view_groups()
        tel_cap = self.telemetry.capacity if self.telemetry else 0
        key = ("superstep", type(policy).device_select, policy.needs_pairs,
               policy.steps_per_sync,
               tuple(g.key for g in groups),
               tuple(g.capacity for g in groups),
               tuple(g.overlay.capacity for g in groups),
               self.q, float(self.alpha), int(self.samples),
               self.use_pallas, tel_cap)
        if self._mesh2d is not None:
            # the 2D superstep closes over the mesh layout AND the pair
            # partition's shapes (the shard_map in_specs pytrees), so both
            # join the key; leaving the mesh falls back to the 1D entry —
            # one entry per (policy, shape, placement), never growth per
            # run() (pinned by tests/test_dist_mesh2d.py retrace test)
            from repro.dist.mesh2d import build_device_step_2d
            key = key + (self._mesh2d.signature(),
                         tuple(self._pair_shards(g).tree_flatten()[1]
                               for g in groups))
            if key not in self._jit_cache:
                self._jit_cache[key] = build_device_step_2d(
                    policy, self, self._mesh2d)
            return self._jit_cache[key]
        if key not in self._jit_cache:
            self._jit_cache[key] = build_device_step(policy, self)
        return self._jit_cache[key]

    def _pairs_fn(self, grp: ViewGroup, with_resid: bool = False):
        """with_resid=True additionally returns the group's max vertex
        priority (the telemetry residual) from the SAME jitted program —
        telemetry must not add a device dispatch per superstep."""
        key = ("pairs", grp.key, with_resid)
        if key not in self._jit_cache:
            alg = grp.alg
            if with_resid:
                self._jit_cache[key] = jax.jit(
                    lambda v, d: (*compute_pairs(alg, v, d),
                                  jnp.max(alg.vertex_priority(v, d))))
            else:
                self._jit_cache[key] = jax.jit(
                    lambda v, d: compute_pairs(alg, v, d))
        return self._jit_cache[key]

    def _counts_fn(self, grp: ViewGroup, with_resid: bool = False):
        key = ("counts", grp.key, with_resid)
        if key not in self._jit_cache:
            alg = grp.alg
            if with_resid:
                self._jit_cache[key] = jax.jit(
                    lambda v, d: (jnp.sum(alg.unconverged(v, d),
                                          axis=(1, 2)),
                                  jnp.max(alg.vertex_priority(v, d))))
            else:
                self._jit_cache[key] = jax.jit(
                    lambda v, d: jnp.sum(alg.unconverged(v, d),
                                         axis=(1, 2)))
        return self._jit_cache[key]

    def _pair_data(self, grp: ViewGroup):
        """The view's destination-sorted `BlockPairs`, built lazily from
        the CURRENT tiles and cached on the group; stream structural
        edits / compaction invalidate it (set `grp.pairs = None`) so the
        next run rebuilds from the edited tiles."""
        if grp.pairs is None:
            from repro.graph.structure import build_block_pairs
            grp.pairs = build_block_pairs(grp.graph)
        return grp.pairs

    def _push_shared_fn(self, grp: ViewGroup):
        """All jobs of the view process the same selected blocks (CAJS)."""
        if self._mesh2d is not None:
            key = ("push_shared2d", grp.key, self.use_pallas,
                   self._mesh2d.signature())
            if key not in self._jit_cache:
                from repro.dist.mesh2d import shared_push_fn_2d
                self._jit_cache[key] = shared_push_fn_2d(
                    self._mesh2d, grp, self.use_pallas)
            return self._jit_cache[key]
        key = ("push_shared", grp.key, self.use_pallas)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(shared_push_fn(
                grp.semiring, grp.push_one, self.use_pallas))
        return self._jit_cache[key]

    def _push_indep_fn(self, grp: ViewGroup):
        """Each job processes its own selection (redundancy baseline)."""
        if self._mesh2d is not None:
            key = ("push_indep2d", grp.key, self._mesh2d.signature())
            if key not in self._jit_cache:
                from repro.dist.mesh2d import indep_push_fn_2d
                self._jit_cache[key] = indep_push_fn_2d(self._mesh2d, grp)
            return self._jit_cache[key]
        key = ("push_indep", grp.key)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(indep_push_fn(grp.push_one))
        return self._jit_cache[key]

    def _pair_shards(self, grp: ViewGroup):
        """The view's dst-partitioned `PairShards` on the current 2D mesh
        (repro.dist.mesh2d), cached on the group against the identity of
        the source BlockPairs and the mesh signature — compaction rebuilds
        `grp.pairs`, so the partition follows automatically; blocks-
        replicated groups get the trivial 1-shard partition."""
        from repro.dist.mesh2d import (partition_block_pairs,
                                       place_pair_shards)
        spec = self._mesh2d
        bp = self._pair_data(grp)
        lay = spec.layout(grp)
        n = spec.block_shards if lay.blocks_sharded else 1
        cached = grp.pair_shards
        if (cached is not None and cached[0] is bp
                and cached[1] == spec.signature()):
            return cached[2]
        fill = float(grp.alg.graph_fill)
        ps = place_pair_shards(spec, partition_block_pairs(bp, n, fill),
                               lay.blocks_sharded)
        grp.pair_shards = (bp, spec.signature(), ps)
        return ps

    # -- placement -----------------------------------------------------------

    def _place(self, mesh) -> None:
        """Shard every view group's job axis over `mesh` (repro.dist.graph):
        each view's tiles replicated per device, its values/deltas
        job-sharded.  Scheduling is unchanged — SPMD partitions the vmapped
        pushes along each job axis, so per-job arithmetic (and the fixpoint)
        is identical."""
        if mesh is None:
            return
        # a mesh with >= 2 named axes selects the 2D (jobs x blocks)
        # placement; shard_session also clears a previous 2D placement
        # when re-placing on a 1D mesh
        from repro.dist.graph import shard_session
        shard_session(mesh, self)

    # -- driving -------------------------------------------------------------

    def run(self, policy: Optional[SchedulePolicy] = None,
            max_supersteps: int = 100000, *, mesh=None) -> RunMetrics:
        """Advance all active jobs until they converge (or the budget ends).

        Jobs submitted after this returns resume from the shared state:
        call run() again to drive the new mix — that is the arrival model.
        Under a device-backend policy with steps_per_sync=K the session
        only regains control every K supersteps, so an arrival waits up to
        K supersteps before the next run() can admit it (see docs/API.md,
        "Scheduler backends")."""
        if not self.groups:
            raise ValueError("no jobs submitted yet")
        policy = TwoLevel() if policy is None else policy
        self._place(mesh)
        t_run = self.trace.now_us() if self.trace.enabled else 0.0
        m = policy.run(self, max_supersteps)
        self._drain_stream_stats(m)
        if self.trace.enabled:
            self._trace_run(policy, m, t_run)
        return m

    def _trace_run(self, policy, m: RunMetrics, t_run: float) -> None:
        """One run() span + counter tracks from the telemetry series."""
        dur = self.trace.now_us() - t_run
        self.trace.complete("run", t_run, dur, cat="run",
                            policy=policy.name, **m.to_dict())
        if m.converged:
            self.trace.instant("converged", cat="run",
                               supersteps=int(m.supersteps))
        tel = m.telemetry
        if tel is None or len(tel) == 0:
            return
        # counter samples interpolated across the run span (the device
        # backend has no per-superstep wall clock); stride caps the event
        # volume for very long runs
        k = len(tel)
        stride = max(1, k // 2000)
        for i in range(0, k, stride):
            ts = t_run + dur * (i + 1) / k
            vals = {"active_jobs": int(tel.active_jobs[i]),
                    "tile_loads": int(tel.tile_loads[i]),
                    "job_block_pushes": int(tel.job_block_pushes[i]),
                    "gq_occupancy": int(tel.gq_occupancy[i]),
                    "dirty_blocks": int(tel.dirty_blocks[i]),
                    "tile_pair_loads": int(tel.tile_pair_loads[i]),
                    "halo_bytes": float(tel.halo_bytes[i])}
            self.trace.counter("telemetry", vals, ts_us=ts)
            for gi in range(tel.num_groups):
                self.trace.counter(
                    f"group{gi}",
                    {"unconverged": int(tel.unconverged[i, gi]),
                     "max_residual": float(tel.max_residual[i, gi])},
                    ts_us=ts)

    def step(self, policy: Optional[SchedulePolicy] = None) -> RunMetrics:
        """A single superstep under `policy`."""
        return self.run(policy, max_supersteps=1)
