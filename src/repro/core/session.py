"""GraphSession: a long-lived job-lifecycle API over one shared graph.

The paper's premise is massive concurrent jobs ARRIVING AND LEAVING while
sharing one graph (its §4.4 API has `initPtable` for a "newly-arrived
job"), yet the historical engine API only ran a fixed job set to a joint
fixpoint.  A GraphSession owns the shared BlockedGraph and exposes:

  submit(alg) -> JobHandle     admit a job at ANY superstep
  run(policy, max_supersteps)  advance all active jobs under a SchedulePolicy
  step(policy)                 a single superstep
  converged(handle)            per-job convergence test
  result(handle)               per-job result extraction
  detach(handle)               release the job's slot for reuse

Internally the session maintains a PADDED [J_cap, B_N, Vb] job axis plus an
active mask, so jitted push shapes stay stable across arrivals/departures:
free slots hold the semiring's inert state (delta 0 / +inf), which makes
them arithmetic no-ops in every policy — no re-tracing on submit/detach.
Slots are recycled; handle generations catch stale use.  Capacity doubles
(one re-trace) only when submissions exceed it.

`run(..., mesh=...)` composes any policy with job-axis placement from
repro.dist.graph (tiles replicated, job state sharded).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm, PLUS_TIMES
from repro.core.policy import RunMetrics, SchedulePolicy, TwoLevel
from repro.core.push import compute_pairs, push_plus_one, push_min_one
from repro.core.scheduler import (TwoLevelScheduler, optimal_queue_length,
                                  PRITER_C)
from repro.core.do_select import DEFAULT_SAMPLES
from repro.core.global_q import DEFAULT_ALPHA
from repro.graph.structure import CSRGraph, build_blocked


@dataclasses.dataclass(frozen=True)
class JobHandle:
    """Ticket for a submitted job; stale after detach (generation check)."""

    slot: int
    gen: int
    alg: Algorithm


def _view_key(alg: Algorithm):
    return (alg.semiring, alg.graph_fill, alg.graph_normalize,
            alg.graph_symmetrize)


class GraphSession:
    """Owns one shared BlockedGraph + a padded, recyclable job axis."""

    def __init__(self, csr: Optional[CSRGraph] = None, block_size: int = 64,
                 *, capacity: int = 4, c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA, samples: int = DEFAULT_SAMPLES,
                 seed: int = 0, use_pallas: bool = False):
        self._csr = csr
        self.block_size = block_size
        self.capacity = max(1, int(capacity))
        self.c = c
        self._alpha = alpha
        self._samples = samples
        self._seed = seed
        self.use_pallas = use_pallas
        # populated on first submit (the graph view depends on the algorithm)
        self.graph = None
        self.view_alg: Optional[Algorithm] = None
        self.scheduler: Optional[TwoLevelScheduler] = None
        self.q = 0
        self._push_one = None
        self.values = self.deltas = self.push_scale = None
        self.algs: List[Optional[Algorithm]] = [None] * self.capacity
        self.active = np.zeros(self.capacity, dtype=bool)
        self._gens = [0] * self.capacity
        self._jit_cache = {}

    # alpha/samples/seed live canonically on the scheduler once it exists
    # (every policy must see one consistent value); before the first submit
    # they are held locally

    @property
    def alpha(self) -> float:
        return self.scheduler.alpha if self.scheduler else self._alpha

    @alpha.setter
    def alpha(self, value: float) -> None:
        self._alpha = value
        if self.scheduler:
            self.scheduler.alpha = value

    @property
    def samples(self) -> int:
        return self.scheduler.samples if self.scheduler else self._samples

    @samples.setter
    def samples(self, value: int) -> None:
        self._samples = value
        if self.scheduler:
            self.scheduler.samples = value

    @property
    def seed(self) -> int:
        return self.scheduler.seed if self.scheduler else self._seed

    @seed.setter
    def seed(self, value: int) -> None:
        self._seed = value
        if self.scheduler:
            self.scheduler.reset(value)  # re-seeds AND restarts the stream

    # -- construction from a legacy ConcurrentRun ---------------------------

    @classmethod
    def from_run(cls, run, *, c: float = PRITER_C,
                 alpha: float = DEFAULT_ALPHA,
                 samples: int = DEFAULT_SAMPLES, seed: int = 0,
                 use_pallas: bool = False) -> "GraphSession":
        """Adopt a pre-built ConcurrentRun: capacity == J, no padding, so
        the legacy engine shim stays bit-identical to the historical API."""
        sess = cls(None, run.graph.block_size, capacity=run.num_jobs,
                   c=c, alpha=alpha, samples=samples, seed=seed,
                   use_pallas=use_pallas)
        sess._install_graph(run.graph, run.algs[0])
        sess.values = run.values
        sess.deltas = run.deltas
        sess.push_scale = run.push_scale
        sess.algs = list(run.algs)
        sess.active[:] = True
        return sess

    # -- graph / state initialisation ---------------------------------------

    def _install_graph(self, g, view_alg: Algorithm) -> None:
        self.graph = g
        self.view_alg = view_alg
        self.q = optimal_queue_length(g.num_blocks, g.n_real, self.c)
        self.scheduler = TwoLevelScheduler(
            g.num_blocks, self.q, alpha=self.alpha, samples=self.samples,
            seed=self.seed)
        self._push_one = (push_plus_one
                          if view_alg.semiring == PLUS_TIMES
                          else push_min_one)

    def _inert_state(self, n: int):
        """State for free slots: converged-everywhere, pushes are no-ops."""
        g = self.graph
        fill = 0.0 if self.view_alg.semiring == PLUS_TIMES else jnp.inf
        shape = (n, g.num_blocks, g.block_size)
        return (jnp.full(shape, fill, dtype=jnp.float32),
                jnp.full(shape, fill, dtype=jnp.float32))

    def _ensure_graph(self, alg: Algorithm) -> None:
        if self.graph is not None:
            if _view_key(alg) != _view_key(self.view_alg):
                raise ValueError(
                    "concurrent jobs must share one graph view: "
                    f"{_view_key(alg)} != {_view_key(self.view_alg)}")
            return
        if self._csr is None:
            raise ValueError("GraphSession needs a CSRGraph to build from")
        g_csr = (self._csr.symmetrized() if alg.graph_symmetrize
                 else self._csr)
        g = build_blocked(g_csr, self.block_size, fill=alg.graph_fill,
                          normalize=alg.graph_normalize)
        self._install_graph(g, alg)
        self.values, self.deltas = self._inert_state(self.capacity)
        self.push_scale = jnp.ones(self.capacity, dtype=jnp.float32)

    def _grow(self) -> None:
        extra = self.capacity
        iv, idl = self._inert_state(extra)
        self.values = jnp.concatenate([self.values, iv])
        self.deltas = jnp.concatenate([self.deltas, idl])
        self.push_scale = jnp.concatenate(
            [self.push_scale, jnp.ones(extra, dtype=jnp.float32)])
        self.algs.extend([None] * extra)
        self._gens.extend([0] * extra)
        self.active = np.concatenate(
            [self.active, np.zeros(extra, dtype=bool)])
        self.capacity += extra

    # -- job lifecycle -------------------------------------------------------

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    def submit(self, alg: Algorithm) -> JobHandle:
        """Admit a job at any superstep; recycles a free slot or grows."""
        self._ensure_graph(alg)
        free = np.nonzero(~self.active)[0]
        if len(free) == 0:
            self._grow()
            free = np.nonzero(~self.active)[0]
        slot = int(free[0])
        v, d = alg.init(self.graph)
        self.values = self.values.at[slot].set(v)
        self.deltas = self.deltas.at[slot].set(d)
        self.push_scale = self.push_scale.at[slot].set(alg.get_push_scale())
        self.algs[slot] = alg
        self.active[slot] = True
        return JobHandle(slot=slot, gen=self._gens[slot], alg=alg)

    def _check(self, handle: JobHandle) -> None:
        if not (0 <= handle.slot < self.capacity) \
                or self._gens[handle.slot] != handle.gen \
                or not self.active[handle.slot]:
            raise KeyError(f"stale or unknown job handle {handle}")

    def unconverged_counts(self) -> np.ndarray:
        """[J_cap] unconverged-vertex count per slot (0 for free slots) —
        one device reduction; index by handle.slot to poll many handles."""
        return np.asarray(self._counts_fn()(self.values, self.deltas))

    def converged(self, handle: JobHandle) -> bool:
        self._check(handle)
        return bool(self.unconverged_counts()[handle.slot] == 0)

    def result(self, handle: JobHandle) -> np.ndarray:
        """[n_real] result for one job (valid at any superstep)."""
        self._check(handle)
        res = handle.alg.result(self.values[handle.slot],
                                self.deltas[handle.slot])
        return np.asarray(res).reshape(-1)[:self.graph.n_real]

    def detach(self, handle: JobHandle) -> np.ndarray:
        """Extract the job's result and free its slot for reuse."""
        res = self.result(handle)
        slot = handle.slot
        iv, idl = self._inert_state(1)
        self.values = self.values.at[slot].set(iv[0])
        self.deltas = self.deltas.at[slot].set(idl[0])
        self.push_scale = self.push_scale.at[slot].set(1.0)
        self.algs[slot] = None
        self.active[slot] = False
        self._gens[slot] += 1
        return res

    # -- jitted primitives (shared by every policy) --------------------------

    def _pairs_fn(self):
        key = "pairs"
        if key not in self._jit_cache:
            alg = self.view_alg
            self._jit_cache[key] = jax.jit(
                lambda v, d: compute_pairs(alg, v, d))
        return self._jit_cache[key]

    def _counts_fn(self):
        key = "counts"
        if key not in self._jit_cache:
            alg = self.view_alg
            self._jit_cache[key] = jax.jit(
                lambda v, d: jnp.sum(alg.unconverged(v, d), axis=(1, 2)))
        return self._jit_cache[key]

    def _push_shared_fn(self):
        """All jobs process the same selected blocks (CAJS)."""
        key = ("push_shared", self.use_pallas)
        if key not in self._jit_cache:
            if self.use_pallas:
                from repro.kernels.mj_spmm import ops as mj_ops
                fn = partial(mj_ops.push_shared,
                             semiring=self.view_alg.semiring)
                self._jit_cache[key] = jax.jit(
                    lambda v, d, t, n, si, sm, ps: fn(v, d, t, n, si, sm, ps))
            else:
                push = self._push_one
                self._jit_cache[key] = jax.jit(jax.vmap(
                    push, in_axes=(0, 0, None, None, None, None, 0)))
        return self._jit_cache[key]

    def _push_indep_fn(self):
        """Each job processes its own selection (redundancy baseline)."""
        key = "push_indep"
        if key not in self._jit_cache:
            push = self._push_one
            self._jit_cache[key] = jax.jit(jax.vmap(
                push, in_axes=(0, 0, None, None, 0, 0, 0)))
        return self._jit_cache[key]

    # -- placement -----------------------------------------------------------

    def _place(self, mesh) -> None:
        """Shard the job axis over `mesh` (repro.dist.graph): tiles
        replicated per device, values/deltas job-sharded.  Scheduling is
        unchanged — SPMD partitions the vmapped pushes along the job axis,
        so per-job arithmetic (and the fixpoint) is identical."""
        if mesh is None:
            return
        from repro.dist.graph import shard_job_state
        self.values, self.deltas, self.push_scale = shard_job_state(
            mesh, self.values, self.deltas, self.push_scale, self.graph)

    # -- driving -------------------------------------------------------------

    def run(self, policy: Optional[SchedulePolicy] = None,
            max_supersteps: int = 100000, *, mesh=None) -> RunMetrics:
        """Advance all active jobs until they converge (or the budget ends).

        Jobs submitted after this returns resume from the shared state:
        call run() again to drive the new mix — that is the arrival model."""
        if self.graph is None:
            raise ValueError("no jobs submitted yet")
        policy = TwoLevel() if policy is None else policy
        self._place(mesh)
        return policy.run(self, max_supersteps)

    def step(self, policy: Optional[SchedulePolicy] = None) -> RunMetrics:
        """A single superstep under `policy`."""
        return self.run(policy, max_supersteps=1)
