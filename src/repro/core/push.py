"""Device-side push primitives for one job (vmapped over jobs above).

A "push" processes the selected adjacency blocks for one job: it consumes
the pending deltas of the selected blocks and scatters their contributions
into the neighbours' deltas (paper Eq. 3, both semirings).  These are pure
functions of stacked [B_N, Vb] state, shared by every schedule policy and
by the pod-scale dry-run (repro.launch.graph_dryrun).

Evolving graphs (repro.stream) stage a bounded per-block delta-COO
overlay alongside each tile (graph.structure.TileOverlay): a push of
block b consumes b's pending deltas through the base tile AND through
b's overlay edges in the same staging.  Every push takes the overlay as
its trailing argument; the all-inert capacity-0 overlay of a
never-updated view contributes exact no-ops (plus-times adds 0.0,
min-plus mins inf), keeping frozen-graph runs bitwise identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm
from repro.core import priority as prio
from repro.graph.structure import TileOverlay, empty_overlay

__all__ = [
    "push_plus_one", "push_min_one", "compute_pairs",
    "shared_push_fn", "indep_push_fn",
    "overlay_push_plus", "overlay_push_min",
]


def _block_mask(sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                num_blocks: int) -> jnp.ndarray:
    """[q] ids + validity mask -> dense [B_N] bool, scatter-hazard free."""
    m = jnp.zeros((num_blocks,), dtype=jnp.bool_)
    return m.at[sel_ids].max(sel_mask > 0)


def _overlay_rows(ov: TileOverlay, sel_ids: jnp.ndarray):
    """Gather the overlay rows of the selected blocks: [q, C] each."""
    return ov.src_u[sel_ids], ov.dst[sel_ids], ov.w[sel_ids], ov.mask[sel_ids]


def overlay_push_plus(deltas: jnp.ndarray, d_sel: jnp.ndarray,
                      ov: TileOverlay, sel_ids: jnp.ndarray) -> jnp.ndarray:
    """Scatter the selected blocks' overlay contributions into `deltas`.

    d_sel [q, Vb] must be the SAME consumed-and-scaled deltas the base
    tile push used (pre-consumption values), so an overlay edge pushes
    exactly once per staging, in lockstep with the tile."""
    if ov.capacity == 0:
        return deltas
    src_u, dst, w, mask = _overlay_rows(ov, sel_ids)          # [q, C]
    q = sel_ids.shape[0]
    contrib = d_sel[jnp.arange(q)[:, None], src_u] * w * mask  # [q, C]
    flat = deltas.reshape(-1)
    flat = flat.at[dst.reshape(-1)].add(contrib.reshape(-1))
    return flat.reshape(deltas.shape)


def overlay_push_min(values: jnp.ndarray, deltas: jnp.ndarray,
                     d_sel: jnp.ndarray, ov: TileOverlay,
                     sel_ids: jnp.ndarray):
    """Min-plus analogue: relax the selected blocks' overlay edges.

    d_sel [q, Vb] is the consumed pending distance of the selected blocks
    (inf where nothing pends / the slot is padded)."""
    if ov.capacity == 0:
        return values, deltas
    src_u, dst, w, mask = _overlay_rows(ov, sel_ids)          # [q, C]
    q = sel_ids.shape[0]
    cand = jnp.where(mask > 0,
                     d_sel[jnp.arange(q)[:, None], src_u] + w,
                     jnp.inf).reshape(-1)
    idx = dst.reshape(-1)
    vb = values.shape[-1]
    v_flat, d_flat = values.reshape(-1), deltas.reshape(-1)
    old = v_flat[idx]
    v_flat = v_flat.at[idx].min(cand)
    new = v_flat[idx]
    d_flat = d_flat.at[idx].min(jnp.where(new < old, new, jnp.inf))
    return v_flat.reshape(-1, vb), d_flat.reshape(-1, vb)


def push_plus_one(values: jnp.ndarray, deltas: jnp.ndarray,
                  tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                  sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                  push_scale: jnp.ndarray, overlay: TileOverlay = None):
    """One job, PLUS_TIMES semiring. values/deltas [B_N, Vb]."""
    if overlay is None:
        overlay = empty_overlay(values.shape[0])
    consumed = _block_mask(sel_ids, sel_mask, values.shape[0])[:, None]
    raw = jnp.where(consumed, deltas, 0.0)
    # mask padded selection slots: a padded slot aliases block 0 and must not
    # re-push block 0's delta when block 0 is itself selected
    d_sel = raw[sel_ids] * push_scale * sel_mask[:, None]  # [q, Vb]
    t_sel = tiles[sel_ids]                                # [q, K, Vb, Vb]
    contrib = jnp.einsum("qv,qkvw->qkw", d_sel, t_sel)    # [q, K, Vb]
    values = values + raw
    deltas = deltas - raw
    dst = nbr_ids[sel_ids].reshape(-1)                    # [q*K]
    deltas = deltas.at[dst].add(
        contrib.reshape(-1, contrib.shape[-1]), mode="drop")
    deltas = overlay_push_plus(deltas, d_sel, overlay, sel_ids)
    return values, deltas


def push_min_one(values: jnp.ndarray, deltas: jnp.ndarray,
                 tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                 sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                 push_scale: jnp.ndarray, overlay: TileOverlay = None):
    """One job, MIN_PLUS semiring (push_scale unused, kept for signature)."""
    del push_scale
    if overlay is None:
        overlay = empty_overlay(values.shape[0])
    bn = values.shape[0]
    consumed = _block_mask(sel_ids, sel_mask, bn)[:, None]
    d_sel = jnp.where(consumed, deltas, jnp.inf)[sel_ids]   # [q, Vb]
    d_sel = jnp.where(sel_mask[:, None] > 0, d_sel, jnp.inf)
    deltas = jnp.where(consumed, jnp.inf, deltas)
    t_sel = tiles[sel_ids]                                   # [q, K, Vb, Vb]
    nbr_sel = nbr_ids[sel_ids]                               # [q, K]

    def body(carry, inp):
        values, deltas = carry
        t_k, dst_k = inp                                     # [q,Vb,Vb], [q]
        contrib = jnp.min(d_sel[:, :, None] + t_k, axis=1)   # [q, Vb]
        old = values[dst_k]
        values = values.at[dst_k].min(contrib)
        new = values[dst_k]
        improved = new < old
        deltas = deltas.at[dst_k].min(jnp.where(improved, new, jnp.inf))
        return (values, deltas), None

    (values, deltas), _ = jax.lax.scan(
        body, (values, deltas),
        (jnp.swapaxes(t_sel, 0, 1), jnp.swapaxes(nbr_sel, 0, 1)))
    values, deltas = overlay_push_min(values, deltas, d_sel, overlay, sel_ids)
    return values, deltas


def compute_pairs(alg: Algorithm, values: jnp.ndarray, deltas: jnp.ndarray):
    """[J, B_N, Vb] -> (node_un [J,B_N], p_mean [J,B_N])."""
    p = alg.vertex_priority(values, deltas)
    return prio.block_pairs(p)


def shared_push_fn(semiring: str, push_one, use_pallas: bool):
    """Stacked-job CAJS push callable (un-jitted): all jobs process the
    same [q] selection plus the shared overlay (in_axes None — one
    staging serves every job).  The ONE place the kernel-vs-jnp dispatch
    and the in_axes wiring live — jitted+cached by GraphSession for the
    host driver, inlined into the compiled superstep by the device
    driver.

    Returns fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
    pairs) with `pairs` the view's `graph.BlockPairs`:

      use_pallas=True   the fused_superstep Pallas megakernel sweeps the
                        destination-sorted pairs (select/stage/push/
                        priority fused per dst block); the overlay
                        ride-along stays in jnp on the PRE-consumption
                        deltas, exactly like every other push path.
      use_pallas=False  plus-times emulates the same pair sweep in jnp
                        with a per-(job, pair) einsum + scatter-add.
                        Deliberately NOT `pairs.dense_op`: a [J, N] @
                        [N, N] matmul lets XLA pick a J-dependent
                        contraction blocking, which breaks the bit-for-
                        bit job-axis sharding invariance dist.graph
                        guarantees.  min-plus keeps the vmapped per-job
                        `push_one` (its sequential min-scan is the
                        bitwise anchor the fixpoint tests pin).
      pairs=None        falls back to the vmapped `push_one` (block-ELL
                        staging), for callers without a pair view.
    """
    vm = jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0, None))
    if use_pallas:
        from repro.kernels.fused_superstep import ops as fused_ops

        def fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
               pairs):
            if pairs is None:         # no pair view: block-ELL fallback
                return vm(values, deltas, tiles, nbr_ids, sel, msk, scales,
                          overlay)
            del tiles, nbr_ids        # the pair view replaces ELL staging
            # the overlay must see the PRE-consumption deltas, gathered
            # before the kernel zeroes/infs them
            ride = overlay is not None and overlay.capacity
            if ride:
                consumed = _block_mask(sel, msk,
                                       values.shape[1])[None, :, None]
                if semiring == "plus_times":
                    raw = jnp.where(consumed, deltas, 0.0)
                    d_sel = (raw[:, sel, :] * scales[:, None, None]
                             * msk[None, :, None])          # [J, q, Vb]
                else:
                    d_sel = jnp.where(consumed, deltas, jnp.inf)[:, sel, :]
                    d_sel = jnp.where(msk[None, :, None] > 0, d_sel,
                                      jnp.inf)
            values, deltas = fused_ops.fused_push(
                values, deltas, pairs, sel, msk, scales, semiring=semiring)
            if ride:
                if semiring == "plus_times":
                    deltas = jax.vmap(
                        overlay_push_plus, in_axes=(0, 0, None, None))(
                            deltas, d_sel, overlay, sel)
                else:
                    values, deltas = jax.vmap(
                        overlay_push_min, in_axes=(0, 0, 0, None, None))(
                            values, deltas, d_sel, overlay, sel)
            return values, deltas

        return fn

    if semiring != "plus_times":
        def fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
               pairs):
            del pairs
            return vm(values, deltas, tiles, nbr_ids, sel, msk, scales,
                      overlay)

        return fn

    def fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
           pairs):
        if pairs is None:
            return vm(values, deltas, tiles, nbr_ids, sel, msk, scales,
                      overlay)
        bn = values.shape[1]
        selb = _block_mask(sel, msk, bn)[None, :, None]
        raw = jnp.where(selb, deltas, 0.0)
        d = raw * scales[:, None, None]
        base = deltas - raw
        contrib = jnp.einsum("jpv,pvw->jpw", d[:, pairs.src, :],
                             pairs.tiles)
        out = base.at[:, pairs.dst, :].add(contrib, mode="drop")
        values = values + raw
        deltas = out
        if overlay is not None and overlay.capacity:
            d_sel = d[:, sel, :] * msk[None, :, None]       # [J, q, Vb]
            deltas = jax.vmap(
                overlay_push_plus, in_axes=(0, 0, None, None))(
                    deltas, d_sel, overlay, sel)
        return values, deltas

    return fn


def indep_push_fn(push_one):
    """Per-job-selection push callable (un-jitted): each job its own [q];
    the overlay is still the shared view data (in_axes None)."""
    return jax.vmap(push_one, in_axes=(0, 0, None, None, 0, 0, 0, None))
