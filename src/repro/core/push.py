"""Device-side push primitives for one job (vmapped over jobs above).

A "push" processes the selected adjacency blocks for one job: it consumes
the pending deltas of the selected blocks and scatters their contributions
into the neighbours' deltas (paper Eq. 3, both semirings).  These are pure
functions of stacked [B_N, Vb] state, shared by every schedule policy and
by the pod-scale dry-run (repro.launch.graph_dryrun).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm
from repro.core import priority as prio


def _block_mask(sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                num_blocks: int) -> jnp.ndarray:
    """[q] ids + validity mask -> dense [B_N] bool, scatter-hazard free."""
    m = jnp.zeros((num_blocks,), dtype=jnp.bool_)
    return m.at[sel_ids].max(sel_mask > 0)


def push_plus_one(values: jnp.ndarray, deltas: jnp.ndarray,
                  tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                  sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                  push_scale: jnp.ndarray):
    """One job, PLUS_TIMES semiring. values/deltas [B_N, Vb]."""
    consumed = _block_mask(sel_ids, sel_mask, values.shape[0])[:, None]
    raw = jnp.where(consumed, deltas, 0.0)
    # mask padded selection slots: a padded slot aliases block 0 and must not
    # re-push block 0's delta when block 0 is itself selected
    d_sel = raw[sel_ids] * push_scale * sel_mask[:, None]  # [q, Vb]
    t_sel = tiles[sel_ids]                                # [q, K, Vb, Vb]
    contrib = jnp.einsum("qv,qkvw->qkw", d_sel, t_sel)    # [q, K, Vb]
    values = values + raw
    deltas = deltas - raw
    dst = nbr_ids[sel_ids].reshape(-1)                    # [q*K]
    deltas = deltas.at[dst].add(
        contrib.reshape(-1, contrib.shape[-1]), mode="drop")
    return values, deltas


def push_min_one(values: jnp.ndarray, deltas: jnp.ndarray,
                 tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                 sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                 push_scale: jnp.ndarray):
    """One job, MIN_PLUS semiring (push_scale unused, kept for signature)."""
    del push_scale
    bn = values.shape[0]
    consumed = _block_mask(sel_ids, sel_mask, bn)[:, None]
    d_sel = jnp.where(consumed, deltas, jnp.inf)[sel_ids]   # [q, Vb]
    d_sel = jnp.where(sel_mask[:, None] > 0, d_sel, jnp.inf)
    deltas = jnp.where(consumed, jnp.inf, deltas)
    t_sel = tiles[sel_ids]                                   # [q, K, Vb, Vb]
    nbr_sel = nbr_ids[sel_ids]                               # [q, K]

    def body(carry, inp):
        values, deltas = carry
        t_k, dst_k = inp                                     # [q,Vb,Vb], [q]
        contrib = jnp.min(d_sel[:, :, None] + t_k, axis=1)   # [q, Vb]
        old = values[dst_k]
        values = values.at[dst_k].min(contrib)
        new = values[dst_k]
        improved = new < old
        deltas = deltas.at[dst_k].min(jnp.where(improved, new, jnp.inf))
        return (values, deltas), None

    (values, deltas), _ = jax.lax.scan(
        body, (values, deltas),
        (jnp.swapaxes(t_sel, 0, 1), jnp.swapaxes(nbr_sel, 0, 1)))
    return values, deltas


def compute_pairs(alg: Algorithm, values: jnp.ndarray, deltas: jnp.ndarray):
    """[J, B_N, Vb] -> (node_un [J,B_N], p_mean [J,B_N])."""
    p = alg.vertex_priority(values, deltas)
    return prio.block_pairs(p)


def shared_push_fn(semiring: str, push_one, use_pallas: bool):
    """Stacked-job CAJS push callable (un-jitted): all jobs process the
    same [q] selection.  The ONE place the pallas-vs-vmap dispatch and the
    in_axes wiring live — jitted+cached by GraphSession for the host
    driver, inlined into the compiled superstep by the device driver."""
    if use_pallas:
        from functools import partial
        from repro.kernels.mj_spmm import ops as mj_ops
        return partial(mj_ops.push_shared, semiring=semiring)
    return jax.vmap(push_one, in_axes=(0, 0, None, None, None, None, 0))


def indep_push_fn(push_one):
    """Per-job-selection push callable (un-jitted): each job its own [q]."""
    return jax.vmap(push_one, in_axes=(0, 0, None, None, 0, 0, 0))
