"""The paper's user-facing API (§4.4), mapped onto the redesigned core.

  initPtable     - per-block initial priority state for a newly-arrived job
                   (what `GraphSession.submit` runs when a job arrives)
  De_In_Priority - per-job block priority queue (pairs + Function 2;
                   `TwoLevelScheduler.job_queues`)
  De_Gl_Priority - global priority queue (Fig. 7 synthesis;
                   `TwoLevelScheduler.synthesize`)
  Con_processing - schedule all jobs over the global queue (the CAJS push
                   one `TwoLevel.select` + shared push performs per step)

These are thin, composable wrappers so a "traditional" engine can adopt the
two strategies incrementally, exactly as the paper prescribes.  The
session/policy API (docs/API.md) is the batteries-included version of the
same four steps.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms.base import Algorithm, PLUS_TIMES
from repro.core.do_select import DEFAULT_SAMPLES
from repro.core.engine import ConcurrentRun
from repro.core.global_q import DEFAULT_ALPHA
from repro.core.push import compute_pairs, push_plus_one, push_min_one
from repro.core.scheduler import TwoLevelScheduler


def initPtable(alg: Algorithm, graph) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Initial (values, deltas) for a new job — every block starts with the
    same priority (paper step 2: 'priority values ... set to the same in the
    first iteration'), which falls out of the algorithm's uniform init."""
    return alg.init(graph)


def De_In_Priority(alg: Algorithm, values: jnp.ndarray, deltas: jnp.ndarray,
                   q: int, rng: np.random.Generator,
                   samples: int = DEFAULT_SAMPLES) -> List[np.ndarray]:
    """Per-job priority queues for stacked [J, B_N, Vb] state."""
    node_un, p_mean = map(np.asarray, compute_pairs(alg, values, deltas))
    sched = TwoLevelScheduler(node_un.shape[1], q, samples=samples)
    sched.rng = rng  # caller-owned stream, paper-API style
    return sched.job_queues(node_un, p_mean)


def De_Gl_Priority(job_queues: Sequence[np.ndarray], num_blocks: int, q: int,
                   alpha: float = DEFAULT_ALPHA) -> np.ndarray:
    return TwoLevelScheduler(num_blocks, q, alpha=alpha).synthesize(job_queues)


_CON_PUSH_JIT: dict = {}


def _con_push(push):
    """Compiled vmapped push, cached per push function (RPA005: a fresh
    jax.jit of a fresh vmap closure would re-trace on every superstep)."""
    fn = _CON_PUSH_JIT.get(push)
    if fn is None:
        fn = jax.jit(jax.vmap(push, in_axes=(0, 0, None, None, None,
                                             None, 0)))
        _CON_PUSH_JIT[push] = fn
    return fn


def Con_processing(run: ConcurrentRun, gq: np.ndarray, q: int):
    """CAJS: stage each selected block once; every job processes it."""
    g = run.graph
    push = (push_plus_one if run.algs[0].semiring == PLUS_TIMES
            else push_min_one)
    sel = np.zeros(q, dtype=np.int32)
    msk = np.zeros(q, dtype=np.float32)
    sel[:len(gq)] = gq[:q]
    msk[:len(gq)] = 1.0
    values, deltas = _con_push(push)(
        run.values, run.deltas, g.tiles, g.nbr_ids,
        jnp.asarray(sel), jnp.asarray(msk), run.push_scale)
    return values, deltas
