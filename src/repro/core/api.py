"""The paper's user-facing API (§4.4), mapped onto the engine.

  initPtable     - per-block initial priority state for a newly-arrived job
  De_In_Priority - per-job block priority queue (pairs + Function 2)
  De_Gl_Priority - global priority queue (Fig. 7 synthesis)
  Con_processing - schedule all jobs over the global queue (CAJS push)

These are thin, composable wrappers so a "traditional" engine can adopt the
two strategies incrementally, exactly as the paper prescribes.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np
import jax.numpy as jnp

from repro.algorithms.base import Algorithm
from repro.core.do_select import do_select, DEFAULT_SAMPLES
from repro.core.engine import (ConcurrentRun, compute_pairs, push_plus_one,
                               push_min_one, optimal_queue_length)
from repro.core.global_q import global_queue, DEFAULT_ALPHA
from repro.algorithms.base import PLUS_TIMES

import jax


def initPtable(alg: Algorithm, graph) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Initial (values, deltas) for a new job — every block starts with the
    same priority (paper step 2: 'priority values ... set to the same in the
    first iteration'), which falls out of the algorithm's uniform init."""
    return alg.init(graph)


def De_In_Priority(alg: Algorithm, values: jnp.ndarray, deltas: jnp.ndarray,
                   q: int, rng: np.random.Generator,
                   samples: int = DEFAULT_SAMPLES) -> List[np.ndarray]:
    """Per-job priority queues for stacked [J, B_N, Vb] state."""
    node_un, p_mean = map(np.asarray, compute_pairs(alg, values, deltas))
    return [do_select(node_un[j], p_mean[j], q, rng, samples)
            for j in range(values.shape[0])]


def De_Gl_Priority(job_queues: Sequence[np.ndarray], num_blocks: int, q: int,
                   alpha: float = DEFAULT_ALPHA) -> np.ndarray:
    return global_queue(job_queues, num_blocks, q, alpha)


def Con_processing(run: ConcurrentRun, gq: np.ndarray, q: int):
    """CAJS: stage each selected block once; every job processes it."""
    g = run.graph
    push = (push_plus_one if run.algs[0].semiring == PLUS_TIMES
            else push_min_one)
    sel = np.zeros(q, dtype=np.int32)
    msk = np.zeros(q, dtype=np.float32)
    sel[:len(gq)] = gq[:q]
    msk[:len(gq)] = 1.0
    values, deltas = jax.jit(jax.vmap(
        push, in_axes=(0, 0, None, None, None, None, 0)))(
        run.values, run.deltas, g.tiles, g.nbr_ids,
        jnp.asarray(sel), jnp.asarray(msk), run.push_scale)
    return values, deltas
