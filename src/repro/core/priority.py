"""Block priority pairs <Node_un, P_mean> and the CBP comparator (Function 1).

Paper §4.2.1: the priority of a block is the pair
  Node_un  = number of unconverged vertices in the block
  P_mean   = mean priority value over the *unconverged* vertices (Eq. 1)

Function 1 (CBP) compares two pairs: higher mean wins, unless the means are
within the epsilon band (eps = 0.2 * P_mean_a, the paper's default), in which
case the *total* priority Node_un * P_mean decides.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

EPS_FACTOR = 0.2  # paper: eps = 0.2 * P_mean_a


# --------------------------------------------------------------------------
# device-side pair computation
# --------------------------------------------------------------------------

def block_pairs(vertex_priority: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[..., B_N, Vb] positive priorities (0 == converged) ->
    (node_un [..., B_N] float32, p_mean [..., B_N] float32)."""
    un = vertex_priority > 0.0
    node_un = jnp.sum(un, axis=-1).astype(jnp.float32)
    p_sum = jnp.sum(jnp.where(un, vertex_priority, 0.0), axis=-1)
    p_mean = p_sum / jnp.maximum(node_un, 1.0)
    return node_un, p_mean


def counts_from_pairs(node_un):
    """Per-job unconverged-vertex totals derived from the pair computation.

    Summing Node_un over blocks counts exactly the unconverged vertices
    (a vertex is unconverged iff its positive priority entered Node_un), so
    a driver that already computed <Node_un, P_mean> gets the convergence
    counts for free — one device dispatch per group per superstep instead
    of a separate counts reduction.  Works on numpy and jax arrays alike
    ([..., B_N] -> [...]).
    """
    return node_un.sum(-1)


# --------------------------------------------------------------------------
# Function 1: CBP — host scalar comparator, verbatim from the paper
# --------------------------------------------------------------------------

def cbp(pair_a: Tuple[float, float], pair_b: Tuple[float, float],
        eps_factor: float = EPS_FACTOR) -> bool:
    """Is the priority of block a higher than block b?

    pair = (node_un, p_mean).  Transcribes the paper's Function 1 exactly,
    including the swap/negate structure.
    """
    (n_a, m_a), (n_b, m_b) = pair_a, pair_b
    state = True
    if m_a < m_b:
        (n_a, m_a), (n_b, m_b) = (n_b, m_b), (n_a, m_a)
        state = not state
    # invariant: m_a >= m_b
    if n_a < n_b:
        if (m_a - m_b) < eps_factor * m_a and (m_a * n_a) < (m_b * n_b):
            state = not state
    return state


def cbp_key_sort(node_un: np.ndarray, p_mean: np.ndarray) -> np.ndarray:
    """Sort block indices in CBP-descending order (host, exact).

    Uses functools.cmp_to_key over Function 1 — O(B log B) comparisons, used
    only on already-selected ~q blocks (Function 2 keeps the full pass O(B)).
    """
    import functools

    idx = list(range(len(node_un)))

    def cmp(i: int, j: int) -> int:
        if i == j:
            return 0
        return -1 if cbp((node_un[i], p_mean[i]), (node_un[j], p_mean[j])) else 1

    idx.sort(key=functools.cmp_to_key(cmp))
    return np.asarray(idx, dtype=np.int64)


# --------------------------------------------------------------------------
# device-side DO-order score (beyond-paper fused scheduler)
# --------------------------------------------------------------------------

def do_score(node_un: jnp.ndarray, p_mean: jnp.ndarray) -> jnp.ndarray:
    """Scalar score whose descending order approximates CBP order.

    CBP is lexicographic-with-band: P_mean decides unless two means are
    within 20%, then total = node_un * p_mean decides.  We bucket log(P_mean)
    with bucket width ln(1.25) (values within the paper's 0.8 ratio band fall
    in the same or adjacent bucket) and break ties inside a bucket by the
    normalized total priority.  Converged blocks (node_un == 0) score -inf.
    """
    total = node_un * p_mean
    bucket = jnp.floor(jnp.log(jnp.maximum(p_mean, 1e-30)) / jnp.log(1.25))
    # total / (total + 1) in (0, 1) keeps the tiebreak strictly inside a bucket
    tiebreak = total / (total + 1.0)
    score = bucket + tiebreak
    return jnp.where(node_un > 0, score, -jnp.inf)
