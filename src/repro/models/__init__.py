from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.model import LM

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "LM"]
