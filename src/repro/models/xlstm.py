"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent gate mixing), both with exponential gating + log-space stabilizer.

Train/prefill run a chunked nested scan (outer chunks under jax.remat so the
backward pass recomputes inner steps instead of storing 4k residual sets);
decode is a single recurrent step on the carried state.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, dense_init
from repro.models.recurrent import causal_conv

_CHUNK = 128  # inner scan chunk length


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg):
    di = int(cfg.proj_factor * cfg.d_model)
    h = cfg.n_heads
    return di, h, di // h


def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di, h, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "w_up": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_i": dense_init(ks[5], di, h, jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": dense_init(ks[6], di, h, jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-bias init
        "w_down": dense_init(ks[7], di, d, dtype),
    }


def init_mlstm_cache(cfg, batch: int, dtype) -> dict:
    di, h, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
    }


def _mlstm_step(carry, inp):
    """One recurrent step.  carry: (C [B,H,dv,dk], n [B,H,dk], m [B,H])."""
    C, n, m = carry
    q, k, v, i_pre, f_pre = inp     # [B,H,dh] x3, [B,H] x2
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * \
        jnp.einsum("bhv,bhk->bhvk", v, k)
    n = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h_t = jnp.einsum("bhvk,bhk->bhv", C, q) / denom[..., None]
    return (C, n, m_new), h_t


def _mlstm_scan(q, k, v, i_pre, f_pre, state):
    """q/k/v [B,S,H,dh] (f32), gates [B,S,H] -> (h [B,S,H,dh], state)."""
    b, s, h, dh = q.shape
    cl = min(_CHUNK, s)
    n_chunk = -(-s // cl)
    pad = n_chunk * cl - s

    def to_chunks(x):
        if pad:
            x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        return x.reshape(b, n_chunk, cl, *x.shape[2:]).swapaxes(0, 1)

    qs, ks_, vs, is_, fs = map(to_chunks, (q, k, v, i_pre, f_pre))

    @jax.remat
    def chunk(carry, inp):
        qc, kc, vc, ic, fc = inp    # [B,cl,H,dh] etc.
        def step(c, z):
            return _mlstm_step(c, z)
        carry, hs = jax.lax.scan(
            step, carry,
            (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             ic.swapaxes(0, 1), fc.swapaxes(0, 1)))
        return carry, hs.swapaxes(0, 1)   # [B,cl,H,dh]

    state, hs = jax.lax.scan(chunk, state, (qs, ks_, vs, is_, fs))
    hs = hs.swapaxes(0, 1).reshape(b, n_chunk * cl, h, dh)
    return hs[:, :s], state


def mlstm_block(x: jnp.ndarray, p: dict, cfg,
                cache: Optional[dict]) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    di, h, dh = _mlstm_dims(cfg)
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    up = xn @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)                 # [B,S,di] each
    conv_state = cache["conv"] if cache is not None else None
    uc, conv_state = causal_conv(u, p["conv_w"], conv_state)
    uc_act = jax.nn.silu(uc)

    q = (uc_act @ p["wq"]).reshape(b, s, h, dh).astype(jnp.float32)
    k = (uc_act @ p["wk"]).reshape(b, s, h, dh).astype(jnp.float32) \
        / math.sqrt(dh)
    v = (u @ p["wv"]).reshape(b, s, h, dh).astype(jnp.float32)
    i_pre = uc_act.astype(jnp.float32) @ p["w_i"] + p["b_i"]   # [B,S,H]
    f_pre = uc_act.astype(jnp.float32) @ p["w_f"] + p["b_f"]

    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    else:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.zeros((b, h), jnp.float32))

    if s == 1:  # decode fast path
        state, h_t = _mlstm_step(
            state, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]))
        hs = h_t[:, None]
    else:
        hs, state = _mlstm_scan(q, k, v, i_pre, f_pre, state)

    hs = hs.reshape(b, s, di).astype(x.dtype)
    y = (hs * jax.nn.silu(z)) @ p["w_down"]
    new_cache = None
    if cache is not None:
        new_cache = {"C": state[0], "n": state[1], "m": state[2],
                     "conv": conv_state}
    return x + y, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 12)
    def rec(k):  # block-diagonal per-head recurrent matrix [H, dh, dh]
        return (jax.random.normal(k, (h, dh, dh), jnp.float32)
                / math.sqrt(dh)).astype(jnp.float32)
    f_up = int(4 * d / 3)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "wz": dense_init(ks[0], d, d, dtype),
        "wi": dense_init(ks[1], d, d, dtype),
        "wf": dense_init(ks[2], d, d, dtype),
        "wo": dense_init(ks[3], d, d, dtype),
        "rz": rec(ks[4]), "ri": rec(ks[5]), "rf": rec(ks[6]), "ro": rec(ks[7]),
        "bz": jnp.zeros((d,), jnp.float32),
        "bi": jnp.zeros((d,), jnp.float32),
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "bo": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "w1": dense_init(ks[8], d, f_up, dtype),
        "w3": dense_init(ks[9], d, f_up, dtype),
        "w2": dense_init(ks[10], f_up, d, dtype),
    }


def init_slstm_cache(cfg, batch: int, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    def z():
        return jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.zeros((batch, h), jnp.float32)}


def _slstm_step(carry, inp, p, heads):
    c, n, hid, m = carry             # [B,H,dh] x3, [B,H]
    zx, ix, fx, ox = inp             # [B,D] pre-activations from input
    b, h, dh = c.shape

    def mix(r, x_pre):               # recurrent block-diag mix + reshape
        rec = jnp.einsum("bhd,hde->bhe", hid, r)
        return x_pre.reshape(b, h, dh) + rec

    z = jnp.tanh(mix(p["rz"], zx))
    i_pre = mix(p["ri"], ix)
    f_pre = mix(p["rf"], fx)
    o = jax.nn.sigmoid(mix(p["ro"], ox))

    # per-head scalar stabilizer (max over the head's units)
    logf = jax.nn.log_sigmoid(f_pre)
    m_cand = jnp.maximum(jnp.max(logf, -1) + m, jnp.max(i_pre, -1))
    i_g = jnp.exp(i_pre - m_cand[..., None])
    f_g = jnp.exp(logf + (m - m_cand)[..., None])
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    hid = o * (c / jnp.maximum(jnp.abs(n), 1e-6))
    return (c, n, hid, m_cand), hid


def slstm_block(x: jnp.ndarray, p: dict, cfg,
                cache: Optional[dict]) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    xf = xn.astype(jnp.float32)
    zx = xf @ p["wz"].astype(jnp.float32) + p["bz"]
    ix = xf @ p["wi"].astype(jnp.float32) + p["bi"]
    fx = xf @ p["wf"].astype(jnp.float32) + p["bf"]
    ox = xf @ p["wo"].astype(jnp.float32) + p["bo"]

    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        z0 = jnp.zeros((b, h, dh), jnp.float32)
        state = (z0, z0, z0, jnp.zeros((b, h), jnp.float32))

    if s == 1:
        state, hid = _slstm_step(
            state, (zx[:, 0], ix[:, 0], fx[:, 0], ox[:, 0]), p, h)
        hs = hid[:, None]
    else:
        cl = min(_CHUNK, s)
        n_chunk = -(-s // cl)
        pad = n_chunk * cl - s

        def to_chunks(t):
            if pad:
                t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
            return t.reshape(b, n_chunk, cl, -1).swapaxes(0, 1)

        zs, is_, fs, os_ = map(to_chunks, (zx, ix, fx, ox))

        @jax.remat
        def chunk(carry, inp):
            zc, ic, fc, oc = inp
            carry, hs = jax.lax.scan(
                lambda cr, z: _slstm_step(cr, z, p, h), carry,
                (zc.swapaxes(0, 1), ic.swapaxes(0, 1),
                 fc.swapaxes(0, 1), oc.swapaxes(0, 1)))
            return carry, hs.swapaxes(0, 1)

        state, hs = jax.lax.scan(chunk, state, (zs, is_, fs, os_))
        hs = hs.swapaxes(0, 1).reshape(b, n_chunk * cl, h, dh)[:, :s]

    y = hs.reshape(b, s, d).astype(x.dtype)
    x = x + y
    # block-internal gated FFN (xLSTM sLSTM post-projection, pf = 4/3)
    xn2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    ff = (jax.nn.silu(xn2 @ p["w1"]) * (xn2 @ p["w3"])) @ p["w2"]
    x = x + ff
    new_cache = None
    if cache is not None:
        new_cache = {"c": state[0], "n": state[1], "h": state[2],
                     "m": state[3]}
    return x, new_cache
