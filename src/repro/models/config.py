"""Model + shape configs for the assigned architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer pattern, cycled: "attn", "swa" (sliding-window attn),
    # "rglru" (Griffin recurrent), "mlstm", "slstm" (xLSTM)
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096                  # for "swa"

    # MoE (applies to the FFN of attn/swa blocks)
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_base: float = 10000.0
    use_rope: bool = True

    # recurrent options
    d_rnn: int = 0                      # rglru width (0 -> d_model)
    conv_width: int = 4                 # temporal conv (rglru / mlstm)
    proj_factor: float = 2.0            # mlstm up-projection factor

    # modality frontends (stubs: precomputed embeddings / token layouts)
    n_codebooks: int = 0                # musicgen: 4 EnCodec streams
    patch_prefix: int = 0               # pixtral: precomputed patch embeds

    # substrate
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    param_dtype: str = "bfloat16"
    # accumulation dtype for the TP-sharded contractions whose partial sums
    # cross the ICI (wo / w2): bf16 halves the all-reduce wire bytes
    reduce_dtype: str = "float32"
    # attention activation layout: "auto" (heads-TP when divisible) or "sp"
    # (q/k/v sequence-sharded; attention chunks stay shard-local)
    qkv_spec: str = "auto"
    scan_layers: bool = True
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024

    # which serve shapes this arch supports (full attention cannot do 500k)
    sub_quadratic: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_rnn_eff(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def pattern_cycles(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def pattern_remainder(self) -> int:
        return self.n_layers % len(self.block_pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("mlstm", "slstm", "rglru") for k in self.block_pattern)

    def n_params(self) -> int:
        """Total parameter count (exact, mirrors the init functions)."""
        from repro.models.model import LM
        import jax
        shapes = jax.eval_shape(lambda: LM(self).init(jax.random.PRNGKey(0)))
        return sum(int(s.size) for s in jax.tree_util.tree_leaves(shapes))

    def n_active_params(self) -> int:
        """Active-per-token params (MoE counts top_k of n_experts)."""
        total = self.n_params()
        if not self.moe:
            return total
        from repro.models.model import LM
        import jax
        shapes = jax.eval_shape(lambda: LM(self).init(jax.random.PRNGKey(0)))
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if any("experts" in str(p) for p in path):
                expert += int(leaf.size)
        active = total - expert + expert * self.top_k // max(self.n_experts, 1)
        return active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int       # train/prefill: tokens per sequence; decode: KV length
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
