"""Griffin/RecurrentGemma RG-LRU recurrent block (+ causal depthwise conv).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
with a_t = exp(c * r_t * log sigmoid(Lambda)), r/i gates linear in the branch
input.  Train/prefill uses an associative scan (log-parallel on TPU);
decode is a single step.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, dense_init
from repro.dist.act import constrain

_C = 8.0  # Griffin's fixed exponent scale


def init_rglru(key, cfg, dtype) -> dict:
    d, r = cfg.d_model, cfg.d_rnn_eff
    ks = jax.random.split(key, 11)
    mlp = {}
    if cfg.d_ff:
        mlp = {
            "ln2": jnp.ones((d,), jnp.float32),
            "w1": dense_init(ks[7], d, cfg.d_ff, dtype),
            "w3": dense_init(ks[8], d, cfg.d_ff, dtype),
            "w2": dense_init(ks[9], cfg.d_ff, d, dtype),
        }
    return {
        **mlp,
        "ln": jnp.ones((d,), jnp.float32),
        "w_gate": dense_init(ks[0], d, r, dtype),
        "w_in": dense_init(ks[1], d, r, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, r), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "w_r": dense_init(ks[3], r, r, dtype),
        "b_r": jnp.zeros((r,), jnp.float32),
        "w_i": dense_init(ks[4], r, r, dtype),
        "b_i": jnp.zeros((r,), jnp.float32),
        # Lambda init so sigmoid(Lambda) ~ U(0.9, 0.999) (Griffin appendix)
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (r,), jnp.float32, 2.0, 7.0)),
        "w_out": dense_init(ks[6], r, d, dtype),
    }


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    r = cfg.d_rnn_eff
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    }


def causal_conv(u: jnp.ndarray, w: jnp.ndarray,
                state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  u [B, S, R]; w [cw, R]; state [B, cw-1, R]."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    full = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    y = jax.lax.conv_general_dilated(
        full, w[:, None, :].astype(u.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=u.shape[2])
    new_state = full[:, -(cw - 1):, :]
    return y, new_state


def lru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t over axis 1, initial h0. a/b [B,S,R], h0 [B,R]."""

    def comb(x, y):
        return (y[0] * x[0], y[0] * x[1] + y[1])

    a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
    return a_cum * h0[:, None, :] + b_cum


def rglru_block(x: jnp.ndarray, p: dict, cfg,
                cache: Optional[dict]) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x [B, S, D] -> (x + block(x), new_cache)."""
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    gate = constrain(jax.nn.silu(h @ p["w_gate"]), "dp", None, "tp")
    u = constrain(h @ p["w_in"], "dp", None, "tp")           # [B,S,R]
    conv_state = cache["conv"] if cache is not None else None
    u, conv_state = causal_conv(u, p["conv_w"], conv_state)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])            # [B,S,R] (<0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * uf)

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32))
    hs = lru_scan(a, b, h0)                                  # [B,S,R] f32

    y = (gate * hs.astype(x.dtype)) @ p["w_out"]
    x = x + y
    if "w1" in p:  # Griffin: MLP block after every temporal-mixing block
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        a = h2 @ p["w1"]
        a = jax.nn.gelu(a) if cfg.act == "gelu" else jax.nn.silu(a)
        a = constrain(a, "dp", None, "tp")
        x = x + (a * (h2 @ p["w3"])) @ p["w2"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": hs[:, -1, :], "conv": conv_state}
    return constrain(x, "dp", "sp", None), new_cache
