"""Capacity-based top-k MoE (GShard/MaxText-style dense dispatch).

Expert-parallel by construction: expert weights carry a leading E axis that
the sharding rules map to the `model` mesh axis; dispatch/combine are
scatter/gather einsums XLA partitions into all-to-all traffic.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.dist.act import constrain, axis_size, is_serve


def init_moe(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "experts": {
            "w1": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
                jax.random.split(ks[1], e)),
            "w3": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
                jax.random.split(ks[2], e)),
            "w2": jax.vmap(lambda k: dense_init(k, f, d, dtype))(
                jax.random.split(ks[3], e)),
        },
    }


def moe_ffn(x: jnp.ndarray, p: dict, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), axis=0)
        / t)
    aux = e * jnp.sum(me) * ce  # cheap proxy; exact f_e below is optional

    # ---- shard-aligned grouped dispatch (EXPERIMENTS.md §Perf) ------------
    # Ranking/scatter run *per data-shard group*: the one-hot cumsum and
    # capacity bookkeeping never cross shards (a global cumsum over the
    # sharded token axis serializes across devices); only the inherent
    # token->expert all-to-all remains.  g=1 outside a mesh context.
    g = max(axis_size("fsdp"), 1)
    if t % g or (t // g) * k < 1:
        g = 1
    tg = t // g

    # capacity per group; floor keeps tiny (decode) batches dropless
    capacity = max(1, int(cfg.capacity_factor * tg * k / e))
    capacity = max(capacity, min(tg * k, 16))

    xg = constrain(xt.reshape(g, tg, d), "fsdp", None, None)
    eg = top_i.reshape(g, tg * k)                             # expert ids
    pg = top_p.reshape(g, tg * k)

    oh = jax.nn.one_hot(eg, e, dtype=jnp.int32)               # [G, Tg*k, E]
    pos = jnp.cumsum(oh, axis=1) - 1
    pos = jnp.take_along_axis(pos, eg[..., None], axis=2)[..., 0]
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)

    xg_rep = jnp.repeat(xg, k, axis=1)                        # [G, Tg*k, D]
    upd = jnp.where(keep[..., None], xg_rep, 0.0).astype(x.dtype)

    def scatter_group(e_ids, p_ids, u):
        return jnp.zeros((e, capacity, d), x.dtype).at[e_ids, p_ids].add(u)

    buf = jax.vmap(scatter_group)(eg, pos_c, upd)             # [G, E, C, D]
    # expert einsum must use BOTH mesh axes: experts over model when E
    # divides it; otherwise per-group capacity over model (mixtral E=8).
    # Serve cells with indivisible E keep the dispatch unsharded beyond
    # groups — the model axis lives on the (resident) expert FFN dim instead
    # (EXPERIMENTS.md §Perf cell 3)
    if e % max(axis_size("tp"), 1) == 0:
        ep_spec = ("fsdp", "tp", None, None)
    elif is_serve() and t <= 4096:   # decode-scale batches only
        ep_spec = ("fsdp", None, None, None)
    else:
        ep_spec = ("fsdp", None, "tp", None)
    buf = constrain(buf, *ep_spec)

    w = p["experts"]
    h = jnp.einsum("gecd,edf->gecf", buf, w["w1"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", buf, w["w3"])
    h = constrain(h, *ep_spec)
    out = jnp.einsum("gecf,efd->gecd", h, w["w2"])            # [G, E, C, D]
    out = constrain(out, *ep_spec)

    gathered = jax.vmap(lambda o, e_ids, p_ids: o[e_ids, p_ids])(
        out, eg, pos_c)                                       # [G, Tg*k, D]
    weight = (pg * keep).astype(x.dtype)
    y = (gathered * weight[..., None]).reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
