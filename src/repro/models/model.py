"""LM: assembles the architecture zoo from block kinds.

Layer stack = `block_pattern` cycled `pattern_cycles` times (scanned, remat)
plus an unrolled remainder.  One code path serves train (no cache), prefill
(cache written), and decode (cache read/updated, one token).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.dist.act import constrain, axis_size
from repro.models.moe import init_moe, moe_ffn
from repro.models.recurrent import init_rglru, init_rglru_cache, rglru_block
from repro.models.xlstm import (init_mlstm, init_mlstm_cache, mlstm_block,
                                init_slstm, init_slstm_cache, slstm_block)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    ks = jax.random.split(key, 10)
    p = {
        "ln1": jnp.ones((d,), jnp.float32),
        "wq": L.dense_init(ks[0], d, h * hd, dtype),
        "wk": L.dense_init(ks[1], d, kv * hd, dtype),
        "wv": L.dense_init(ks[2], d, kv * hd, dtype),
        "wo": L.dense_init(ks[3], h * hd, d, dtype),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    if cfg.moe:
        p["moe"] = init_moe(ks[4], cfg, dtype)
    else:
        p["w1"] = L.dense_init(ks[5], d, f, dtype)
        p["w3"] = L.dense_init(ks[6], d, f, dtype)
        p["w2"] = L.dense_init(ks[7], f, d, dtype)
    return p


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if kind == "swa":
        w = min(cfg.window, max_len)
        return {
            "k": jnp.zeros((batch, w, kv, hd), dtype),
            "v": jnp.zeros((batch, w, kv, hd), dtype),
            "pos_arr": jnp.full((batch, w), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def _head_norm(x, w, eps):
    """Per-head RMSNorm over the last (head_dim) axis (qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def attn_block(x, p, cfg: ModelConfig, kind: str, cache: Optional[dict],
               positions: jnp.ndarray, pos0: Optional[jnp.ndarray]):
    """x [B,S,D]; positions [B,S]; pos0 = scalar cache fill level (None when
    training without cache).  Returns (x, new_cache, aux_loss)."""
    b, s, d = x.shape
    h_, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.window if kind == "swa" else None

    hnorm = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = hnorm @ p["wq"]
    k = hnorm @ p["wk"]
    v = hnorm @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    # heads-TP when the head count divides the tp axis; otherwise keep the
    # query seq-sharded (sequence parallelism) and let SPMD gather k/v.
    # qkv_spec="sp" forces the uniform sequence layout (prefill: attention
    # chunks become shard-local instead of re-gathering per chunk)
    if cfg.qkv_spec == "sp":
        qspec = kvspec = ("dp", "sp", None, None)
    elif h_ % max(axis_size("tp"), 1) == 0:
        qspec = ("dp", None, "tp", None)
        kvspec = ("dp", None, "tp", None)
    else:
        qspec = ("dp", "sp", None, None)
        kvspec = ("dp", None, "tp", None)
    q = constrain(q.reshape(b, s, h_, hd), *qspec)
    k = constrain(k.reshape(b, s, kv, hd), *kvspec)
    v = constrain(v.reshape(b, s, kv, hd), *kvspec)
    if cfg.qk_norm:
        q = _head_norm(q, p["q_norm"], cfg.norm_eps)
        k = _head_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        cos, sin = L.rope_tables(positions, hd, cfg.rope_base)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)

    new_cache = None
    if cache is None:
        kk, vv, kv_pos = k, v, positions
        triangular = True
    else:
        triangular = False
        if "pos_arr" in cache:          # sliding-window ring buffer
            if s > 1:
                # prefill (fresh cache at pos0): attend in-sequence — never
                # read the cache, only build the ring for future decode
                kk, vv, kv_pos = k, v, positions
                triangular = True
            else:
                # decode: attend over (old ring UNION the new token)
                kk = jnp.concatenate([cache["k"], k], axis=1)
                vv = jnp.concatenate([cache["v"], v], axis=1)
                kv_pos = jnp.concatenate([cache["pos_arr"], positions],
                                         axis=1)
            w = cache["k"].shape[1]
            lw = min(s, w)
            slots = (positions[0, -lw:]) % w          # [lw] (shared layout)
            ck = cache["k"].at[:, slots].set(k[:, -lw:])
            cv = cache["v"].at[:, slots].set(v[:, -lw:])
            cp = cache["pos_arr"].at[:, slots].set(positions[:, -lw:])
            new_cache = {"k": ck, "v": cv, "pos_arr": cp}
        else:                            # full causal cache
            if s == cache["k"].shape[1]:
                # prefill filling the whole cache: direct assignment (a
                # traced-offset DUS covering every slot would force SPMD
                # to replicate the sharded cache)
                ck, cv = k, v
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, pos0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, pos0, 1)
            new_cache = {"k": ck, "v": cv}
            if s > 1:
                # prefill (fresh cache at pos0): attend in-sequence; the
                # cache write above never feeds the attention read, so a
                # sequence-sharded cache layout stays slice-free
                kk, vv, kv_pos = k, v, positions
                triangular = True
            else:
                max_len = ck.shape[1]
                row = jnp.arange(max_len, dtype=jnp.int32)
                valid = row < (pos0 + s)
                kv_pos = jnp.broadcast_to(jnp.where(valid, row, -1),
                                          (b, max_len))
                kk, vv = ck, cv

    o = L.flash_attention(q, kk, vv, positions, kv_pos, window=window,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                          triangular=triangular)
    acc_t = jnp.bfloat16 if cfg.reduce_dtype == "bfloat16" else jnp.float32
    wo_out = jax.lax.dot_general(
        o.reshape(b, s, h_ * hd), p["wo"], (((2,), (0,)), ((), ())),
        preferred_element_type=acc_t).astype(x.dtype)
    x = x + wo_out
    # sequence-parallel residual stream: the scan carry (saved per cycle by
    # remat) is sharded over the tp axis on the sequence dim
    x = constrain(x, "dp", "sp", None)

    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        ffn, aux = moe_ffn(h2, p["moe"], cfg)
    else:
        h1 = jax.nn.silu(h2 @ p["w1"]) if cfg.act == "silu" \
            else jax.nn.gelu(h2 @ p["w1"])
        h1 = constrain(h1, "dp", None, "tp")
        ffn = jax.lax.dot_general(
            h1 * (h2 @ p["w3"]), p["w2"], (((2,), (0,)), ((), ())),
            preferred_element_type=acc_t).astype(x.dtype)
        aux = jnp.float32(0.0)
    x = x + ffn
    return constrain(x, "dp", "sp", None), new_cache, aux


# ---------------------------------------------------------------------------
# block dispatch
# ---------------------------------------------------------------------------

_INIT = {"attn": init_attn_block, "swa": init_attn_block,
         "rglru": init_rglru, "mlstm": init_mlstm, "slstm": init_slstm}


def init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "swa"):
        return init_attn_cache(cfg, kind, batch, max_len, dtype)
    if kind == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def apply_block(kind: str, x, p, cfg, cache, positions, pos0):
    if kind in ("attn", "swa"):
        return attn_block(x, p, cfg, kind, cache, positions, pos0)
    if kind == "rglru":
        x, c = rglru_block(x, p, cfg, cache)
    elif kind == "mlstm":
        x, c = mlstm_block(x, p, cfg, cache)
    elif kind == "slstm":
        x, c = slstm_block(x, p, cfg, cache)
    else:
        raise ValueError(kind)
    return x, c, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = _dtype(cfg)
        k_embed, k_blocks, k_rem, k_head = jax.random.split(key, 4)
        pattern = cfg.block_pattern
        n_cyc, rem = cfg.pattern_cycles, cfg.pattern_remainder

        if cfg.n_codebooks:
            embed = (jax.random.normal(
                k_embed, (cfg.n_codebooks, cfg.vocab_size, cfg.d_model),
                jnp.float32) * 0.02).astype(dtype)
        else:
            embed = (jax.random.normal(
                k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(dtype)

        cyc_keys = jax.random.split(k_blocks, max(n_cyc, 1))

        def one_cycle(ck):
            pks = jax.random.split(ck, len(pattern))
            return tuple(_INIT[kind](pks[i], cfg, dtype)
                         for i, kind in enumerate(pattern))

        blocks = jax.vmap(one_cycle)(cyc_keys) if n_cyc else ()

        rem_keys = jax.random.split(k_rem, max(rem, 1))
        rem_blocks = tuple(_INIT[pattern[i]](rem_keys[i], cfg, dtype)
                           for i in range(rem))

        params = {
            "embed": embed,
            "blocks": blocks,
            "rem": rem_blocks,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            if cfg.n_codebooks:
                params["head"] = (jax.random.normal(
                    k_head, (cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
                    jnp.float32) * 0.02).astype(dtype)
            else:
                params["head"] = L.dense_init(
                    k_head, cfg.d_model, cfg.vocab_size, dtype)
        return params

    # -- caches -----------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = _dtype(cfg)
        pattern = cfg.block_pattern
        n_cyc, rem = cfg.pattern_cycles, cfg.pattern_remainder

        def stack(kind):
            one = init_block_cache(cfg, kind, batch, max_len, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_cyc,) + a.shape).copy(), one)

        return {
            "pos": jnp.int32(0),
            "blocks": tuple(stack(kind) for kind in pattern),
            "rem": tuple(init_block_cache(cfg, pattern[i], batch, max_len,
                                          dtype) for i in range(rem)),
        }

    # -- embedding / head ---------------------------------------------------------

    def _embed(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        if cfg.n_codebooks:
            # tokens [B, S, n_cb]
            parts = [jnp.take(params["embed"][c], tokens[..., c], axis=0)
                     for c in range(cfg.n_codebooks)]
            x = sum(parts)
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        if patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        return constrain(x, "dp", "sp", None)

    def _head(self, params, x):
        cfg = self.cfg
        xf = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.n_codebooks:
            head = params.get("head")
            return jnp.einsum("bsd,cdv->bscv", xf, head)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["head"])
        return xf @ head

    # -- layer stack -----------------------------------------------------------------

    def _run_blocks(self, params, x, caches, positions, pos0):
        cfg = self.cfg
        pattern = cfg.block_pattern
        n_cyc, rem = cfg.pattern_cycles, cfg.pattern_remainder
        has_cache = caches is not None
        aux_total = jnp.float32(0.0)

        def cycle(carry, inp):
            x, aux = carry
            if has_cache:
                cyc_params, cyc_cache = inp
            else:
                cyc_params, cyc_cache = inp, [None] * len(pattern)
            new_caches = []
            for i, kind in enumerate(pattern):
                x, c_new, aux_i = apply_block(
                    kind, x, cyc_params[i], cfg, cyc_cache[i], positions,
                    pos0)
                new_caches.append(c_new)
                aux = aux + aux_i
            ys = tuple(new_caches) if has_cache else None
            return (x, aux), ys

        body = jax.remat(cycle) if cfg.remat else cycle

        if n_cyc:
            xs = ((params["blocks"], caches["blocks"]) if has_cache
                  else params["blocks"])
            if cfg.scan_layers:
                (x, aux_total), new_blocks = jax.lax.scan(
                    body, (x, aux_total), xs)
            else:
                outs = []
                carry = (x, aux_total)
                for ci in range(n_cyc):
                    inp = jax.tree.map(lambda a: a[ci], xs)
                    carry, ys = body(carry, inp)
                    outs.append(ys)
                x, aux_total = carry
                new_blocks = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                              if has_cache else None)
        else:
            new_blocks = caches["blocks"] if has_cache else None

        new_rem = []
        for i in range(rem):
            kind = pattern[i]
            c_i = caches["rem"][i] if has_cache else None
            x, c_new, aux_i = apply_block(kind, x, params["rem"][i], cfg,
                                          c_i, positions, pos0)
            new_rem.append(c_new)
            aux_total = aux_total + aux_i

        new_caches = None
        if has_cache:
            new_caches = {"pos": pos0 + x.shape[1],
                          "blocks": new_blocks, "rem": tuple(new_rem)}
        return x, new_caches, aux_total

    # -- public entry points ------------------------------------------------------------

    def forward_train(self, params, tokens, patch_embeds=None):
        """Full forward, no cache. Returns (logits, aux_loss)."""
        x = self._embed(params, tokens, patch_embeds)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _, aux = self._run_blocks(params, x, None, positions, None)
        return self._head(params, x), aux

    def loss(self, params, batch) -> jnp.ndarray:
        """Chunked cross-entropy: the [B, S, V] logits tensor is never
        materialized — the head matmul + CE run per sequence chunk under
        remat (the classic big-vocab memory fix).

        batch: {tokens [B,S(,n_cb)] int32, (patch_embeds [B,P,D])}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch.get("patch_embeds"))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _, aux = self._run_blocks(params, x, None, positions, None)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.patch_prefix:
            x = x[:, cfg.patch_prefix:]
        x = x[:, :-1]
        labels = tokens[:, 1:]

        if cfg.n_codebooks:
            head = params["head"]

            def head_fn(xc):
                return jnp.einsum("bsd,cdv->bscv", xc, head)
        else:
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["head"])

            def head_fn(xc):
                return xc @ head

        chunk = max(1, min(256, x.shape[1]))
        n_chunk = -(-x.shape[1] // chunk)
        pad = n_chunk * chunk - x.shape[1]
        weights = jnp.ones(x.shape[:2], jnp.float32)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, [(0, 0), (0, pad)] +
                             [(0, 0)] * (labels.ndim - 2))
            weights = jnp.pad(weights, ((0, 0), (0, pad)))

        def to_chunks(t):
            return t.reshape(b, n_chunk, chunk,
                             *t.shape[2:]).swapaxes(0, 1)

        xs = (to_chunks(x), to_chunks(labels), to_chunks(weights))

        @jax.remat
        def body(carry, inp):
            xc, lc, wc = inp
            logits = head_fn(xc).astype(jnp.float32)
            # seq-shard the chunk over tp: per-device logits stay small even
            # for non-16-divisible vocabs (minicpm, phi4)
            if cfg.n_codebooks:
                logits = constrain(logits, "dp", "tp", None, None)
            else:
                logits = constrain(logits, "dp", "tp", None)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            nll = logz - gold
            if cfg.n_codebooks:
                nll = jnp.mean(nll, axis=-1)
            return carry + jnp.sum(nll * wc), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        return total / denom + 0.01 * aux

    def prefill(self, params, tokens, cache, patch_embeds=None):
        """Writes the cache; returns (last-token logits, cache)."""
        x = self._embed(params, tokens, patch_embeds)
        b, s = x.shape[0], x.shape[1]
        pos0 = cache["pos"]
        positions = pos0 + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        x, cache, _ = self._run_blocks(params, x, cache, positions, pos0)
        return self._head(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        """tokens [B,1(,n_cb)]; returns (logits [B,1,V(,cb)], cache)."""
        x = self._embed(params, tokens)
        b = x.shape[0]
        pos0 = cache["pos"]
        positions = jnp.full((b, 1), pos0, jnp.int32)
        x, cache, _ = self._run_blocks(params, x, cache, positions, pos0)
        return self._head(params, x), cache
