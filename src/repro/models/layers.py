"""Core layers: RMSNorm, RoPE, flash (chunked online-softmax) attention,
SwiGLU MLP.  All activations bf16 with f32 softmax/norm internals."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_tables(positions: jnp.ndarray, head_dim: int,
                base: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [..., S] -> (cos, sin) [..., S, head_dim/2] (f32)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(base) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, N, D]; cos/sin [B, S, D/2] (NeoX half-rotation layout)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

_NEG = -1e30


def _attn_chunk_scan(q_c, q_pos_c, k, v, kv_pos, kv_chunk, window, scale):
    """One q chunk against kv chunks [0, n_kv).  Shapes:
    q_c [B, qc, KV, G, D]; q_pos_c [B, qc]; k/v [B, Skv, KV, D];
    kv_pos [B, Skv].  Returns [B, qc, KV, G, D]."""
    b, qc, kv_h, g, d = q_c.shape
    skv = k.shape[1]
    n_kv = skv // kv_chunk

    def body(carry, idx):
        # slice chunks in-loop (no materialized transpose of the KV cache)
        m, l, acc = carry
        off = idx * kv_chunk
        k_c = jax.lax.dynamic_slice_in_dim(k, off, kv_chunk, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, off, kv_chunk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, off, kv_chunk, axis=1)
        s = jnp.einsum("bqkgd,bckd->bkgqc", q_c, k_c,
                       preferred_element_type=jnp.float32) * scale
        mask = (kp[:, None, None, None, :] >= 0) & \
               (kp[:, None, None, None, :] <= q_pos_c[:, None, None, :, None])
        if window is not None:
            mask &= kp[:, None, None, None, :] > \
                (q_pos_c[:, None, None, :, None] - window)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, kv_h, g, qc), _NEG, jnp.float32),
            jnp.zeros((b, kv_h, g, qc), jnp.float32),
            jnp.zeros((b, kv_h, g, qc, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_kv))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4)        # [B, qc, KV, G, D]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    q_pos: jnp.ndarray, kv_pos: jnp.ndarray, *,
                    window: Optional[int] = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    triangular: bool = False) -> jnp.ndarray:
    """Online-softmax attention with positional masking.

    q [B, Sq, H, D]; k/v [B, Skv, KV, D]; q_pos [B, Sq]; kv_pos [B, Skv]
    (kv_pos < 0 marks invalid cache slots).  `triangular=True` (self-attention
    where q_pos == kv_pos) statically skips kv chunks above the causal
    diagonal — half the FLOPs of the full rectangle.
    """
    b, sq, h, d = q.shape
    _, skv, kv_h, _ = k.shape
    g = h // kv_h
    scale = 1.0 / math.sqrt(d)

    if sq == 1:
        # decode fast path: no chunk loop, no dynamic slicing — works
        # directly on a sequence-sharded KV cache (flash-decoding layout:
        # XLA partial-softmaxes per shard and combines)
        qg = q.reshape(b, 1, kv_h, g, d)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k,
                       preferred_element_type=jnp.float32) * scale
        mask = (kv_pos[:, None, None, None, :] >= 0) & \
               (kv_pos[:, None, None, None, :] <=
                q_pos[:, None, None, :, None])
        if window is not None:
            mask &= kv_pos[:, None, None, None, :] > \
                (q_pos[:, None, None, :, None] - window)
        s = jnp.where(mask, s, _NEG)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m) * mask
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, d).astype(q.dtype)

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)

    # pad sequences to chunk multiples (padded kv slots get pos = -1)
    sq_p = -(-sq // qc) * qc
    skv_p = -(-skv // kc) * kc
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, sq_p - sq)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, skv_p - skv)),
                         constant_values=-1)

    qg = q.reshape(b, sq_p, kv_h, g, d)
    n_q = sq_p // qc
    outs = []
    for i in range(n_q):
        q_c = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)
        qp_c = jax.lax.dynamic_slice_in_dim(q_pos, i * qc, qc, axis=1)
        if triangular:
            # causal self-attention: kv chunks beyond this q chunk's last
            # position can never be attended — skip them statically
            hi = min((i + 1) * qc, skv_p)
            hi = -(-hi // kc) * kc
        else:
            hi = skv_p
        o = _attn_chunk_scan(q_c, qp_c, k[:, :hi], v[:, :hi],
                             kv_pos[:, :hi], kc, window, scale)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)[:, :sq]
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
           w2: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    a = x @ w1
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    return (a * (x @ w3)) @ w2


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * (1.0 / math.sqrt(in_dim))).astype(dtype)
