"""End-to-end training driver.

Runs on whatever devices exist (CPU smoke -> real pod): builds the mesh,
shards state, runs the fault-tolerant training loop (async checkpoints,
straggler watchdog, deterministic resumable data).

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
      --steps 20 --batch 8 --seq-len 64
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import LM
from repro.data.pipeline import SyntheticTokens, Prefetcher
from repro.dist.act import activation_sharding
from repro.dist.fault import RestartManager
from repro.dist.sharding import ShardingRules, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m", choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = LM(cfg)
    opt_cfg = AdamWConfig(
        peak_lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        schedule="wsd" if args.arch == "minicpm-2b" else "cosine")

    mesh = make_host_mesh()
    rules = ShardingRules(mesh, "dp")

    params = model.init(jax.random.PRNGKey(args.seed))
    state = {"params": params, "opt": adamw_init(params)}
    p_sh = param_shardings(rules, jax.eval_shape(lambda: params))
    state_sh = {"params": p_sh,
                "opt": {"mu": p_sh, "nu": p_sh, "step": rules.named((), [])}}
    state = jax.device_put(state, state_sh)

    raw_step = make_train_step(model, opt_cfg, accum_steps=args.accum)

    def ctx_step(state, batch):
        with activation_sharding(rules):
            return raw_step(state, batch)

    jit_step = jax.jit(ctx_step, donate_argnums=(0,))

    data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq_len,
                           n_codebooks=cfg.n_codebooks,
                           patch_prefix=cfg.patch_prefix,
                           d_model=cfg.d_model, seed=args.seed)
    prefetch = Prefetcher(data, depth=2).start(0)

    mgr = RestartManager(args.ckpt_dir, save_every=args.save_every)

    losses = []

    def step_fn(state, batch):
        with mesh:
            state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
        return state, metrics

    t0 = time.perf_counter()
    try:
        state, steps, restarts = mgr.run(state, step_fn, data, args.steps,
                                         shardings=state_sh)
    finally:
        prefetch.stop()
    dt = time.perf_counter() - t0
    if not losses:
        # resumed a checkpoint dir that already reached --steps: nothing to
        # replay (idempotent restart) — report and exit clean
        print(f"arch={cfg.name} steps={steps} restarts={restarts} "
              f"(already complete in {args.ckpt_dir}; no steps run)")
        return 0
    tokens = len(losses) * args.batch * args.seq_len
    print(f"arch={cfg.name} steps={steps} restarts={restarts} "
          f"loss[0]={losses[0]:.4f} loss[-1]={losses[-1]:.4f} "
          f"({tokens / dt:.0f} tok/s wall)")
    if len(losses) > 10:
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), \
            "loss did not decrease"
        print("loss decreased: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
