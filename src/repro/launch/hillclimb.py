import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: re-lower one cell with a named variant (hypothesis)
and diff the roofline terms against the recorded baseline.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch minicpm-2b --shape train_4k --variant grad_bf16_rs
"""

import argparse
import dataclasses
import json

from repro import configs
from repro.launch import dryrun as DR
from repro.models.config import SHAPES


# named variants: cfg/cell overrides implementing one hypothesis each
def variant_overrides(name: str, cfg):
    """Returns (new_cfg, build_kwargs)."""
    if name == "baseline":
        return cfg, {}
    if name == "accum2":
        # hypothesis: halving the microbatch halves live remat residuals
        # (memory term) at <2% collective cost (same grads, one extra loop)
        return cfg, {"accum_steps": 2}
    if name == "accum4":
        return cfg, {"accum_steps": 4}
    if name == "policy_tp":
        return cfg, {"policy": "tp"}
    if name == "policy_dp":
        return cfg, {"policy": "dp"}
    if name == "kv_chunk_2k":
        # hypothesis: larger kv chunks cut per-chunk overheads in prefill
        return dataclasses.replace(cfg, kv_chunk=2048), {}
    if name == "q_chunk_1k":
        return dataclasses.replace(cfg, q_chunk=1024, kv_chunk=2048), {}
    if name == "q_chunk_2k":
        return dataclasses.replace(cfg, q_chunk=2048, kv_chunk=4096), {}
    if name == "q_chunk_4k":
        return dataclasses.replace(cfg, q_chunk=4096, kv_chunk=8192), {}
    if name == "bf16_reduce":
        # hypothesis: XLA all-reduces TP partial sums in the f32 accumulation
        # dtype; bf16 halves those wire bytes at standard numerics cost
        return dataclasses.replace(cfg, reduce_dtype="bfloat16"), {}
    if name == "qkv_sp":
        # hypothesis: uniform seq-sharded q/k/v keeps attention chunk math
        # shard-local; collectives collapse to one k/v gather per layer
        return dataclasses.replace(cfg, qkv_spec="sp"), {}
    if name == "full_sp":
        # hypothesis: qkv_sp failed because serve carries gathered back per
        # layer; with seq-sharded carries too the whole prefill is
        # sequence-resident (weights gathered FSDP-style, activations local)
        return dataclasses.replace(cfg, qkv_spec="sp"), {"force_sp": True}
    if name == "no_remat":
        # hypothesis: decode/prefill don't backprop; remat only pays off in
        # training — disabling it removes recompute dots from serve cells
        return dataclasses.replace(cfg, remat=False), {}
    if name == "unroll_layers":
        return dataclasses.replace(cfg, scan_layers=False), {}
    if name == "dense_expert":
        # hypothesis (decode): at tiny token counts, computing ALL experts
        # densely (E x overcompute on a trivial FLOP budget) eliminates the
        # dispatch machinery entirely — weights are read either way, so the
        # memory term is unchanged and the collective term collapses
        return dataclasses.replace(cfg, capacity_factor=float(
            cfg.n_experts) / max(cfg.top_k, 1)), {}
    raise ValueError(name)


def run(arch: str, shape: str, variant: str, multi_pod: bool = False) -> dict:
    cfg0 = configs.get(arch)
    cfg, kwargs = variant_overrides(variant, cfg0)
    import repro.launch.specs as S

    orig_build = S.build_cell

    def build(a, s, mesh, **kw):
        kw.update(kwargs)
        return orig_build(a, s, mesh, cfg=cfg, **kw)

    DR.build_cell = build
    try:
        rec = DR.run_cell(arch, shape, multi_pod)
    finally:
        DR.build_cell = orig_build
    rec["variant"] = variant
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default="experiments/hillclimb.json")
    args = ap.parse_args()

    rec = run(args.arch, args.shape, args.variant)
    t = rec["roofline"]
    print(f"{args.arch} {args.shape} [{args.variant}]  "
          f"compute={t['compute_s']*1e3:.1f}ms memory={t['memory_s']*1e3:.1f}ms "
          f"collective={t['collective_s']*1e3:.1f}ms dominant={t['dominant']} "
          f"peak={rec['peak_bytes_per_dev']/2**30:.1f}GiB "
          f"wire={rec['collectives']['total_wire_bytes']/2**30:.2f}GiB")
    records = []
    if os.path.exists(args.out):
        records = json.load(open(args.out))
    records.append(rec)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
