"""Compiled-HLO analysis: collective inventory (with while-body trip-count
correction) + roofline terms.

XLA's cost_analysis counts a while (scan) body ONCE, so both FLOPs and
collective bytes inside the layer scan must be multiplied by the trip count.
We parse the compiled HLO text: computations reached from a `while` op's
body/condition get the caller's trip multiplier (the layer-scan count from
the config); collectives outside loops count once.
"""

from __future__ import annotations

import dataclasses
import math
import re

from typing import Dict, List, Optional, Tuple


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?[a-z0-9\[\],\{\} *]*\)?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(type_str: str) -> int:
    """Sum bytes over all tensors in an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    """Participant count per replica group."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota form [ngroups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return total_devices


@dataclasses.dataclass
class Collective:
    op: str
    tensor_bytes: int      # full (global logical) tensor bytes on the op
    group_size: int
    multiplier: int        # while-loop trip count product
    computation: str

    @property
    def wire_bytes_per_device(self) -> float:
        """Ring-algorithm bytes crossing each device's links, per op.

        tensor_bytes is the op's OUTPUT in the SPMD-partitioned module, i.e.
        the per-device local shape:
          all-gather:      out = full gathered  -> wire = b*(g-1)/g
          all-reduce:      out = local buffer   -> wire = 2*b*(g-1)/g
          reduce-scatter:  out = 1/g shard      -> wire = b*(g-1)
          all-to-all:      out = local buffer   -> wire = b*(g-1)/g
          collective-permute: one hop           -> wire = b
        """
        g = max(self.group_size, 1)
        b = self.tensor_bytes
        if self.op == "all-reduce":
            return 2.0 * b * (g - 1) / g
        if self.op == "all-gather":
            return b * (g - 1) / g
        if self.op == "reduce-scatter":
            return float(b) * (g - 1)
        if self.op == "all-to-all":
            return b * (g - 1) / g
        return float(b)


def _computation_blocks(hlo: str) -> Dict[str, str]:
    """Split the HLO module text into named computation bodies.

    Computation headers sit at column 0 and end with '{'; ops are indented;
    a body closes with a column-0 '}'.  Header names may be preceded by
    ENTRY and '%', and parameter lists can contain nested parens (tuple
    types), so the name is taken as the token before the first '('.
    """
    blocks: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if (stripped.endswith("{") and line[:1] not in (" ", "\t")
                and "(" in stripped):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur_name, cur_lines = m.group(1), []
                continue
        if stripped.startswith("}") and line[:1] not in (" ", "\t"):
            if cur_name is not None:
                blocks[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = None, []
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return blocks


#: one HLO scalar literal: int, float, or scientific notation (XLA prints
#: large bounds as e.g. `constant(2.14748365e+09)`, and f32 loop bounds as
#: `constant(1000)` or `constant(1e+06)` depending on magnitude)
_SCALAR_NUM = r"[-+]?(?:\d+\.?\d*|\.\d+)(?:[eE][-+]?\d+)?"


def _parse_scalar(text: str) -> Optional[int]:
    """An HLO scalar constant as an int, or None if not a finite number.

    Trip counts are integral even when the condition compares against an
    f32 bound printed in scientific notation; `int("1e+06")` raises, so
    the previous digits-only parse silently dropped those bounds (trip
    multiplier fell back to 1: a million-fold flop/byte undercount)."""
    t = text.strip()
    try:
        return int(t)
    except ValueError:
        pass
    try:
        v = float(t)
    except ValueError:
        return None
    if not math.isfinite(v):
        return None
    return int(v)


def _loop_trip_count(cond_text: str) -> int:
    """Static trip count from a while condition: the integer constant used in
    the loop-bound compare (i < N).  Falls back to 1 if not found."""
    consts = {}
    for m in re.finditer(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*"
                         r"constant\((" + _SCALAR_NUM + r")\)",
                         cond_text):
        v = _parse_scalar(m.group(2))
        if v is not None and v > 0:
            consts[m.group(1)] = v
    trips = []
    for m in re.finditer(r"compare\(([^)]*)\)[^\n]*direction=(LT|GT|LE|GE)",
                         cond_text):
        for operand in m.group(1).split(","):
            name = operand.strip().lstrip("%")
            if name in consts:
                trips.append(consts[name])
    if trips:
        return max(trips)
    if consts:
        return max(consts.values())
    return 1


def _computation_multipliers(hlo: str, blocks: Dict[str, str]) -> Dict[str, int]:
    """Effective execution count per computation: product of trip counts of
    enclosing while loops (handles nesting: layer scan x attention scan)."""
    # per-block: which computations it calls, and which whiles it contains
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
    while_re = re.compile(r"condition=%?([\w\.\-]+),?\s*body=%?([\w\.\-]+)|"
                          r"body=%?([\w\.\-]+),?\s*condition=%?([\w\.\-]+)")

    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in blocks:
            return
        if mult.get(name, 0) >= m:  # already visited with >= multiplier
            return
        mult[name] = max(mult.get(name, 0), m)
        text = blocks[name]
        for wm in while_re.finditer(text):
            cond = wm.group(1) or wm.group(4)
            body = wm.group(2) or wm.group(3)
            trip = _loop_trip_count(blocks.get(cond, ""))
            visit(cond, m * trip)
            visit(body, m * trip)
        for cm in call_re.finditer(text):
            callee = cm.group(1)
            visit(callee, m)

    # entry computations: those never called/used as bodies
    called = set()
    for text in blocks.values():
        for cm in call_re.finditer(text):
            called.add(cm.group(1))
        for wm in while_re.finditer(text):
            for g in wm.groups():
                if g:
                    called.add(g)
    roots = [n for n in blocks if n not in called]
    for r in roots:
        visit(r, 1)
    # anything unreached (conservatively) counts once
    for n in blocks:
        mult.setdefault(n, 1)
    return mult


def parse_collectives(hlo: str, total_devices: int,
                      while_trip_count: int = 1) -> List[Collective]:
    """Inventory every collective with its true execution count: each op is
    multiplied by the product of trip counts of its enclosing while loops,
    parsed from the loop-bound compares (while_trip_count is unused, kept
    for API compatibility)."""
    del while_trip_count
    blocks = _computation_blocks(hlo)
    mults = _computation_multipliers(hlo, blocks)

    out: List[Collective] = []
    for cname, body in blocks.items():
        mult = mults.get(cname, 1)
        for line in body.splitlines():
            m = _COLL_RE.search(line)
            if not m:
                continue
            if "-done(" in line:
                continue  # count start ops only (async pairs)
            op = m.group(1)
            # result type: substring between '=' and the op token
            eq = line.find("=")
            op_idx = line.find(op, eq)
            tbytes = _tensor_bytes(line[eq + 1:op_idx]) if eq >= 0 else 0
            if "-start(" in line and op == "all-gather":
                # async start returns (operand, result) tuple: halve
                tbytes //= 2
            out.append(Collective(op=op, tensor_bytes=tbytes,
                                  group_size=_group_size(line, total_devices),
                                  multiplier=mult, computation=cname))
    return out


def collective_summary(colls: List[Collective]) -> Dict[str, float]:
    by_op: Dict[str, float] = {}
    total = 0.0
    for c in colls:
        wire = c.wire_bytes_per_device * c.multiplier
        by_op[c.op] = by_op.get(c.op, 0.0) + wire
        total += wire
    by_op["total_wire_bytes"] = total
    by_op["n_ops"] = float(len(colls))
    return by_op


# ---------------------------------------------------------------------------
# FLOP / HBM-byte estimation with loop multipliers
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(
    r"=\s*(?P<out>[\w\[\],\{\} ]+?)\s*dot\(\s*(?P<args>[^)]*)\)"
    r"[^\n]*lhs_contracting_dims=\{(?P<lc>[\d,]*)\}")
_CONV_RE = re.compile(r"=\s*(?P<out>[\w\[\],\{\} ]+?)\s*convolution\(")


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _lhs_dims(args: str, op_shape: Dict[str, List[int]]) -> List[int]:
    """LHS operand dims of a dot.  Compiled HLO writes TYPED operands —
    `dot(f32[16,32]{1,0} %Arg_0.1, f32[32,8]{1,0} %Arg_1.2)` — so the shape
    is read straight off the operand text (naively splitting the arg list on
    ',' would cut `f32[16,32]` in half and lose the contracting dims, a
    silent ~K-fold FLOP undercount).  Unoptimized-HLO operand lists are
    name-only (`dot(%a, %b)`); those fall back to the definition map."""
    typed = _SHAPE_RE.findall(args)
    if typed:
        return [int(d) for d in typed[0][1].split(",") if d]
    first = args.split(",")[0].strip().lstrip("%")
    return op_shape.get(first, [])


def parse_dot_flops(hlo: str) -> float:
    """Sum 2 * prod(out_dims) * prod(contracting_dims) over every dot in the
    module, multiplied by the enclosing while-loop trip product.  out_dims
    carries the batch dims, so batched dots are fully counted."""
    blocks = _computation_blocks(hlo)
    mults = _computation_multipliers(hlo, blocks)

    # map op name -> result dims (fallback for untyped operand lists)
    def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*([^=]+?)\s*"
                        r"([a-z][\w\-]*)\(")
    op_shape: Dict[str, List[int]] = {}
    for line in hlo.splitlines():
        m = def_re.match(line)
        if m:
            _, dims = _shape_dims(m.group(2))
            op_shape[m.group(1)] = dims

    total = 0.0
    for cname, body in blocks.items():
        mult = mults.get(cname, 1)
        for line in body.splitlines():
            if " dot(" not in line:
                continue
            dm = _DOT_RE.search(line)
            if not dm:
                continue
            _, out_dims = _shape_dims(dm.group("out"))
            lhs_dims = _lhs_dims(dm.group("args"), op_shape)
            lc = [int(x) for x in dm.group("lc").split(",") if x]
            k = 1
            for ci in lc:
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
            out_n = 1
            for d in out_dims:
                out_n *= d
            total += 2.0 * out_n * k * mult
    return total


def estimate_hbm_bytes(hlo: str) -> float:
    """Rough HBM traffic: every top-level op result written once (and read
    ~once downstream), times the loop multiplier.  Fusion internals are
    invisible (correct: they stay in registers/VMEM); parameters are counted
    via their get-tuple-element/parameter materializations."""
    blocks = _computation_blocks(hlo)
    mults = _computation_multipliers(hlo, blocks)
    def_re = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*([^=]+?)\s*"
                        r"[a-z][\w\-]*\(")
    skip = ("tuple(", "get-tuple-element(", "parameter(", "constant(",
            "bitcast(", "copy-done(", "all-gather-done(")
    total = 0.0
    for cname, body in blocks.items():
        mult = mults.get(cname, 1)
        for line in body.splitlines():
            if any(s in line for s in skip):
                continue
            m = def_re.match(line)
            if not m:
                continue
            total += _tensor_bytes(m.group(1)) * mult
    return 2.0 * total  # write + downstream read


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (~per device, ring)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> Dict[str, float]:
    """All inputs are per-device quantities (the SPMD module has local
    shapes), so no further division by chip count:
    HLO_FLOPs/(chips*peak) == flops_per_dev/peak for balanced sharding."""
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = hbm_bytes_per_dev / HBM_BW
    collective_s = wire_bytes_per_dev / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
