"""input_specs: ShapeDtypeStruct stand-ins for every model input, plus the
per-cell (step_fn, arg specs, shardings) assembly used by dryrun/roofline.

No device allocation happens here — params/caches come from jax.eval_shape.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import LM
from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.dist.sharding import (ShardingRules, param_shardings,
                                 batch_shardings, cache_shardings)
from repro.dist.act import activation_sharding
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step


def batch_size_per_step(shape: ShapeConfig) -> int:
    return shape.global_batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """The model-input part of a cell: tokens (+ patch embeddings)."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        text = s - cfg.patch_prefix
        spec: Dict[str, Any] = {}
        if cfg.n_codebooks:
            spec["tokens"] = jax.ShapeDtypeStruct(
                (b, text, cfg.n_codebooks), jnp.int32)
        else:
            spec["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
        if cfg.patch_prefix:
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.patch_prefix, cfg.d_model), jnp.bfloat16)
        return spec
    if shape.kind == "prefill":
        s = shape.seq_len
        text = s - cfg.patch_prefix
        spec = {}
        if cfg.n_codebooks:
            spec["tokens"] = jax.ShapeDtypeStruct(
                (b, text, cfg.n_codebooks), jnp.int32)
        else:
            spec["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
        if cfg.patch_prefix:
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.patch_prefix, cfg.d_model), jnp.bfloat16)
        return spec
    # decode: one new token against a seq_len KV cache
    if cfg.n_codebooks:
        return {"tokens": jax.ShapeDtypeStruct((b, 1, cfg.n_codebooks),
                                               jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    fn: Any                  # callable to jit
    args: Tuple[Any, ...]    # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...]
    meta: Dict[str, Any]


def _state_specs(model: LM):
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mu = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                      params)
    nu = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                      params)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params,
            "opt": {"mu": mu, "nu": nu, "step": step}}


def _replicated(rules: ShardingRules, tree):
    return jax.tree.map(lambda s: rules.named(s.shape, [None] * s.ndim), tree)


def choose_policy(cfg: ModelConfig, shape: ShapeConfig, mesh) -> str:
    """Pure FSDP-DP for dense train cells whose batch tiles every chip;
    TP/EP/SP otherwise (MoE needs EP; serving batches don't tile)."""
    if (shape.kind == "train" and not cfg.moe
            and shape.global_batch % mesh.size == 0):
        return "dp"
    return "tp"


def build_cell(arch: str, shape_name: str, mesh, *,
               cfg: Optional[ModelConfig] = None,
               accum_steps: int = 1,
               policy: Optional[str] = None,
               force_sp: bool = False) -> Cell:
    """Assemble (fn, specs, shardings) for one (arch x shape) cell."""
    shape = SHAPES[shape_name]
    cfg = cfg or configs.get(arch)
    if shape.kind == "prefill" and cfg.q_chunk < 2048:
        # adopted from §Perf iteration: SPMD chunk-boundary reshards scale
        # with chunk count; 2k/4k chunks cut prefill wire bytes 21% at +3%
        # compute (triangular granularity) and neutral memory
        cfg = dataclasses.replace(cfg, q_chunk=2048, kv_chunk=4096)
    model = LM(cfg)
    policy = policy or choose_policy(cfg, shape, mesh)
    rules = ShardingRules(mesh, policy)

    serve = shape.kind != "train" and not force_sp

    def _ctx(fn):
        def wrapped(*a):
            with activation_sharding(rules, serve=serve):
                return fn(*a)
        return wrapped

    if shape.kind == "train":
        opt_cfg = AdamWConfig(schedule="wsd" if arch == "minicpm-2b"
                              else "cosine")
        step_fn = _ctx(make_train_step(model, opt_cfg,
                                       accum_steps=accum_steps))
        state = _state_specs(model)
        batch = input_specs(cfg, shape)
        p_sh = param_shardings(rules, state["params"])
        state_sh = {"params": p_sh,
                    "opt": {"mu": jax.tree.map(lambda s: s, p_sh),
                            "nu": jax.tree.map(lambda s: s, p_sh),
                            "step": rules.named((), [])}}
        batch_sh = batch_shardings(rules, batch)
        metrics_sh = {"loss": rules.named((), []),
                      "grad_norm": rules.named((), []),
                      "lr": rules.named((), [])}
        return Cell(arch, shape, step_fn, (state, batch),
                    (state_sh, batch_sh), (state_sh, metrics_sh),
                    donate=(0,),
                    meta={"cfg": cfg, "model": model, "policy": policy})

    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(rules, params, serve=True)
    b = shape.global_batch

    if shape.kind == "prefill":
        cache = jax.eval_shape(
            lambda: model.init_cache(batch=b, max_len=shape.seq_len))
        c_sh = cache_shardings(rules, cache)
        batch = input_specs(cfg, shape)
        batch_sh = batch_shardings(rules, batch)

        if cfg.patch_prefix:
            @_ctx
            def fn(params, cache, tokens, patch_embeds):
                return model.prefill(params, tokens, cache, patch_embeds)
            args = (params, cache, batch["tokens"], batch["patch_embeds"])
            in_sh = (p_sh, c_sh, batch_sh["tokens"],
                     batch_sh["patch_embeds"])
        else:
            @_ctx
            def fn(params, cache, tokens):
                return model.prefill(params, tokens, cache)
            args = (params, cache, batch["tokens"])
            in_sh = (p_sh, c_sh, batch_sh["tokens"])
        logits_sh = rules.named(
            (b, 1, cfg.vocab_size), ["dp", None, None]
            ) if not cfg.n_codebooks else rules.named(
            (b, 1, cfg.n_codebooks, cfg.vocab_size), ["dp", None, None, None])
        return Cell(arch, shape, fn, args, in_sh, (logits_sh, c_sh),
                    donate=(1,),
                    meta={"cfg": cfg, "model": model, "policy": policy})

    # decode
    cache = jax.eval_shape(
        lambda: model.init_cache(batch=b, max_len=shape.seq_len))
    # cache filled to seq_len - 1 (the new token lands at the last slot)
    c_sh = cache_shardings(rules, cache)
    batch = input_specs(cfg, shape)
    batch_sh = batch_shardings(rules, batch)

    @_ctx
    def fn(params, cache, tokens):
        return model.decode_step(params, tokens, cache)

    if cfg.n_codebooks:
        logits_sh = rules.named((b, 1, cfg.n_codebooks, cfg.vocab_size),
                                ["dp", None, None, None])
    else:
        logits_sh = rules.named((b, 1, cfg.vocab_size), ["dp", None, None])
    return Cell(arch, shape, fn, (params, cache, batch["tokens"]),
                (p_sh, c_sh, batch_sh["tokens"]), (logits_sh, c_sh),
                donate=(1,),
                meta={"cfg": cfg, "model": model, "policy": policy})


def cell_is_applicable(arch: str, shape_name: str) -> Tuple[bool, str]:
    cfg = configs.get(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense-causal decode "
                       "requires sub-quadratic attention (DESIGN.md)")
    return True, ""
