# launch: production mesh, input specs, dry-run driver, train/serve drivers.
