import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture x input shape) on
the single-pod (16,16) and multi-pod (2,16,16) production meshes.

Records per cell: per-device memory analysis (proves fit), cost analysis
(FLOPs/bytes for the roofline), collective inventory (wire bytes with
while-body trip correction), compile wall time.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # multi-pod only
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, cell_is_applicable, input_specs  # noqa: F401 (input_specs is the public API)
from repro.launch import hlo_analysis as H
from repro.models.config import SHAPES

HBM_PER_CHIP = 16 * 2**30  # v5e


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             accum_steps: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cell = build_cell(arch, shape_name, mesh, accum_steps=accum_steps)
    cfg = cell.meta["cfg"]

    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    trip = cfg.pattern_cycles if cfg.scan_layers else 1
    colls = H.parse_collectives(hlo, n_dev, while_trip_count=trip)
    csum = H.collective_summary(colls)
    flops_dev = H.parse_dot_flops(hlo)          # per-device, loop-corrected
    from repro.launch.analytic import cell_flops
    ana = cell_flops(cfg, cell.shape)

    per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               - mem.alias_size_in_bytes + mem.temp_size_in_bytes)

    # exact analytic per-device argument bytes from specs x shardings (the
    # CPU backend emulates bf16 through f32 for some loop carries, inflating
    # measured temp ~2x vs real TPU; see EXPERIMENTS.md note)
    def _local_bytes(spec_tree, shard_tree) -> int:
        total = 0
        for s, sh in zip(jax.tree_util.tree_leaves(spec_tree),
                         jax.tree_util.tree_leaves(
                             shard_tree, is_leaf=lambda x: hasattr(x, "spec"))):
            n = 1
            parts = list(sh.spec) + [None] * (len(s.shape) - len(sh.spec))
            for dim, ax in zip(s.shape, parts):
                if ax is None:
                    n *= dim
                else:
                    axes = (ax,) if isinstance(ax, str) else tuple(ax)
                    k = 1
                    for a in axes:
                        k *= mesh.shape[a]
                    n *= -(-dim // k)
            total += n * s.dtype.itemsize
        return total

    arg_analytic = sum(_local_bytes(a, s)
                       for a, s in zip(cell.args, cell.in_shardings))

    # HBM traffic model: every argument byte read + every output written
    # (2 x analytic args; state/cache are donated aliases) plus transient
    # activations streamed through HBM once (XLA temp; its CPU-bf16 f32
    # inflation ~cancels the second touch).  Lower-bound; see EXPERIMENTS.md.
    hbm_dev = 2.0 * arg_analytic + float(mem.temp_size_in_bytes)
    terms = H.roofline_terms(flops_dev, hbm_dev,
                             csum["total_wire_bytes"])
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "arg_bytes_per_dev": int(mem.argument_size_in_bytes),
        "temp_bytes_per_dev": int(mem.temp_size_in_bytes),
        "out_bytes_per_dev": int(mem.output_size_in_bytes),
        "alias_bytes_per_dev": int(mem.alias_size_in_bytes),
        "peak_bytes_per_dev": int(per_dev),
        "arg_bytes_analytic": int(arg_analytic),
        "fits_16gb": bool(per_dev < HBM_PER_CHIP),
        "hlo_flops_top": float(cost.get("flops", 0.0)),
        "hlo_bytes_top": float(cost.get("bytes accessed", 0.0)),
        "scan_trip": trip,
        "collectives": {k: (round(v, 1) if isinstance(v, float) else v)
                        for k, v in csum.items()},
        "n_hlo_collectives": len(colls),
        # roofline inputs (per-device, loop-multiplier corrected)
        "hlo_flops_per_dev": flops_dev,
        "hbm_bytes_per_dev_est": hbm_dev,
        "policy": cell.meta.get("policy"),
        "analytic": ana,
        "model_flops_ratio": (ana["model_flops"]
                              / max(flops_dev * n_dev, 1.0)),
        "roofline": terms,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else configs.ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records}

    for arch in archs:
        for shape_name in shapes:
            ok, why = cell_is_applicable(arch, shape_name)
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    continue
                if not ok:
                    records.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_name, "status": "skipped",
                                    "reason": why})
                    print(f"SKIP {arch} {shape_name} {mesh_name}: {why}")
                    os.makedirs(os.path.dirname(args.out), exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
                    continue
                try:
                    rec = run_cell(arch, shape_name, mp)
                    print(f"OK   {arch:22s} {shape_name:12s} {mesh_name:8s} "
                          f"compile={rec['compile_s']:7.1f}s "
                          f"peak={rec['peak_bytes_per_dev']/2**30:6.2f}GiB "
                          f"fits={rec['fits_16gb']} "
                          f"wire={rec['collectives']['total_wire_bytes']/2**20:10.1f}MiB")
                except Exception as e:  # noqa: BLE001 - record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"FAIL {arch} {shape_name} {mesh_name}: {e}")
                records.append(rec)
                os.makedirs(os.path.dirname(args.out), exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
