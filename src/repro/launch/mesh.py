"""Production meshes.  A FUNCTION (not module-level constant) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod:  (2, 16, 16) = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
