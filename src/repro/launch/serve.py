"""Serving driver: batched prefill + decode with the concurrent two-level
request scheduler (the paper's policy at the serving layer).

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium --smoke \
      --streams 4 --requests 16 --steps 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import LM
from repro.serve.engine import ServeEngine
from repro.serve.concurrent import (ConcurrentServeScheduler, Request,
                                    RequestStream)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--batch-budget", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 8)

    rng = np.random.default_rng(args.seed)
    sched = ConcurrentServeScheduler(args.groups, args.batch_budget,
                                     seed=args.seed)
    for sid in range(args.streams):
        stream = RequestStream(sid)
        for _ in range(args.requests // args.streams):
            stream.add(Request(sid, int(rng.integers(args.groups)),
                               urgency=float(rng.uniform(0.1, 5.0)),
                               tokens_left=args.steps))
        sched.add_stream(stream)

    served = 0
    t0 = time.perf_counter()
    while True:
        admitted = sched.schedule_step()
        if not admitted:
            break
        b = len(admitted)
        if cfg.n_codebooks:
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab_size,
                             (b, args.prompt_len, cfg.n_codebooks)), jnp.int32)
        else:
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, args.prompt_len)),
                jnp.int32)
        if cfg.patch_prefix:
            # VLM stub frontend: prepend precomputed patch embeddings
            patches = jnp.asarray(
                rng.standard_normal((b, cfg.patch_prefix, cfg.d_model)),
                jnp.bfloat16)
            cache = engine.new_cache(b)
            logits, cache = engine.prefill(prompts, cache, patches)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for _ in range(args.steps):
                logits, cache = engine.decode(tok.reshape(b, 1), cache)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        else:
            out = engine.generate(prompts, args.steps)
            assert out.shape[1] == args.steps
        served += b
        print(f"decode batch of {b} requests "
              f"(groups {sorted(set(r.group for r in admitted))})")
    dt = time.perf_counter() - t0
    print(f"served {served} requests from {args.streams} concurrent streams "
          f"in {dt:.1f}s ({served * args.steps / dt:.1f} tok/s wall)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
