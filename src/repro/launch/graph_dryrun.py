import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN WORKLOAD at pod scale: the fused two-level
engine (one superstep) over a production-sized concurrent-PageRank fleet.

Sharding: vertex blocks over `data`, jobs over `model` (and `pod`); the
global queue is shared, so the push exchanges only the q selected blocks —
the paper's cache argument becomes an ICI sparsifier (DESIGN.md §2).

  PYTHONPATH=src python -m repro.launch.graph_dryrun
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.algorithms import PageRank
from repro.core import engine as E
from repro.core import priority as prio
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as H


def fused_superstep(alg, num_blocks, q, nbr_k, vb):
    """One two-level superstep as a pure function of (values, deltas, tiles,
    nbr_ids, push_scale) — the body of the production while_loop."""

    def step(values, deltas, tiles, nbr_ids, push_scale):
        node_un, p_mean = E.compute_pairs(alg, values, deltas)
        score = prio.do_score(node_un, p_mean)
        topv, topi = jax.lax.top_k(score, q)
        valid = jnp.isfinite(topv)
        w = jnp.arange(q, 0, -1, dtype=jnp.float32) * valid
        gpri = jnp.zeros((num_blocks,), jnp.float32)
        gpri = gpri.at[topi.reshape(-1)].add(w.reshape(-1))
        gv, gsel = jax.lax.top_k(gpri, q)
        gmask = (gv > 0.0).astype(jnp.float32)
        values, deltas = jax.vmap(
            E.push_plus_one, in_axes=(0, 0, None, None, None, None, 0))(
            values, deltas, tiles, nbr_ids,
            gsel.astype(jnp.int32), gmask, push_scale)
        un = jnp.sum(alg.unconverged(values, deltas))
        return values, deltas, un

    return step


def run(n_vertices: int, n_jobs: int, vb: int, avg_nbr_blocks: int,
        multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    bn = n_vertices // vb
    q = E.optimal_queue_length(bn, n_vertices)
    alg = PageRank()
    step = fused_superstep(alg, bn, q, avg_nbr_blocks, vb)

    specs = (
        jax.ShapeDtypeStruct((n_jobs, bn, vb), jnp.float32),  # values
        jax.ShapeDtypeStruct((n_jobs, bn, vb), jnp.float32),  # deltas
        jax.ShapeDtypeStruct((bn, avg_nbr_blocks, vb, vb), jnp.float32),
        jax.ShapeDtypeStruct((bn, avg_nbr_blocks), jnp.int32),
        jax.ShapeDtypeStruct((n_jobs,), jnp.float32),
    )
    job_axes = ("pod", "model") if multi_pod else "model"
    sh = (
        NamedSharding(mesh, P(job_axes, "data", None)),
        NamedSharding(mesh, P(job_axes, "data", None)),
        NamedSharding(mesh, P("data", None, None, None)),
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P()),
    )
    out_sh = (sh[0], sh[1], NamedSharding(mesh, P()))

    t0 = time.perf_counter()
    with mesh:
        comp = jax.jit(step, in_shardings=sh, out_shardings=out_sh,
                       donate_argnums=(0, 1)).lower(*specs).compile()
    dt = time.perf_counter() - t0
    mem = comp.memory_analysis()
    hlo = comp.as_text()
    colls = H.parse_collectives(hlo, mesh.size)
    csum = H.collective_summary(colls)
    flops = H.parse_dot_flops(hlo)
    hbm = H.estimate_hbm_bytes(hlo)
    terms = H.roofline_terms(flops, hbm, csum["total_wire_bytes"])
    rec = {
        "cell": f"graph-pagerank-V{n_vertices}-J{n_jobs}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(dt, 1),
        "q": q, "num_blocks": bn, "vb": vb,
        "arg_gib_per_dev": round(mem.argument_size_in_bytes / 2**30, 2),
        "temp_gib_per_dev": round(mem.temp_size_in_bytes / 2**30, 2),
        "wire_gib_per_dev": round(csum["total_wire_bytes"] / 2**30, 3),
        "flops_per_dev": flops,
        "roofline": terms,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1 << 20)
    ap.add_argument("--jobs", type=int, default=64)
    ap.add_argument("--vb", type=int, default=512)
    ap.add_argument("--nbr-blocks", type=int, default=32)
    ap.add_argument("--out", default="experiments/graph_dryrun.json")
    args = ap.parse_args()
    records = []
    for mp in (False, True):
        rec = run(args.vertices, args.jobs, args.vb, args.nbr_blocks, mp)
        print(json.dumps(rec, indent=1))
        records.append(rec)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
