"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from dryrun.json.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun.json
prints markdown to stdout (the EXPERIMENTS.md sections are pasted from it).
"""

from __future__ import annotations

import json
import sys


def gib(x) -> str:
    return f"{x / 2**30:.2f}"


def fmt_s(x) -> str:
    if x >= 0.1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def dryrun_table(records) -> str:
    out = ["| arch | shape | mesh | policy | compile | arg GiB/dev "
           "(analytic) | peak GiB/dev (XLA-CPU) | fits | wire GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"skip | — | — | n/a | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"ERROR | — | — | — | — |")
            continue
        wire = r["collectives"]["total_wire_bytes"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{r['compile_s']:.0f}s | {gib(r['arg_bytes_analytic'])} | "
            f"{gib(r['peak_bytes_per_dev'])} | "
            f"{'Y' if r['fits_16gb'] else 'cpu-f32*'} | {gib(wire)} |")
    return "\n".join(out)


def recompute_terms(r) -> dict:
    """Terms from stored fields (memory model: 2x analytic args + temp)."""
    from repro.launch import hlo_analysis as H
    hbm = 2.0 * r["arg_bytes_analytic"] + r["temp_bytes_per_dev"]
    return H.roofline_terms(r["hlo_flops_per_dev"], hbm,
                            r["collectives"]["total_wire_bytes"])


def roofline_table(records) -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO | roofline-frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        t = recompute_terms(r)
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / bound if bound else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {r['model_flops_ratio']:.2f} | "
            f"{frac:.2f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"
    records = json.load(open(path))
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = len(records) - n_ok - n_skip
    print(f"## Dry-run ({n_ok} ok / {n_skip} skipped-documented / "
          f"{n_err} errors)\n")
    print(dryrun_table(records))
    print("\n## Roofline (single-pod 16x16; per-device terms)\n")
    print(roofline_table(records))


if __name__ == "__main__":
    main()
