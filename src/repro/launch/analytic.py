"""Analytic FLOP model for every block kind — exact to this codebase's
einsums.  Used for the MODEL_FLOPS/HLO_FLOPs ratio in §Roofline and as a
cross-check on the HLO dot parser (tests assert agreement on smoke configs).
"""

from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig


def _attn_kv_effective(cfg: ModelConfig, s: int) -> float:
    """Average kv length actually computed by the triangular chunked
    attention (chunk-granular causal skipping)."""
    qc = min(cfg.q_chunk, s)
    kc = min(cfg.kv_chunk, s)
    n_q = -(-s // qc)
    total_rows = 0.0
    for i in range(n_q):
        hi = min((i + 1) * qc, s)
        hi = -(-hi // kc) * kc
        total_rows += qc * min(hi, s + (kc - s % kc) % kc)
    return total_rows / (n_q * qc)


def block_flops(cfg: ModelConfig, kind: str, tokens: float, s_kv: float,
                *, decode: bool = False) -> float:
    """Forward FLOPs of one block over `tokens` tokens attending s_kv keys."""
    d, h_, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    if kind in ("attn", "swa"):
        fl = 2 * tokens * d * (h_ + 2 * kv) * hd      # qkv proj
        fl += 2 * 2 * tokens * h_ * hd * s_kv         # qk^T and pV
        fl += 2 * tokens * h_ * hd * d                # out proj
        if cfg.moe:
            fl += 2 * tokens * d * cfg.n_experts      # router
            disp = tokens * cfg.top_k                 # dispatched assignments
            fl += 3 * 2 * disp * d * f                # expert swiglu
        else:
            fl += 3 * 2 * tokens * d * f              # swiglu
        return fl
    if kind == "rglru":
        r = cfg.d_rnn_eff
        fl = 2 * tokens * d * r * 2                   # w_in, w_gate
        fl += 2 * tokens * r * r * 2                  # r/i gate matmuls
        fl += 2 * tokens * cfg.conv_width * r         # conv
        fl += 10 * tokens * r                         # scan elementwise
        fl += 2 * tokens * r * d                      # w_out
        if f:
            fl += 3 * 2 * tokens * d * f              # Griffin MLP block
        return fl
    if kind == "mlstm":
        di = int(cfg.proj_factor * d)
        dh = di // h_
        fl = 2 * tokens * d * 2 * di                  # up
        fl += 3 * 2 * tokens * di * di                # q/k/v
        fl += 2 * 2 * tokens * di * h_                # gates
        fl += 2 * tokens * cfg.conv_width * di        # conv
        fl += 10 * tokens * di * dh                   # recurrence (C,n,Cq)
        fl += 2 * tokens * di * d                     # down
        return fl
    if kind == "slstm":
        dh = d // h_
        fl = 4 * 2 * tokens * d * d                   # z/i/f/o input proj
        fl += 4 * 2 * tokens * h_ * dh * dh           # recurrent mixes
        fl += 12 * tokens * d                         # gate elementwise
        f_up = int(4 * d / 3)
        fl += 3 * 2 * tokens * d * f_up               # gated ffn
        return fl
    raise ValueError(kind)


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global FLOPs for the cell + MODEL_FLOPS (6*N*D convention)."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        tokens = float(b * s)
        s_kv = _attn_kv_effective(cfg, s)
        decode = False
    elif shape.kind == "prefill":
        s = shape.seq_len
        tokens = float(b * s)
        s_kv = _attn_kv_effective(cfg, s)
        decode = False
    else:  # decode: one token, cache of seq_len
        tokens = float(b)
        s_kv = float(min(shape.seq_len, cfg.window)
                     if cfg.block_pattern[0] == "swa" or "swa" in
                     cfg.block_pattern else shape.seq_len)
        decode = True

    pattern = cfg.block_pattern
    n_cyc, rem = cfg.pattern_cycles, cfg.pattern_remainder
    fwd = 0.0
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if kind == "swa":
            kv_len = min(s_kv, cfg.window) if not decode else \
                min(shape.seq_len, cfg.window)
        else:
            kv_len = s_kv
        fwd += block_flops(cfg, kind, tokens, kv_len, decode=decode)

    # head (+ loss) and embed
    v = cfg.vocab_size * max(cfg.n_codebooks, 1)
    if shape.kind == "train":
        fwd += 2 * tokens * cfg.d_model * v
    else:
        head_tokens = tokens if shape.kind == "decode" else float(b)
        fwd += 2 * head_tokens * cfg.d_model * v

    if shape.kind == "train":
        total = 3.0 * fwd                     # fwd + 2x bwd
        n_params = cfg.n_params()
        total += 10.0 * n_params              # optimizer update
        model_flops = 6.0 * cfg.n_active_params() * tokens
    else:
        total = fwd
        model_flops = 2.0 * cfg.n_active_params() * tokens
    return {"hlo_est_flops": total, "model_flops": model_flops,
            "fwd_flops": fwd, "tokens": tokens}
