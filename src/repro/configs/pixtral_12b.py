"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified]: mistral-nemo-like
text backbone; the Pixtral-ViT frontend is a STUB — input_specs() provides
precomputed patch embeddings concatenated as a 256-token prefix."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_base=1e6,
    patch_prefix=256,       # precomputed ViT patch embeddings (stub frontend)
    sub_quadratic=False,
)
