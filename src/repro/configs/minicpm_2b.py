"""MiniCPM-2B [arXiv:2404.06395; hf]: dense llama-like, MHA, tied embeddings,
trained with the WSD schedule (see repro.train.optimizer.wsd_schedule)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,          # GQA kv=36 == MHA
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    sub_quadratic=False,    # full attention: long_500k skipped (DESIGN.md)
)

TRAIN_SCHEDULE = "wsd"
