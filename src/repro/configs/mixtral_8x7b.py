"""Mixtral-8x7B [arXiv:2401.04088; hf]: MoE 8 experts top-2, GQA kv=8,
sliding-window attention (W=4096) — SWA makes long_500k decode windowed,
so this arch runs the long-context cell."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("swa",),
    window=4096,
    moe=True,
    n_experts=8,
    top_k=2,
    sub_quadratic=True,     # windowed cache: O(W) per token
)
