"""Architecture registry: one module per assigned architecture.

Each module exposes FULL (exact public config) and the registry builds a
reduced SMOKE variant for CPU tests.  `get(name)` / `get_smoke(name)`.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from repro.configs import (minicpm_2b, qwen3_32b, qwen2_5_14b, phi4_mini_3_8b,
                           mixtral_8x7b, qwen3_moe_235b_a22b,
                           recurrentgemma_9b, pixtral_12b, xlstm_350m,
                           musicgen_medium)

_MODULES = {
    "minicpm-2b": minicpm_2b,
    "qwen3-32b": qwen3_32b,
    "qwen2.5-14b": qwen2_5_14b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "mixtral-8x7b": mixtral_8x7b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "pixtral-12b": pixtral_12b,
    "xlstm-350m": xlstm_350m,
    "musicgen-medium": musicgen_medium,
}

ARCH_NAMES = list(_MODULES)


def get(name: str) -> ModelConfig:
    return _MODULES[name].FULL


def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny dims, same block pattern/features."""
    pat = cfg.block_pattern
    n_layers = len(pat) + min(cfg.pattern_remainder, len(pat))
    if n_layers == len(pat):
        n_layers = 2 * len(pat) if len(pat) == 1 else len(pat)
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv * min(cfg.q_per_kv, 2), kv)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=128,
        window=16 if "swa" in pat else cfg.window,
        n_experts=4 if cfg.moe else 0,
        top_k=2 if cfg.moe else 0,
        capacity_factor=8.0 if cfg.moe else cfg.capacity_factor,  # dropless

        d_rnn=64 if cfg.d_rnn else 0,
        patch_prefix=4 if cfg.patch_prefix else 0,
        q_chunk=16,
        kv_chunk=16,
    )


def get_smoke(name: str) -> ModelConfig:
    return make_smoke(get(name))
