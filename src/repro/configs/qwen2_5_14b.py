"""Qwen2.5-14B [hf:Qwen/Qwen2.5 family; hf]: dense, GQA kv=8, QKV bias."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_base=1e6,
    sub_quadratic=False,
)
