"""xLSTM-350M [arXiv:2405.04517; unverified]: xLSTM[7:1] — 7 mLSTM blocks per
sLSTM block (24 layers = 3 cycles of 8).  d_ff=0: FFN is internal to the
blocks (mLSTM pf=2 up-projection, sLSTM pf=4/3 gated FFN).  Attention-free:
runs long_500k with O(1) state."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    use_rope=False,
    proj_factor=2.0,
    sub_quadratic=True,
)
