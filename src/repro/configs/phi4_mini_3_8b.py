"""Phi-4-mini 3.8B [arXiv:2412.08905; hf]: dense, RoPE + SwiGLU + GQA kv=8,
tied embeddings (200k vocab)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    sub_quadratic=False,
)
