"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3 MoE family; hf]: 128 experts top-8,
per-expert d_ff=1536, GQA kv=4, qk-norm."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    rope_base=1e6,
    moe=True,
    n_experts=128,
    top_k=8,
    sub_quadratic=False,
)
