"""MusicGen-medium [arXiv:2306.05284; hf]: decoder-only over 4 EnCodec
codebook streams (delay pattern is a data-layout concern handled by the
stub frontend): summed codebook embeddings in, 4 parallel 2048-way heads out.
Positional encoding: RoPE stands in for MusicGen's sinusoidal embeddings
(recorded deviation, DESIGN.md)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,          # MHA
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    sub_quadratic=False,
)
