"""Qwen3-32B [hf:Qwen/Qwen3-8B family; hf]: dense, GQA kv=8, qk-norm."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_base=1e6,
    sub_quadratic=False,
)
