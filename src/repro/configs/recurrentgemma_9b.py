"""RecurrentGemma-9B [arXiv:2402.19427 Griffin; unverified]: RG-LRU recurrent
blocks + local attention, 2:1 pattern (recurrent, recurrent, local-attn),
MQA kv=1, window 2048.  Sub-quadratic: runs long_500k."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,                    # 12 full cycles + (rglru, rglru) remainder
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "swa"),
    window=2048,
    d_rnn=4096,
    act="gelu",
    sub_quadratic=True,
)
