"""Sharding rules: logical axes -> mesh axes, plus state-tree shardings.

Two policies over the production ("data", "model") mesh (launch/mesh.py):

  "dp"  - pure FSDP-DP: the batch (and fsdp parameter shards) tile EVERY
          chip; no tensor parallelism.
  "tp"  - TP/EP/SP: batch over "data", tensor/expert/sequence parallelism
          over "model".

Rules degrade gracefully: logical axes whose mesh axes are absent from the
mesh (e.g. a ("pod",)-only pipeline mesh) or whose sizes do not divide the
tensor dim simply drop to replicated — the same model code lowers on any
mesh, including the 1-device test mesh.

`reshard` is the elastic helper: device_put a whole state tree onto new
shardings (possibly a different mesh — elastic rescale after a restart).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis candidates, per policy
_POLICIES: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "dp": {
        "dp": ("pod", "data", "model"),
        "fsdp": ("pod", "data", "model"),
        "tp": (),
        "sp": (),
        "ep": (),
    },
    "tp": {
        "dp": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "tp": ("model",),
        "sp": ("model",),
        "ep": ("model",),
    },
}


class ShardingRules:
    """Maps logical axis names to mesh axes for one (mesh, policy) pair."""

    def __init__(self, mesh: Mesh, policy: str = "dp"):
        if policy not in _POLICIES:
            raise ValueError(f"unknown sharding policy {policy!r}")
        self.mesh = mesh
        self.policy = policy
        table = _POLICIES[policy]
        self.table: Dict[str, Tuple[str, ...]] = {
            k: tuple(a for a in v if a in mesh.axis_names)
            for k, v in table.items()}

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return self.table.get(logical, ())

    def axis_size(self, logical: str) -> int:
        n = 1
        for a in self.mesh_axes(logical):
            n *= self.mesh.shape[a]
        return n

    def spec(self, shape: Sequence[int],
             logical_axes: Sequence[Optional[str]]) -> P:
        """PartitionSpec for `shape`, dropping any mesh axis already used on
        an earlier dim or whose size does not divide the dim."""
        used: set = set()
        parts: List[Any] = []
        for dim, lax_name in zip(shape, logical_axes):
            chosen: List[str] = []
            n = 1
            for a in self.mesh_axes(lax_name):
                if a in used:
                    continue
                sz = self.mesh.shape[a]
                if dim % (n * sz) == 0:
                    chosen.append(a)
                    n *= sz
            used.update(chosen)
            if not chosen:
                parts.append(None)
            elif len(chosen) == 1:
                parts.append(chosen[0])
            else:
                parts.append(tuple(chosen))
        return P(*parts)

    def named(self, shape: Sequence[int],
              logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, logical_axes))


def _leaf_sharding(rules: ShardingRules, shape: Sequence[int],
                   logical: str, prefer_last: bool) -> NamedSharding:
    """Shard the largest divisible dim of `shape` over `logical`; ties go to
    the last dim for serve/TP (output features resident per device) and to
    the first for train/FSDP."""
    if not shape or rules.axis_size(logical) <= 1:
        return rules.named(shape, [None] * len(shape))
    group = rules.axis_size(logical)
    order = range(len(shape) - 1, -1, -1) if prefer_last else range(len(shape))
    best = None
    for i in order:
        if shape[i] % group == 0 and (best is None or shape[i] > shape[best]):
            best = i
    axes: List[Optional[str]] = [None] * len(shape)
    if best is not None:
        axes[best] = logical
    return rules.named(shape, axes)


def param_shardings(rules: ShardingRules, params: Any, *,
                    serve: bool = False) -> Any:
    """Tree of NamedShardings for a parameter tree.

    Train: FSDP — each tensor sharded on its largest fsdp-divisible dim.
    Serve: weights stay resident, sharded over the tp axis (prefer the output
    feature dim) so matmul shards line up with activation TP."""
    logical = "tp" if serve else "fsdp"
    return jax.tree.map(
        lambda p: _leaf_sharding(rules, p.shape, logical, prefer_last=serve),
        params)


def batch_shardings(rules: ShardingRules, batch: Any) -> Any:
    """Batch trees shard dim 0 over dp, everything else replicated."""
    return jax.tree.map(
        lambda b: rules.named(
            b.shape, (["dp"] + [None] * (len(b.shape) - 1)) if b.shape else []),
        batch)


def cache_shardings(rules: ShardingRules, cache: Any) -> Any:
    """KV/recurrent caches shard their batch dim.  Stacked (scanned) caches
    carry a leading layer-cycle axis, so the batch dim is dim 0 or dim 1
    depending on the leaf; shard the first dp-divisible of the two (both are
    safe: each is uniform across devices, and spec() drops non-divisible
    axes)."""
    def one(c):
        if not c.shape:
            return rules.named((), [])
        axes: List[Optional[str]] = [None] * len(c.shape)
        # prefer the first dp-divisible dim among the leading two (layer
        # stack axis for scanned caches, batch otherwise)
        group = rules.axis_size("dp")
        for i in range(min(2, len(c.shape))):
            if group > 1 and c.shape[i] % group == 0:
                axes[i] = "dp"
                break
        return rules.named(c.shape, axes)
    return jax.tree.map(one, cache)


def reshard(tree: Any, shardings: Any) -> Any:
    """Elastic re-shard: move a live state tree onto (possibly different-mesh)
    shardings.  Used after an elastic restart when the device set changed."""
    return jax.device_put(tree, shardings)


def replicated(mesh: Mesh, tree: Any) -> Any:
    """Tree of fully-replicated NamedShardings on `mesh`."""
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * len(x.shape)))), tree)
