"""repro.dist: the distributed substrate.

  act          - logical-axis activation sharding (constrain / axis_size /
                 is_serve under an activation_sharding context)
  sharding     - ShardingRules (logical -> mesh axes), state-tree shardings,
                 elastic reshard helpers
  fault        - RestartManager (checkpoint-resume), StragglerWatchdog
  compression  - int8 gradient all-reduce with error feedback
  pipeline     - GPipe-style microbatched pipeline-parallel loss
  graph        - job-axis sharding for concurrent graph runs (multi-device
                 CAJS: tiles replicated, job state sharded)

Submodules are imported lazily by call sites (`from repro.dist.act import
constrain`) so importing `repro.dist` itself never touches jax device state.
"""

__all__ = ["act", "sharding", "fault", "compression", "pipeline", "graph"]
