"""Int8 gradient compression with error feedback (1-bit-Adam family).

The data-parallel gradient all-reduce is the wire-dominant collective of
dense training.  `make_compressed_grad_fn` builds a shard_map step that:

  1. computes local grads on each device's batch shard,
  2. adds the carried error-feedback residual,
  3. quantizes to int8 against a group-shared scale (pmax of local absmax,
     so every device reduces in the same code space),
  4. all-reduces the quantized values (8/32 of the fp32 wire bytes),
  5. dequantizes, and carries the new residual (local tensor minus its
     quantized image) into the next step.

Error feedback makes the quantization bias telescope away over steps: the
residual re-enters the pre-quantization sum, so the long-run gradient
estimate is unbiased even at 8 bits.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_ef(t: jax.Array, bits: int = 8, axis=None):
    """Quantize `t` to a signed (2^bits - 1)-level grid, returning
    (dequantized, residual) with t == dequantized + residual exactly.

    `axis` selects the scale granularity: None shares one absmax scale
    across the whole tensor; an int/tuple computes the scale per slice
    along the REMAINING axes (e.g. axis=-1 gives every leading-index row
    its own scale — what the 2D-mesh frontier exchange uses per
    (job, slot) delta row, so one hot row cannot flatten the grid of a
    near-converged one).  Zero rows quantize to exact zeros (the 1e-30
    floor only guards the division), so sparse frontiers stay sparse.
    Feeding the residual back into the next quantization makes the bias
    telescope away — see `make_compressed_grad_fn`, whose local math this
    reuses without the collectives."""
    t = t.astype(jnp.float32)
    levels = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(t), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-30) / levels
    q = jnp.clip(jnp.round(t / scale), -levels, levels)
    deq = q * scale
    return deq, t - deq


def make_compressed_grad_fn(mesh: Mesh, loss_fn: Callable[..., jax.Array], *,
                            axis_name: str = None, bits: int = 8):
    """Returns fn(params, err, batch) -> (loss, grads, new_err).

    params/err are replicated trees (err: the error-feedback state, zeros at
    step 0, same structure as params); batch is sharded on dim 0 over
    `axis_name` (defaults to the mesh's first axis).  grads approximate the
    exact data-parallel mean gradient to within one quantization step.
    """
    axis = axis_name or mesh.axis_names[0]
    levels = float(2 ** (bits - 1) - 1)

    def _local(params, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        e_leaves = jax.tree_util.tree_leaves(err)
        out_g, out_e = [], []
        for g, e in zip(g_leaves, e_leaves):
            t = g.astype(jnp.float32) + e
            # shared scale: every device quantizes into the same int8 grid,
            # so the reduction of quantized values is well defined
            amax = jax.lax.pmax(jnp.max(jnp.abs(t)), axis)
            scale = jnp.maximum(amax, 1e-30) / levels
            q = jnp.clip(jnp.round(t / scale), -levels, levels)
            deq = q * scale
            out_g.append(jax.lax.pmean(deq, axis))
            # residual kept replicated (pmean) so the state tree stays
            # replicated under SPMD; exact on 1 device, and the mean
            # residual still telescopes in expectation across devices
            out_e.append(jax.lax.pmean(t - deq, axis))
        return (loss,
                jax.tree_util.tree_unflatten(treedef, out_g),
                jax.tree_util.tree_unflatten(treedef, out_e))

    return shard_map(_local, mesh=mesh,
                     in_specs=(P(), P(), P(axis)),
                     out_specs=(P(), P(), P()),
                     check_rep=False)
