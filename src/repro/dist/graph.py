"""Job-axis sharding for concurrent graph runs — multi-device CAJS.

The paper's premise is J concurrent jobs sharing one graph.  On a multi-
device mesh the natural SPMD extension keeps the locality story intact:

  * adjacency tiles / neighbour ids are REPLICATED — every device stages a
    selected block once into its local memory and serves all jobs resident
    on that device (CAJS per device, NXgraph-style locality-first staging);
  * the stacked job state (values/deltas [J, B_N, Vb], push_scale [J]) is
    SHARDED over a "jobs" mesh axis — each device advances J/D jobs.

Because every per-job computation in the engine is a vmap over the job axis,
partitioning that axis changes the device assignment but not a single
arithmetic op per job: the sharded run converges to the SAME fixpoint,
bit-for-bit, as the single-device run (asserted by tests/test_dist_graph.py).

Jobs that do not divide the axis fall back to replication for the remainder-
free guarantee (documented, not silently wrong).

Composition with the device-resident scheduler (core.policy,
backend="device"): the compiled superstep takes each group's
values/deltas/push_scale and the replicated tiles as ARGUMENTS, so the
placement below flows straight into the jitted scan/while_loop — jax
re-specializes the cached compilation on the new shardings, the per-job DO
sampling and pushes partition along the job axis, and the only cross-device
traffic per superstep is the global-queue scatter-add and the scalar
convergence all-reduce.  With steps_per_sync=K even those stay on device
for K supersteps per host round-trip.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

JOB_AXIS = "jobs"


def make_job_mesh(n_devices: Optional[int] = None,
                  axis_name: str = JOB_AXIS) -> Mesh:
    """1-D mesh over the first n_devices devices (default: all)."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"asked for {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]).reshape(n), (axis_name,))


def job_sharding(mesh: Mesh, axis_name: Optional[str] = None,
                 ndim: int = 3) -> NamedSharding:
    """NamedSharding for a [J, ...] stacked job tensor."""
    axis = axis_name or mesh.axis_names[0]
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def _replicated(mesh: Mesh, x) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(*([None] * x.ndim))))


def shard_job_state(mesh: Mesh, values, deltas, push_scale, graph,
                    axis_name: Optional[str] = None, view_key=None):
    """Place stacked job state on `mesh`: values/deltas/push_scale sharded
    over the job axis, the shared graph replicated (mutated in place — it is
    the shared view by design).  Used by GraphSession and shard_run alike;
    a session's padded [J_cap, ...] axis shards exactly like a fixed [J, ...]
    one because free slots are inert."""
    axis = axis_name or mesh.axis_names[0]
    n_shard = mesh.shape[axis]
    j = values.shape[0]
    if j % n_shard == 0:
        jobs3 = job_sharding(mesh, axis, ndim=3)
        jobs1 = job_sharding(mesh, axis, ndim=1)
    else:  # remainder jobs: replicate rather than pad (identical math)
        if n_shard > 1:
            from repro.dist.mesh2d import warn_layout_once
            warn_layout_once(view_key if view_key is not None else ("run",),
                             axis, n_shard, j, "jobs-replicated")
        jobs3 = NamedSharding(mesh, P(None, None, None))
        jobs1 = NamedSharding(mesh, P(None))
    graph.tiles = _replicated(mesh, graph.tiles)
    graph.nbr_ids = _replicated(mesh, graph.nbr_ids)
    graph.nbr_mask = _replicated(mesh, graph.nbr_mask)
    graph.vertex_mask = _replicated(mesh, graph.vertex_mask)
    return (jax.device_put(values, jobs3),
            jax.device_put(deltas, jobs3),
            jax.device_put(push_scale, jobs1))


def shard_session(mesh: Mesh, session, axis_name: Optional[str] = None,
                  axes=None, *, compress_halo: bool = False, bits: int = 8):
    """Place a (possibly heterogeneous) GraphSession on `mesh`: EVERY view
    group's job axis is sharded independently (each view keeps its own
    padded [J_view_cap, B_N, Vb] state) and every view's tiles are
    replicated, so each device stages a selected block once per view and
    serves all jobs resident on it.  Groups whose job axis does not divide
    the mesh fall back to replication (identical math), per group — a
    divisible plus-times group shards even when the min-plus group cannot.

    `axes=("jobs", "blocks")` (or any mesh with >= 2 named axes) selects
    the 2D placement instead: job state shards over BOTH axes and each
    block shard owns its `BlockPairs` slice + the destination rows it
    updates, exchanging only frontier deltas per superstep — see
    repro.dist.mesh2d (`compress_halo`/`bits` apply only there).

    The delta-COO overlay of an evolving view (repro.stream) is SHARED
    graph data exactly like the tiles, so it replicates with them: each
    device stages a block's overlay row alongside its tile for its local
    jobs.  Job state stays sharded across update batches — apply_updates
    touches values/deltas with .at scatters, which preserve placement."""
    import dataclasses as _dc
    if axes is not None or len(mesh.axis_names) >= 2:
        from repro.dist.mesh2d import shard_session_2d
        ax = tuple(axes) if axes is not None else tuple(mesh.axis_names[:2])
        return shard_session_2d(mesh, session, axes=ax,
                                compress_halo=compress_halo, bits=bits)
    from repro.dist.mesh2d import unshard_session as _unshard2d
    _unshard2d(session)   # leaving a 2D mesh for a 1D one
    for grp in session.view_groups():
        grp.values, grp.deltas, grp.push_scale = shard_job_state(
            mesh, grp.values, grp.deltas, grp.push_scale, grp.graph,
            axis_name, view_key=grp.key)
        if grp.overlay is not None:
            grp.overlay = _dc.replace(
                grp.overlay,
                src_u=_replicated(mesh, grp.overlay.src_u),
                dst=_replicated(mesh, grp.overlay.dst),
                w=_replicated(mesh, grp.overlay.w),
                mask=_replicated(mesh, grp.overlay.mask))
        # the destination-sorted block-pair view is shared adjacency data
        # exactly like the tiles: build it now (from the just-replicated
        # tiles) and replicate every leaf, so the fused megakernel sweep
        # stages each pair once per device for its local jobs.
        # dense_op is DROPPED under a mesh: the engine never pushes
        # through it (a [J, N] @ [N, N] matmul would let XLA pick a
        # J-dependent contraction blocking, breaking the bit-for-bit
        # sharding invariance this module guarantees — the pair einsum /
        # scatter reduces per (job, pair) independently instead), so
        # replicating an [N, N] dense operator would waste HBM.
        bp = session._pair_data(grp)
        grp.pairs = _dc.replace(
            bp,
            src=_replicated(mesh, bp.src), dst=_replicated(mesh, bp.dst),
            slot=_replicated(mesh, bp.slot),
            first=_replicated(mesh, bp.first),
            last=_replicated(mesh, bp.last),
            src_nnz=_replicated(mesh, bp.src_nnz),
            dst_touched=_replicated(mesh, bp.dst_touched),
            tiles=_replicated(mesh, bp.tiles),
            dense_op=None)
    return session


def unshard_session(session):
    """Gather a 2D-placed session back to single-device placement (no-op
    for 1D job-axis placements, which never commit state off-mesh)."""
    from repro.dist.mesh2d import unshard_session as _unshard2d
    return _unshard2d(session)


def shard_run(run, mesh: Mesh, axis_name: Optional[str] = None):
    """Place a ConcurrentRun on `mesh`: job state sharded over the job axis,
    graph replicated.  Returns a new ConcurrentRun."""
    values, deltas, push_scale = shard_job_state(
        mesh, run.values, run.deltas, run.push_scale, run.graph, axis_name)
    return dataclasses.replace(
        run, values=values, deltas=deltas, push_scale=push_scale)
