"""Activation sharding via *logical* axis names.

Model code annotates intermediate tensors with logical axes ("dp", "sp",
"tp", "fsdp") through `constrain`; a surrounding `activation_sharding(rules)`
context resolves them to mesh axes per the active ShardingRules policy and
emits `with_sharding_constraint`.  Outside any context every annotation is an
identity, so the same model code runs unsharded on one device (smoke tests)
and sharded on a pod without modification.

The context is thread-local (the serve engine runs prefill/decode cells from
worker threads) and re-entrant (nested cells keep their own rules).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def _current() -> Optional[Tuple[object, bool]]:
    st = _stack()
    return st[-1] if st else None


@contextlib.contextmanager
def activation_sharding(rules, serve: bool = False):
    """Activate `rules` (a repro.dist.sharding.ShardingRules) for constrain /
    axis_size / is_serve within the dynamic extent."""
    _stack().append((rules, serve))
    try:
        yield rules
    finally:
        _stack().pop()


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Constrain `x` to the sharding implied by per-dim logical axis names
    (None = replicated dim).  Identity outside an activation_sharding
    context or when no named axis resolves to a real mesh axis."""
    cur = _current()
    if cur is None:
        return x
    rules = cur[0]
    sharding = rules.named(x.shape, list(logical_axes))
    if all(p is None for p in sharding.spec):
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def axis_size(logical_axis: str) -> int:
    """Total device count behind a logical axis under the active rules
    (1 outside any context: the unsharded code path)."""
    cur = _current()
    if cur is None:
        return 1
    return cur[0].axis_size(logical_axis)


def is_serve() -> bool:
    """True when the active activation_sharding context is a serve cell."""
    cur = _current()
    return bool(cur is not None and cur[1])
