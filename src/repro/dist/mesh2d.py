"""2D (jobs x blocks) mesh: shard the graph, not just the jobs.

repro.dist.graph replicates every view's adjacency on every device, so
the maximum graph is one device's memory — the opposite of the
production-scale north star.  This module adds the second mesh axis: the
BLOCK-ROW axis.  A (Dj x S) mesh composes the existing job-axis sharding
with a partition of the destination-sorted `BlockPairs` list into S
contiguous dst-ranges (NXgraph-style sub-shards):

  * block-shard s owns block rows [s*B_loc, (s+1)*B_loc) of every job's
    values/deltas AND the pair slice whose destinations fall there —
    pairs are dst-sorted, so the slice is contiguous and its first/last
    run flags stay valid (a dst run never spans shards);
  * adjacency TILES are therefore sharded too: each device holds ~P/S
    pair tiles instead of P, which is what lets a graph larger than one
    device's memory run at all (`benchmarks/run.py fig_graphscale`);
  * at each superstep the shards exchange only the FRONTIER — the
    consumed deltas of the <=q selected blocks, [J, q, Vb] — via a
    lax.psum (plus-times) / lax.pmin (min-plus) over the blocks axis
    inside the jitted superstep, so `steps_per_sync=inf` stays one host
    sync.  Each global block is owned by exactly one shard (non-owners
    contribute the semiring identity), so the collective is exact.
    `RunMetrics.halo_bytes` accounts this payload: occupied selection
    slots x Vb x itemsize x live jobs — proportional to frontier deltas,
    never to whole tiles.

Scheduling stays a single global two-level decision: per-(job, shard)
DO queues sample each shard's LOCAL blocks, are scatter-added into the
global [B_N] cumulative priority (psum over both axes — B_N floats of
queue metadata, not graph data), and `synthesize_topq` then computes the
same global queue on every device.  Fixpoints are bit-identical to the
single-device run for min-plus (min is exact and order-independent, and
d(u)+w is evaluated identically on whichever shard owns the
destination) and tolerance-tight for plus-times.

The frontier exchange can optionally be int8-compressed with error
feedback (`compress_halo=True`, plus-times shared-selection policies
only): the owner quantizes its rows against a per-(job, slot) scale,
non-owners contribute exact zeros, and the residual is carried on the
owned block rows and drained the next time the block is selected —
the same telescoping-bias construction as `dist.compression`.

Groups whose job axis does not divide the jobs axis, or whose B_N does
not divide the blocks axis, fall back to replication along that axis
(identical math, one-time `MeshLayoutWarning` naming the chosen layout).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.algorithms.base import PLUS_TIMES
from repro.core import priority as prio
from repro.core.do_select import do_select_device
from repro.core.global_q import accumulate_priority, synthesize_topq
from repro.core.push import _block_mask
from repro.dist.compression import quantize_ef
from repro.obs.telemetry import device_buffers, device_write

JOBS_AXIS, BLOCKS_AXIS = "jobs", "blocks"

__all__ = [
    "Mesh2DSpec", "GroupLayout", "MeshLayoutWarning", "PairShards",
    "make_mesh2d", "partition_block_pairs", "shard_session_2d",
    "unshard_session", "build_device_step_2d", "run_device_2d",
    "shared_push_fn_2d", "indep_push_fn_2d", "reset_layout_warnings",
]


class MeshLayoutWarning(UserWarning):
    """A view group could not shard along a requested mesh axis and fell
    back to replication there (identical math, more memory/compute)."""


_LAYOUT_WARNED: set = set()


def reset_layout_warnings() -> None:
    """Forget which fallback layouts have been warned about (tests)."""
    _LAYOUT_WARNED.clear()


def warn_layout_once(view_key, axis_name: str, n_shard: int, size: int,
                     chosen: str) -> None:
    """One-time MeshLayoutWarning naming the layout actually chosen."""
    tag = (tuple(view_key), axis_name, n_shard, size, chosen)
    if tag in _LAYOUT_WARNED:
        return
    _LAYOUT_WARNED.add(tag)
    warnings.warn(
        f"view {view_key}: size {size} does not divide mesh axis "
        f"'{axis_name}' ({n_shard} shards) — falling back to layout "
        f"'{chosen}' (replicated along '{axis_name}'; identical math)",
        MeshLayoutWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """Per-view-group placement decision on a 2D mesh."""

    jobs_sharded: bool
    blocks_sharded: bool


@dataclasses.dataclass
class Mesh2DSpec:
    """A (jobs x blocks) mesh placement for a GraphSession.

    Held on the session as `sess._mesh2d`; its signature() feeds every
    jit-cache key so entering/leaving/re-entering a mesh re-uses — never
    grows — the one-entry-per-key compilation pins."""

    mesh: Mesh
    jobs_axis: str = JOBS_AXIS
    blocks_axis: str = BLOCKS_AXIS
    compress_halo: bool = False
    bits: int = 8

    @property
    def jobs_shards(self) -> int:
        return int(self.mesh.shape[self.jobs_axis])

    @property
    def block_shards(self) -> int:
        return int(self.mesh.shape[self.blocks_axis])

    def signature(self) -> tuple:
        return ("mesh2d", self.jobs_shards, self.block_shards,
                self.jobs_axis, self.blocks_axis, bool(self.compress_halo),
                int(self.bits))

    def layout(self, grp, warn: bool = False) -> GroupLayout:
        """Shard along an axis iff the group's extent divides it."""
        js = grp.capacity % self.jobs_shards == 0
        bs = grp.graph.num_blocks % self.block_shards == 0
        if warn and not js and self.jobs_shards > 1:
            warn_layout_once(grp.key, self.jobs_axis, self.jobs_shards,
                             grp.capacity, "jobs-replicated")
        if warn and not bs and self.block_shards > 1:
            warn_layout_once(grp.key, self.blocks_axis, self.block_shards,
                             grp.graph.num_blocks, "blocks-replicated")
        return GroupLayout(jobs_sharded=js, blocks_sharded=bs)

    def state_sharding(self, lay: GroupLayout) -> NamedSharding:
        ja = self.jobs_axis if lay.jobs_sharded else None
        ba = self.blocks_axis if lay.blocks_sharded else None
        return NamedSharding(self.mesh, P(ja, ba, None))

    def state_spec(self, lay: GroupLayout) -> P:
        return P(self.jobs_axis if lay.jobs_sharded else None,
                 self.blocks_axis if lay.blocks_sharded else None, None)

    def jobs_spec(self, lay: GroupLayout) -> P:
        return P(self.jobs_axis if lay.jobs_sharded else None)


def make_mesh2d(jobs: int = 1, blocks: int = 1, *,
                jobs_axis: str = JOBS_AXIS,
                blocks_axis: str = BLOCKS_AXIS) -> Mesh:
    """(jobs x blocks) mesh over the first jobs*blocks devices."""
    devs = jax.devices()
    n = jobs * blocks
    if not 1 <= n <= len(devs):
        raise ValueError(f"asked for {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]).reshape(jobs, blocks),
                (jobs_axis, blocks_axis))


# ---------------------------------------------------------------------------
# PairShards: the dst-partitioned BlockPairs view
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PairShards:
    """`BlockPairs` partitioned into S contiguous dst-ranges.

    Pairs are destination-sorted, so shard s = dst // B_loc owns a
    contiguous slice; slicing preserves the first/last run flags (a dst
    run never spans shards).  Shards are padded to one common pair count
    with inert pairs: src 0, dst_local clamped to the shard's last real
    destination (pallas-safe), first/last 0, an all-`fill` tile — an
    exact no-op in both semirings.

      src        [S, Pm] int32  GLOBAL source block of each pair
      dst_local  [S, Pm] int32  destination block MINUS the shard offset
      first/last [S, Pm] int32  run flags, valid per shard
      tiles      [S, Pm, Vb, Vb] f32  the shard's pair tiles (the memory
                 that actually scales down 1/S — the capacity win)
      src_nnz    [B_N] int32  GLOBAL per-source real-pair counts (the
                 tile_pair_loads accounting is shard-agnostic)
      dst_touched_local [S, B_loc] bool  per-shard touched destinations
    """

    num_shards: int
    pair_cap: int
    block_size: int
    num_blocks: int
    blocks_per_shard: int
    fill: float
    src: jnp.ndarray
    dst_local: jnp.ndarray
    first: jnp.ndarray
    last: jnp.ndarray
    tiles: jnp.ndarray
    src_nnz: jnp.ndarray
    dst_touched_local: jnp.ndarray

    def tree_flatten(self):
        leaves = (self.src, self.dst_local, self.first, self.last,
                  self.tiles, self.src_nnz, self.dst_touched_local)
        aux = (self.num_shards, self.pair_cap, self.block_size,
               self.num_blocks, self.blocks_per_shard, self.fill)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux, *leaves)


jax.tree_util.register_pytree_node(
    PairShards, PairShards.tree_flatten, PairShards.tree_unflatten)


def partition_block_pairs(bp, n_shards: int, fill: float) -> PairShards:
    """Split a dst-sorted `BlockPairs` into `n_shards` contiguous
    dst-range shards (requires num_blocks % n_shards == 0)."""
    bn, vb = bp.num_blocks, bp.block_size
    if bn % n_shards:
        raise ValueError(
            f"B_N={bn} does not divide into {n_shards} block shards")
    b_loc = bn // n_shards
    src, dst, first, last, tiles, touched = map(
        np.asarray, jax.device_get((bp.src, bp.dst, bp.first, bp.last,
                                    bp.tiles, bp.dst_touched)))
    bounds = np.searchsorted(dst, np.arange(n_shards + 1) * b_loc,
                             side="left")
    pm = max(1, int(np.max(np.diff(bounds))))
    s_src = np.zeros((n_shards, pm), np.int32)
    s_dst = np.zeros((n_shards, pm), np.int32)
    s_first = np.zeros((n_shards, pm), np.int32)
    s_last = np.zeros((n_shards, pm), np.int32)
    s_tiles = np.full((n_shards, pm, vb, vb), fill, np.float32)
    s_touch = np.zeros((n_shards, b_loc), bool)
    for s in range(n_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        k = hi - lo
        if k:
            s_src[s, :k] = src[lo:hi]
            s_dst[s, :k] = dst[lo:hi] - s * b_loc
            s_dst[s, k:] = s_dst[s, k - 1]      # inert pads: clamp
            s_first[s, :k] = first[lo:hi]
            s_last[s, :k] = last[lo:hi]
            s_tiles[s, :k] = tiles[lo:hi]
        s_touch[s] = touched[s * b_loc:(s + 1) * b_loc]
    return PairShards(
        num_shards=n_shards, pair_cap=pm, block_size=vb, num_blocks=bn,
        blocks_per_shard=b_loc, fill=float(fill),
        src=jnp.asarray(s_src), dst_local=jnp.asarray(s_dst),
        first=jnp.asarray(s_first), last=jnp.asarray(s_last),
        tiles=jnp.asarray(s_tiles), src_nnz=bp.src_nnz,
        dst_touched_local=jnp.asarray(s_touch))


def place_pair_shards(spec: Mesh2DSpec, ps: PairShards,
                      blocks_sharded: bool) -> PairShards:
    """device_put each leaf: pair slices along the blocks axis (or
    replicated for a blocks-replicated group), src_nnz replicated."""
    ba = spec.blocks_axis if blocks_sharded else None

    def put(x, spec_):
        return jax.device_put(x, NamedSharding(spec.mesh, spec_))

    return dataclasses.replace(
        ps,
        src=put(ps.src, P(ba)), dst_local=put(ps.dst_local, P(ba)),
        first=put(ps.first, P(ba)), last=put(ps.last, P(ba)),
        tiles=put(ps.tiles, P(ba)), src_nnz=put(ps.src_nnz, P()),
        dst_touched_local=put(ps.dst_touched_local, P(ba)))


def pair_shards_spec(spec: Mesh2DSpec, blocks_sharded: bool) -> PairShards:
    """shard_map in/out spec pytree shaped like a PairShards."""
    ba = P(spec.blocks_axis) if blocks_sharded else P()
    return PairShards(
        num_shards=0, pair_cap=0, block_size=0, num_blocks=0,
        blocks_per_shard=0, fill=0.0,
        src=ba, dst_local=ba, first=ba, last=ba, tiles=ba,
        src_nnz=P(), dst_touched_local=ba)


# ---------------------------------------------------------------------------
# shard-local primitives (called inside shard_map)
# ---------------------------------------------------------------------------


def _sum_unique(x, lay: GroupLayout, ja: str, ba: str):
    """psum over both axes counting each logical contribution ONCE: a
    replicated axis gates all but index 0 before summing (the psum then
    re-broadcasts, so the result is replicated and uniform — safe to
    branch a while_loop on)."""
    g = x if lay.jobs_sharded else x * (
        jax.lax.axis_index(ja) == 0).astype(x.dtype)
    g = jax.lax.psum(g, ja)
    g2 = g if lay.blocks_sharded else g * (
        jax.lax.axis_index(ba) == 0).astype(g.dtype)
    return jax.lax.psum(g2, ba)


def _psum_blocks(x, lay: GroupLayout, ba: str):
    """Sum per-job quantities across block shards (gated when the group
    replicates blocks, so each block's contribution counts once)."""
    g = x if lay.blocks_sharded else x * (
        jax.lax.axis_index(ba) == 0).astype(x.dtype)
    return jax.lax.psum(g, ba)


def _exchange_shared(semiring: str, deltas, sel, msk, boff, b_loc: int,
                     bn: int, ba: str, lay: GroupLayout, err,
                     compress: bool, bits: int):
    """Consume the selected blocks' local deltas and exchange the
    frontier: every shard contributes its OWNED rows of the [J, q, Vb]
    selection (semiring identity elsewhere) and a psum/pmin over the
    blocks axis hands every shard the full frontier.  Returns
    (raw, base, d_sel, err) — raw the consumed local rows, base the
    post-consume local deltas, d_sel the exchanged [J, q, Vb] frontier
    (plus-times: UNSCALED; min-plus: inf on invalid slots), err the
    updated error-feedback residual (compress_halo only)."""
    selb = _block_mask(sel, msk, bn)                       # [B_N] global
    consumed = jax.lax.dynamic_slice_in_dim(selb, boff, b_loc)[None, :, None]
    lidx = jnp.clip(sel - boff, 0, b_loc - 1)
    owned = ((sel >= boff) & (sel < boff + b_loc) & (msk > 0))
    if semiring == PLUS_TIMES:  # noqa: RPA001 (static python arg)
        raw = jnp.where(consumed, deltas, 0.0)
        t = raw[:, lidx, :]                                # [J, q, Vb]
        if compress:  # noqa: RPA001 (static python arg)
            t = t + err[:, lidx, :]
            deq, res = quantize_ef(t, bits=bits, axis=-1)
            # drain the residual of re-selected owned rows; pads/unowned
            # slots scatter out of range and are dropped
            scatter_idx = jnp.where(owned, lidx, b_loc)
            err = err.at[:, scatter_idx, :].set(
                jnp.where(owned[None, :, None], res, 0.0), mode="drop")
            t = deq
        contrib = jnp.where(owned[None, :, None], t, 0.0)
        if lay.blocks_sharded:
            d_sel = jax.lax.psum(contrib, ba)
        else:   # every shard already holds the full rows
            d_sel = contrib
        base = deltas - raw
        return raw, base, d_sel, err
    raw = jnp.where(consumed, deltas, jnp.inf)
    t = raw[:, lidx, :]
    contrib = jnp.where(owned[None, :, None], t, jnp.inf)
    d_sel = jax.lax.pmin(contrib, ba) if lay.blocks_sharded else contrib
    d_sel = jnp.where(msk[None, :, None] > 0, d_sel, jnp.inf)
    base = jnp.where(consumed, jnp.inf, deltas)
    return raw, base, d_sel, err


def _exchange_indep(semiring: str, deltas, sel, msk, boff, b_loc: int,
                    bn: int, ba: str, lay: GroupLayout):
    """Per-job-selection analogue of `_exchange_shared` (sel/msk
    [J, q']); no compression — error feedback is defined per owned block
    row, which per-job consumption would make job-coupled."""
    j = deltas.shape[0]
    selb = jnp.zeros((j, bn), jnp.bool_)
    selb = selb.at[jnp.arange(j)[:, None], sel].max(msk > 0)
    consumed = jax.lax.dynamic_slice_in_dim(
        selb, boff, b_loc, axis=1)[:, :, None]
    lidx = jnp.clip(sel - boff, 0, b_loc - 1)              # [J, q']
    owned = ((sel >= boff) & (sel < boff + b_loc) & (msk > 0))
    if semiring == PLUS_TIMES:  # noqa: RPA001 (static python arg)
        raw = jnp.where(consumed, deltas, 0.0)
        t = jnp.take_along_axis(raw, lidx[:, :, None], axis=1)
        contrib = jnp.where(owned[:, :, None], t, 0.0)
        d_sel = jax.lax.psum(contrib, ba) if lay.blocks_sharded else contrib
        return raw, deltas - raw, d_sel
    raw = jnp.where(consumed, deltas, jnp.inf)
    t = jnp.take_along_axis(raw, lidx[:, :, None], axis=1)
    contrib = jnp.where(owned[:, :, None], t, jnp.inf)
    d_sel = jax.lax.pmin(contrib, ba) if lay.blocks_sharded else contrib
    d_sel = jnp.where(msk[:, :, None] > 0, d_sel, jnp.inf)
    return raw, jnp.where(consumed, jnp.inf, deltas), d_sel


def _widen(semiring: str, d_sel, sel, bn: int, shared: bool):
    """Scatter the exchanged [J, q', Vb] frontier into a [J, B_N, Vb]
    operand indexed by GLOBAL source block (what the pair sweep and the
    megakernel consume).  Padded slots alias block 0 with the identity,
    so they cannot re-push it."""
    j, _, vb = d_sel.shape
    if semiring == PLUS_TIMES:  # noqa: RPA001 (static python arg)
        wide = jnp.zeros((j, bn, vb), jnp.float32)
        if shared:  # noqa: RPA001 (static python arg)
            return wide.at[:, sel, :].add(d_sel)
        return wide.at[jnp.arange(j)[:, None], sel, :].add(d_sel)
    wide = jnp.full((j, bn, vb), jnp.inf, jnp.float32)
    if shared:  # noqa: RPA001 (static python arg)
        return wide.at[:, sel, :].min(d_sel)
    return wide.at[jnp.arange(j)[:, None], sel, :].min(d_sel)


def _min_candidates(d_wide, src, tiles):
    """[J, P, Vb] min-plus candidates: min over source rows v of
    d_wide[:, src, v] + tiles[:, v, :], folded per row to bound the
    temporary at [J, P, Vb] (no [J, P, Vb, Vb] broadcast)."""
    d_pair = d_wide[:, src, :]                             # [J, P, Vb]
    vb = tiles.shape[-1]

    def body(v, acc):
        return jnp.minimum(acc, d_pair[:, :, v, None] + tiles[None, :, v, :])

    init = jnp.full(d_pair.shape, jnp.inf, jnp.float32)
    return jax.lax.fori_loop(0, vb, body, init)


def _overlay_plus_local(deltas, d_sel, ov, sel, boff, b_loc: int,
                        shared: bool):
    """Scatter the selected blocks' overlay contributions into the LOCAL
    deltas: only entries whose destination vertex falls in this shard's
    rows land (others drop), so overlay updates route to owning shards."""
    if ov is None or ov.capacity == 0:
        return deltas
    vb = deltas.shape[-1]

    def one(d_j, dsel_j, sel_j):
        q = sel_j.shape[0]
        src_u, dst, w, mask = (ov.src_u[sel_j], ov.dst[sel_j], ov.w[sel_j],
                               ov.mask[sel_j])
        contrib = dsel_j[jnp.arange(q)[:, None], src_u] * w * mask
        ldst = dst - boff * vb
        ok = (ldst >= 0) & (ldst < b_loc * vb) & (mask > 0)
        ldst = jnp.where(ok, ldst, b_loc * vb)
        flat = d_j.reshape(-1)
        flat = flat.at[ldst.reshape(-1)].add(
            jnp.where(ok, contrib, 0.0).reshape(-1), mode="drop")
        return flat.reshape(b_loc, vb)

    in_axes = (0, 0, None) if shared else (0, 0, 0)
    return jax.vmap(one, in_axes=in_axes)(deltas, d_sel, sel)


def _overlay_min_local(values, d_sel, ov, sel, boff, b_loc: int,
                       shared: bool):
    """Scatter-min the selected blocks' overlay relaxations into the
    LOCAL values (improvement bookkeeping happens once, in the caller)."""
    if ov is None or ov.capacity == 0:
        return values
    vb = values.shape[-1]

    def one(v_j, dsel_j, sel_j):
        q = sel_j.shape[0]
        src_u, dst, w, mask = (ov.src_u[sel_j], ov.dst[sel_j], ov.w[sel_j],
                               ov.mask[sel_j])
        cand = jnp.where(mask > 0,
                         dsel_j[jnp.arange(q)[:, None], src_u] + w,
                         jnp.inf)
        ldst = dst - boff * vb
        ok = (ldst >= 0) & (ldst < b_loc * vb)
        ldst = jnp.where(ok, ldst, b_loc * vb)
        flat = v_j.reshape(-1)
        flat = flat.at[ldst.reshape(-1)].min(
            jnp.where(ok, cand, jnp.inf).reshape(-1), mode="drop")
        return flat.reshape(b_loc, vb)

    in_axes = (0, 0, None) if shared else (0, 0, 0)
    return jax.vmap(one, in_axes=in_axes)(values, d_sel, sel)


def _apply_pairs_local(semiring: str, values, deltas_base, raw, d_wide,
                       d_sel, sel, ps_src, ps_dstl, ps_first, ps_last,
                       ps_tiles, ps_touched, scales, msk, overlay, boff,
                       b_loc: int, shared: bool, use_pallas: bool):
    """One shard's pair run: push the exchanged frontier through the
    LOCAL dst-sorted pair slice (+ the overlay ride-along), with the
    one-shot improvement bookkeeping that is provably equivalent to the
    sequential per-block scan at every step (min is order-independent
    and `deltas[v] = min(base, new value)` iff any candidate improved).

    use_pallas sweeps the slice with the fused megakernel (per-shard
    pair run: global-src operand, local-dst output); otherwise the jnp
    einsum/scatter emulation."""
    if semiring == PLUS_TIMES:  # noqa: RPA001 (static python arg)
        d_push = d_wide * scales[:, None, None]
        if use_pallas:  # noqa: RPA001 (static python arg)
            from repro.kernels.fused_superstep.kernel import (
                fused_superstep_call)
            from repro.kernels.common import resolve_interpret
            from repro.kernels.fused_superstep.ops import _pick_job_block
            out, _, _ = fused_superstep_call(
                ps_src, ps_dstl, ps_first, ps_last, d_push, deltas_base,
                ps_tiles, semiring=semiring,
                job_block=_pick_job_block(values.shape[0],
                                          values.shape[-1], semiring),
                interpret=resolve_interpret(None))
            out = jnp.where(ps_touched[None, :, None], out, deltas_base)
        else:
            contrib = jnp.einsum("jpv,pvw->jpw", d_push[:, ps_src, :],
                                 ps_tiles)
            out = deltas_base.at[:, ps_dstl, :].add(contrib)
        d_ov = (d_sel * scales[:, None, None]
                * (msk[None, :, None] if shared else msk[:, :, None]))
        out = _overlay_plus_local(out, d_ov, overlay, sel, boff, b_loc,
                                  shared)
        return values + raw, out
    # min-plus
    if use_pallas:  # noqa: RPA001 (static python arg)
        from repro.kernels.fused_superstep.kernel import fused_superstep_call
        from repro.kernels.common import resolve_interpret
        from repro.kernels.fused_superstep.ops import _pick_job_block
        vo, do, _, _ = fused_superstep_call(
            ps_src, ps_dstl, ps_first, ps_last, d_wide, deltas_base,
            ps_tiles, values=values, semiring=semiring,
            job_block=_pick_job_block(values.shape[0], values.shape[-1],
                                      semiring),
            interpret=resolve_interpret(None))
        v1 = jnp.where(ps_touched[None, :, None], vo, values)
        d1 = jnp.where(ps_touched[None, :, None], do, deltas_base)
        v2 = _overlay_min_local(v1, d_sel, overlay, sel, boff, b_loc, shared)
        improved = v2 < v1
        return v2, jnp.minimum(d1, jnp.where(improved, v2, jnp.inf))
    cand = _min_candidates(d_wide, ps_src, ps_tiles)
    v_old = values
    v1 = values.at[:, ps_dstl, :].min(cand)
    v2 = _overlay_min_local(v1, d_sel, overlay, sel, boff, b_loc, shared)
    improved = v2 < v_old
    return v2, jnp.minimum(deltas_base, jnp.where(improved, v2, jnp.inf))


# ---------------------------------------------------------------------------
# 2D device superstep: both scheduling levels + push + exchange, jitted
# ---------------------------------------------------------------------------


def _sum_jobs(x, lay: GroupLayout, ja: str):
    """Sum a per-jobs-shard quantity across the jobs axis, counting each
    job once (gated when the group replicates jobs)."""
    g = x if lay.jobs_sharded else x * (
        jax.lax.axis_index(ja) == 0).astype(x.dtype)
    return jax.lax.psum(g, ja)


def build_device_step_2d(policy, sess, spec: Mesh2DSpec):
    """Compile the session's superstep for `policy` on the 2D mesh.

    The same contract as `core.policy.build_device_step` — one jitted
    callable, finite steps_per_sync scans / inf while_loops — but the
    whole step body runs INSIDE a shard_map over (jobs x blocks): DO
    sampling per (job, block-shard) over local blocks, global-queue
    synthesis from the psum'd [B_N] cumulative priority, the frontier
    exchange, and each shard's pair run.  The carry grows two slots over
    the 1D layout: state[9] accumulates `halo_bytes` and state[10] is
    the per-group error-feedback residual (all-zero placeholders unless
    compress_halo applies to the group).  Cache via
    session._device_step_fn, whose key carries spec.signature()."""
    from repro.core.policy import AllBlocks, Independent, TwoLevel
    groups = sess.view_groups()
    n_groups = len(groups)
    algs = [g.alg for g in groups]
    lays = [spec.layout(g, warn=True) for g in groups]
    ja, ba = spec.jobs_axis, spec.blocks_axis
    dj, s_blk = spec.jobs_shards, spec.block_shards
    q = int(sess.q)
    alpha = float(sess.alpha)
    samples = int(sess.samples)
    bn = int(sess.scheduler.num_blocks)
    k_sync = policy.steps_per_sync
    needs_pairs = policy.needs_pairs
    tel_cfg = getattr(sess, "telemetry", None)
    tel_cap = int(tel_cfg.capacity) if tel_cfg is not None else 0
    use_pallas = bool(sess.use_pallas)

    if isinstance(policy, Independent):
        mode = "indep"
    elif isinstance(policy, AllBlocks):
        mode = "all"
    elif isinstance(policy, TwoLevel):
        mode = "two"
    else:
        raise NotImplementedError(
            f"policy {type(policy).__name__} has no 2D-mesh device path — "
            "run it on the host backend or a 1D jobs mesh")

    b_locs = [bn // s_blk if lay.blocks_sharded else bn for lay in lays]
    j_locs = [g.capacity // dj if lay.jobs_sharded else g.capacity
              for g, lay in zip(groups, lays)]
    vbs = [int(g.graph.block_size) for g in groups]
    compress = [spec.compress_halo and g.semiring == PLUS_TIMES
                and mode != "indep" and lay.blocks_sharded
                for g, lay in zip(groups, lays)]
    any_bs = any(lay.blocks_sharded for lay in lays) and s_blk > 1

    def _boff(gi):
        if lays[gi].blocks_sharded:
            return jax.lax.axis_index(ba) * b_locs[gi]
        return jnp.int32(0)

    def _group_sample(nu, pm, key, gi):
        """Per-(job, shard) DO queues over this shard's local blocks."""
        lay = lays[gi]
        kb = jax.random.fold_in(
            jax.random.fold_in(key, gi),
            jax.lax.axis_index(ba) if lay.blocks_sharded else 0)
        joff = (jax.lax.axis_index(ja) * j_locs[gi] if lay.jobs_sharded
                else jnp.int32(0))
        jids = joff + jnp.arange(nu.shape[0], dtype=jnp.int32)
        keys = jax.vmap(lambda t: jax.random.fold_in(kb, t))(jids)
        return jax.vmap(
            lambda n, p, k: do_select_device(n, p, q, k, samples))(
                nu, pm, keys)

    def unconverged_total(vs, ds):
        tot = jnp.float32(0)
        for gi in range(n_groups):
            loc = jnp.sum(
                algs[gi].unconverged(vs[gi], ds[gi]).astype(jnp.float32))
            tot = tot + _sum_unique(loc, lays[gi], ja, ba)
        return tot.astype(jnp.int32)

    def superstep(carry, scales, tiles, nbrs, ovs, prs, key):
        (it, vs, ds, loads, pushes, pair_loads, iters, boost, tel, halo,
         errs) = carry
        kstep = jax.random.fold_in(key, it)
        node_uns, p_means, actives, n_lives, keeps = [], [], [], [], []
        for gi in range(n_groups):
            lay = lays[gi]
            if needs_pairs:
                nu, pm = compute_pairs_local(algs[gi], vs[gi], ds[gi])
                if lay.blocks_sharded:
                    bsl = jax.lax.dynamic_slice_in_dim(
                        boost, _boff(gi), b_locs[gi])
                else:
                    bsl = boost
                pm = pm + bsl[None, :] * (nu > 0)
            else:
                un = algs[gi].unconverged(vs[gi], ds[gi])
                nu = jnp.sum(un, axis=-1).astype(jnp.float32)
                pm = None
            cnt = _psum_blocks(prio.counts_from_pairs(nu).astype(jnp.float32),
                               lay, ba)
            act = cnt > 0
            n_live = _sum_jobs(jnp.sum(act.astype(jnp.float32)), lay, ja)
            node_uns.append(nu)
            p_means.append(pm)
            actives.append(act)
            n_lives.append(n_live)
            keeps.append(n_live > 0)

        # -- selection ----------------------------------------------------
        sel_pushes = jnp.float32(0)
        if mode == "two":
            pri = jnp.zeros((bn,), jnp.float32)
            heads_f = jnp.zeros((bn,), jnp.float32)
            for gi in range(n_groups):
                sel, msk = _group_sample(node_uns[gi], p_means[gi], kstep, gi)
                selg = sel + _boff(gi)
                pri_l = jnp.zeros((bn,), jnp.float32)
                heads_l = jnp.zeros((bn,), jnp.bool_)
                pri_l, heads_l = accumulate_priority(pri_l, heads_l, selg,
                                                     msk, q)
                pri = pri + _sum_unique(pri_l, lays[gi], ja, ba)
                heads_f = heads_f + _sum_unique(
                    heads_l.astype(jnp.float32), lays[gi], ja, ba)
            gsel, gmsk = synthesize_topq(pri, heads_f > 0, q, alpha)
            tile_loads = jnp.sum(gmsk > 0).astype(jnp.float32)
            for gi in range(n_groups):
                lsel = jnp.clip(gsel - _boff(gi), 0, b_locs[gi] - 1)
                own = ((gsel >= _boff(gi))
                       & (gsel < _boff(gi) + b_locs[gi]) & (gmsk > 0))
                cnt = jnp.sum(((node_uns[gi][:, lsel] > 0)
                               & own[None, :]).astype(jnp.float32))
                sel_pushes = sel_pushes + _sum_unique(cnt, lays[gi], ja, ba)
            sels = [gsel] * n_groups
            msks = [gmsk] * n_groups
            shared = True
        elif mode == "all":
            gsel = jnp.arange(bn, dtype=jnp.int32)
            gmsk = jnp.ones(bn, jnp.float32)
            tile_loads = jnp.float32(bn)
            sel_pushes = jnp.float32(bn) * sum(n_lives)
            sels = [gsel] * n_groups
            msks = [gmsk] * n_groups
            shared = True
        else:   # indep
            sels, msks = [], []
            tile_loads = jnp.float32(0)
            for gi in range(n_groups):
                sel, msk = _group_sample(node_uns[gi], p_means[gi], kstep, gi)
                selg = sel + _boff(gi)
                if lays[gi].blocks_sharded:
                    sg = jax.lax.all_gather(selg, ba)       # [S, J_loc, q]
                    mg = jax.lax.all_gather(msk, ba)
                    selg = jnp.moveaxis(sg, 0, 1).reshape(sel.shape[0], -1)
                    msk = jnp.moveaxis(mg, 0, 1).reshape(sel.shape[0], -1)
                sels.append(selg)
                msks.append(msk)
                tile_loads = tile_loads + _sum_jobs(
                    jnp.sum(msk > 0).astype(jnp.float32), lays[gi], ja)
            sel_pushes = tile_loads
            shared = False

        # -- exchange + per-shard pair runs --------------------------------
        new_vs, new_ds, new_iters, new_errs = [], [], [], []
        pair_step = jnp.float32(0)
        halo_step = jnp.float32(0)
        for gi in range(n_groups):
            g, lay = groups[gi], lays[gi]
            boff, b_loc, vb = _boff(gi), b_locs[gi], vbs[gi]
            sel, msk = sels[gi], msks[gi]
            if shared:
                raw, base, d_sel, err2 = _exchange_shared(
                    g.semiring, ds[gi], sel, msk, boff, b_loc, bn, ba, lay,
                    errs[gi], compress[gi], spec.bits)
                pair_cnt = jnp.sum(prs[gi].src_nnz[sel]
                                   * (msk > 0)).astype(jnp.float32)
                occ_g = jnp.sum(msk > 0).astype(jnp.float32)
            else:
                raw, base, d_sel = _exchange_indep(
                    g.semiring, ds[gi], sel, msk, boff, b_loc, bn, ba, lay)
                err2 = errs[gi]
                cnt = jnp.sum(prs[gi].src_nnz[sel]
                              * (msk > 0)).astype(jnp.float32)
                pair_cnt = _sum_jobs(cnt, lay, ja)
                occ_g = _sum_jobs(jnp.sum(msk > 0).astype(jnp.float32),
                                  lay, ja)
            d_wide = _widen(g.semiring, d_sel, sel, bn, shared)
            v2, d2 = _apply_pairs_local(
                g.semiring, vs[gi], base, raw, d_wide, d_sel, sel,
                prs[gi].src[0], prs[gi].dst_local[0], prs[gi].first[0],
                prs[gi].last[0], prs[gi].tiles[0],
                prs[gi].dst_touched_local[0], scales[gi], msk, ovs[gi],
                boff, b_loc, shared, use_pallas)
            keep = keeps[gi]
            new_vs.append(jnp.where(keep, v2, vs[gi]))
            new_ds.append(jnp.where(keep, d2, ds[gi]))
            new_errs.append(jnp.where(keep, err2, errs[gi])
                            if compress[gi] else errs[gi])
            new_iters.append(iters[gi] + actives[gi].astype(jnp.int32))
            pair_step = pair_step + keep.astype(jnp.float32) * pair_cnt
            if lay.blocks_sharded and s_blk > 1:
                itemb = 1.0 if (compress[gi] and shared) else 4.0
                if shared:
                    payload = occ_g * vb * itemb * n_lives[gi]
                else:
                    payload = occ_g * vb * 4.0
                halo_step = halo_step + keep.astype(jnp.float32) * payload
        if mode == "two" and any_bs:
            halo_step = halo_step + 8.0 * bn   # [B_N] pri + head psum
        if tel_cap:
            # written AFTER the exchange loop so the row carries the
            # superstep's real pair/halo traffic alongside the pre-push
            # scheduling reads
            idx = jnp.minimum(it, tel_cap - 1)
            occ = (jnp.sum(msks[0] > 0).astype(jnp.int32) if shared
                   else tile_loads.astype(jnp.int32))
            tel = device_write(
                tel, idx,
                sum(n_lives).astype(jnp.int32),
                tile_loads.astype(jnp.int32),
                sel_pushes.astype(jnp.int32), occ,
                jnp.sum(boost > 0).astype(jnp.int32),
                jnp.stack([_sum_unique(jnp.sum(node_uns[gi]), lays[gi],
                                       ja, ba).astype(jnp.int32)
                           for gi in range(n_groups)]),
                jnp.stack([jax.lax.pmax(jax.lax.pmax(
                    jnp.max(algs[gi].vertex_priority(vs[gi], ds[gi])), ja),
                    ba) for gi in range(n_groups)]),
                tile_pair_loads=pair_step.astype(jnp.int32),
                halo_bytes=halo_step)
        return (it + 1, tuple(new_vs), tuple(new_ds),
                loads + tile_loads, pushes + sel_pushes,
                pair_loads + pair_step, tuple(new_iters),
                jnp.zeros_like(boost), tel, halo + halo_step,
                tuple(new_errs))

    def local_step(state, scales, tiles, nbrs, ovs, prs, max_steps, key):
        del tiles, nbrs   # the pair slices replace block-ELL staging

        def body(c):
            return superstep(c, scales, None, None, ovs, prs, key)

        def live(c):
            return (unconverged_total(c[1], c[2]) > 0) & (c[0] < max_steps)

        if k_sync == math.inf:
            state = jax.lax.while_loop(live, body, state)
        else:
            def gated(c, _):
                return jax.lax.cond(live(c), body, lambda x: x, c), None
            state, _ = jax.lax.scan(gated, state, None, length=int(k_sync))
        return state, unconverged_total(state[1], state[2])

    # ---- shard_map wiring -------------------------------------------------
    vs_specs = tuple(spec.state_spec(lay) for lay in lays)
    iters_specs = tuple(spec.jobs_spec(lay) for lay in lays)
    err_specs = tuple(spec.state_spec(lays[gi]) if compress[gi] else P()
                      for gi in range(n_groups))
    tel_spec = (tuple(P() for _ in device_buffers(1, n_groups))
                if tel_cap else ())
    state_spec = (P(), vs_specs, vs_specs, P(), P(), P(), iters_specs,
                  P(), tel_spec, P(), err_specs)
    graph_specs = tuple(
        P(ba) if lay.blocks_sharded else P() for lay in lays)
    ovs_specs = tuple(
        dataclasses.replace(g.overlay, src_u=P(), dst=P(), w=P(), mask=P())
        for g in groups)
    # spec pytrees must carry the SAME aux as the arguments they match
    prs_specs = []
    for g, lay in zip(groups, lays):
        bsp = P(ba) if lay.blocks_sharded else P()
        prs_specs.append(dataclasses.replace(
            sess._pair_shards(g), src=bsp, dst_local=bsp, first=bsp,
            last=bsp, tiles=bsp, src_nnz=P(), dst_touched_local=bsp))
    prs_specs = tuple(prs_specs)
    scales_specs = tuple(spec.jobs_spec(lay) for lay in lays)
    in_specs = (state_spec, scales_specs, graph_specs, graph_specs,
                ovs_specs, prs_specs, P(), P())
    return jax.jit(shard_map(
        local_step, mesh=spec.mesh, in_specs=in_specs,
        out_specs=(state_spec, P()), check_rep=False))


def compute_pairs_local(alg, values, deltas):
    """<Node_un, P_mean> of the LOCAL block rows ([J_loc, B_loc, Vb] in,
    [J_loc, B_loc] out) — `core.push.compute_pairs` is already
    shard-local (per-vertex priority, per-block reduce)."""
    from repro.core.push import compute_pairs
    return compute_pairs(alg, values, deltas)


def run_device_2d(policy, sess, max_supersteps: int):
    """2D-mesh device driver: `core.policy._run_device` with the carry's
    two extra slots (halo_bytes accumulator, error-feedback residuals).
    Sampling streams, chunking semantics and the dtype contract are
    identical to the 1D driver."""
    from repro.core.policy import RunMetrics
    from repro.obs.telemetry import series_from_device
    spec = sess._mesh2d
    groups = sess.view_groups()
    lays = [spec.layout(g) for g in groups]
    step_fn = sess._device_step_fn(policy)
    boost = sess._consume_dirty_boost()
    bn = sess.scheduler.num_blocks
    tel_cfg = getattr(sess, "telemetry", None)
    tel_cap = int(tel_cfg.capacity) if tel_cfg is not None else 0
    trace = getattr(sess, "trace", None)
    trace = trace if trace is not None and trace.enabled else None
    compress = [spec.compress_halo and g.semiring == PLUS_TIMES
                and not _policy_is_indep(policy) and lay.blocks_sharded
                for g, lay in zip(groups, lays)]
    errs = tuple(
        jax.device_put(jnp.zeros_like(g.deltas), spec.state_sharding(lay))
        if comp else jnp.zeros((1, 1, 1), jnp.float32)
        for g, lay, comp in zip(groups, lays, compress))
    state = (jnp.int32(0),
             tuple(g.values for g in groups),
             tuple(g.deltas for g in groups),
             jnp.float32(0), jnp.float32(0), jnp.float32(0),
             tuple(jnp.zeros(g.capacity, jnp.int32) for g in groups),
             jnp.zeros(bn, jnp.float32) if boost is None
             else jnp.asarray(boost, jnp.float32),
             device_buffers(tel_cap, len(groups)) if tel_cap else (),
             jnp.float32(0), errs)
    scales = tuple(g.push_scale for g in groups)
    tiles = tuple(g.graph.tiles for g in groups)
    nbrs = tuple(g.graph.nbr_ids for g in groups)
    ovs = tuple(g.overlay for g in groups)
    prs = tuple(sess._pair_shards(g) for g in groups)
    budget = int(min(max_supersteps, np.iinfo(np.int32).max))
    max_steps = jnp.int32(budget)
    key = jax.random.fold_in(jax.random.PRNGKey(sess.seed),
                             sess.scheduler._step)
    m = RunMetrics()
    while True:
        t_chunk = trace.now_us() if trace else 0.0
        state, un = step_fn(state, scales, tiles, nbrs, ovs, prs,
                            max_steps, key)
        it_h, un_h = map(int, jax.device_get((state[0], un)))
        m.host_syncs += 1
        if trace:
            trace.complete("device_chunk", t_chunk,
                           trace.now_us() - t_chunk, cat="superstep", tid=2,
                           sync=m.host_syncs - 1, supersteps_done=it_h)
        if un_h == 0 or it_h >= budget:
            break
    sess.scheduler._step += it_h
    for gi, g in enumerate(groups):
        g.values, g.deltas = state[1][gi], state[2][gi]
    m.supersteps = it_h
    loads_h, pushes_h, pair_loads_h, iters_h, halo_h = jax.device_get(
        (state[3], state[4], state[5], state[6], state[9]))
    m.tile_loads = int(loads_h)
    m.job_block_pushes = int(pushes_h)
    m.tile_pair_loads = int(pair_loads_h)
    m.halo_bytes = float(halo_h)
    m.converged = un_h == 0
    m.iterations_per_job = np.concatenate(
        [np.asarray(x, dtype=np.int64) for x in iters_h])
    if tel_cap:
        m.telemetry = series_from_device(state[8], it_h,
                                         [g.key for g in groups])
    return m


def _policy_is_indep(policy) -> bool:
    from repro.core.policy import Independent
    return isinstance(policy, Independent)


# ---------------------------------------------------------------------------
# host-backend push functions (scheduling on host, 2D push on device)
# ---------------------------------------------------------------------------


def shared_push_fn_2d(spec: Mesh2DSpec, grp, use_pallas: bool):
    """2D replacement for `core.push.shared_push_fn`: same 9-arg
    signature with `pairs` a `PairShards`; the jitted shard_map consumes
    the host scheduler's global [q] selection, exchanges the frontier
    and runs each shard's pair slice.  The host scheduler sees GLOBAL
    state, so the schedule — and for min-plus the fixpoint, bit-for-bit
    — matches the unsharded session.  Variants are cached per (overlay
    capacity, pair shape) because both are part of the traced program's
    pytree structure."""
    lay = spec.layout(grp, warn=True)
    semiring = grp.semiring
    bn = int(grp.graph.num_blocks)
    b_loc = bn // spec.block_shards if lay.blocks_sharded else bn
    ja, ba = spec.jobs_axis, spec.blocks_axis
    variants = {}

    def build(ov_cap: int, ps_aux: tuple):
        def local(values, deltas, sel, msk, scales, overlay, ps):
            boff = (jax.lax.axis_index(ba) * b_loc if lay.blocks_sharded
                    else jnp.int32(0))
            raw, base, d_sel, _ = _exchange_shared(
                semiring, deltas, sel, msk, boff, b_loc, bn, ba, lay,
                None, False, 8)
            d_wide = _widen(semiring, d_sel, sel, bn, True)
            return _apply_pairs_local(
                semiring, values, base, raw, d_wide, d_sel, sel,
                ps.src[0], ps.dst_local[0], ps.first[0], ps.last[0],
                ps.tiles[0], ps.dst_touched_local[0], scales, msk,
                overlay, boff, b_loc, True, use_pallas)

        st = spec.state_spec(lay)
        ov_spec = TileOverlaySpec(ov_cap)
        ps_spec = pair_shards_spec(spec, lay.blocks_sharded)
        ps_spec = dataclasses.replace(
            ps_spec, num_shards=ps_aux[0], pair_cap=ps_aux[1],
            block_size=ps_aux[2], num_blocks=ps_aux[3],
            blocks_per_shard=ps_aux[4], fill=ps_aux[5])
        return jax.jit(shard_map(
            local, mesh=spec.mesh,
            in_specs=(st, st, P(), P(), spec.jobs_spec(lay), ov_spec,
                      ps_spec),
            out_specs=(st, st), check_rep=False))

    def fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
           pairs):
        del tiles, nbr_ids
        ps_aux = pairs.tree_flatten()[1]
        k = (overlay.capacity if overlay is not None else 0, ps_aux)
        if k not in variants:
            variants[k] = build(k[0], ps_aux)
        return variants[k](values, deltas, sel, msk, scales, overlay,
                           pairs)

    return fn


def indep_push_fn_2d(spec: Mesh2DSpec, grp):
    """2D replacement for `core.push.indep_push_fn` (per-job [J, q]
    selections; one extra trailing `pairs` argument the 2D host driver
    supplies)."""
    lay = spec.layout(grp, warn=True)
    semiring = grp.semiring
    bn = int(grp.graph.num_blocks)
    b_loc = bn // spec.block_shards if lay.blocks_sharded else bn
    ja, ba = spec.jobs_axis, spec.blocks_axis
    variants = {}

    def build(ov_cap: int, ps_aux: tuple):
        def local(values, deltas, sel, msk, scales, overlay, ps):
            boff = (jax.lax.axis_index(ba) * b_loc if lay.blocks_sharded
                    else jnp.int32(0))
            raw, base, d_sel = _exchange_indep(
                semiring, deltas, sel, msk, boff, b_loc, bn, ba, lay)
            d_wide = _widen(semiring, d_sel, sel, bn, False)
            return _apply_pairs_local(
                semiring, values, base, raw, d_wide, d_sel, sel,
                ps.src[0], ps.dst_local[0], ps.first[0], ps.last[0],
                ps.tiles[0], ps.dst_touched_local[0], scales, msk,
                overlay, boff, b_loc, False, False)

        st = spec.state_spec(lay)
        jsp = spec.jobs_spec(lay)
        ov_spec = TileOverlaySpec(ov_cap)
        ps_spec = pair_shards_spec(spec, lay.blocks_sharded)
        ps_spec = dataclasses.replace(
            ps_spec, num_shards=ps_aux[0], pair_cap=ps_aux[1],
            block_size=ps_aux[2], num_blocks=ps_aux[3],
            blocks_per_shard=ps_aux[4], fill=ps_aux[5])
        return jax.jit(shard_map(
            local, mesh=spec.mesh,
            in_specs=(st, st, jsp, jsp, jsp, ov_spec, ps_spec),
            out_specs=(st, st), check_rep=False))

    def fn(values, deltas, tiles, nbr_ids, sel, msk, scales, overlay,
           pairs):
        del tiles, nbr_ids
        ps_aux = pairs.tree_flatten()[1]
        k = (overlay.capacity if overlay is not None else 0, ps_aux)
        if k not in variants:
            variants[k] = build(k[0], ps_aux)
        return variants[k](values, deltas, sel, msk, scales, overlay,
                           pairs)

    return fn


def TileOverlaySpec(capacity: int):
    """shard_map spec pytree shaped like a (replicated) TileOverlay."""
    from repro.graph.structure import TileOverlay
    return TileOverlay(capacity=capacity, src_u=P(), dst=P(), w=P(),
                       mask=P())


def host_halo_bytes(spec: Mesh2DSpec, groups, selection, actives) -> float:
    """Frontier payload of one HOST-driver superstep (see module doc):
    occupied selection slots x Vb x 4 bytes x live jobs, summed over the
    blocks-sharded groups that were pushed."""
    if spec is None or spec.block_shards <= 1:
        return 0.0
    total = 0.0
    for gi, (grp, act) in enumerate(zip(groups, actives)):
        if not act.any() or not spec.layout(grp).blocks_sharded:
            continue
        vb = int(grp.graph.block_size)
        if selection.shared:
            occ = float(np.sum(np.asarray(selection.msk) > 0))
            total += occ * vb * 4.0 * float(act.sum())
        else:
            total += float(np.sum(np.asarray(selection.msk[gi]) > 0)) \
                * vb * 4.0
    return total


# ---------------------------------------------------------------------------
# session placement
# ---------------------------------------------------------------------------


def shard_session_2d(mesh: Mesh, session, axes=(JOBS_AXIS, BLOCKS_AXIS),
                     compress_halo: bool = False, bits: int = 8):
    """Place a GraphSession on a 2D (jobs x blocks) mesh.

    Job state shards over BOTH axes (rows of blocks to the owning block
    shard), adjacency tiles / neighbour ids shard their leading block
    dim over the blocks axis, overlays and masks replicate (shared view
    data staged alongside the owning shard's tiles; dirty-block boosts
    broadcast).  Records the placement as `session._mesh2d`, which
    reroutes the device superstep and the host push functions through
    this module until `unshard_session`."""
    ja, ba = axes
    if ja not in mesh.axis_names or ba not in mesh.axis_names:
        raise ValueError(
            f"mesh axes {mesh.axis_names} do not include {axes}")
    spec = Mesh2DSpec(mesh, ja, ba, compress_halo=compress_halo, bits=bits)
    for grp in session.view_groups():
        lay = spec.layout(grp, warn=True)
        sh3 = spec.state_sharding(lay)
        grp.values = jax.device_put(grp.values, sh3)
        grp.deltas = jax.device_put(grp.deltas, sh3)
        grp.push_scale = jax.device_put(
            grp.push_scale, NamedSharding(mesh, spec.jobs_spec(lay)))
        gsh = P(ba) if lay.blocks_sharded else P()
        g = grp.graph
        g.tiles = jax.device_put(g.tiles, NamedSharding(mesh, gsh))
        g.nbr_ids = jax.device_put(g.nbr_ids, NamedSharding(mesh, gsh))
        g.nbr_mask = jax.device_put(g.nbr_mask, NamedSharding(mesh, gsh))
        g.vertex_mask = jax.device_put(g.vertex_mask,
                                       NamedSharding(mesh, P()))
        if grp.overlay is not None:
            grp.overlay = dataclasses.replace(
                grp.overlay,
                src_u=jax.device_put(grp.overlay.src_u,
                                     NamedSharding(mesh, P())),
                dst=jax.device_put(grp.overlay.dst,
                                   NamedSharding(mesh, P())),
                w=jax.device_put(grp.overlay.w, NamedSharding(mesh, P())),
                mask=jax.device_put(grp.overlay.mask,
                                    NamedSharding(mesh, P())))
        grp.pair_shards = None      # rebuild lazily against this placement
    session._mesh2d = spec
    return session


def unshard_session(session):
    """Gather every view group back to single-device placement and clear
    the 2D-mesh routing (the inverse of `shard_session_2d`)."""
    spec = getattr(session, "_mesh2d", None)
    if spec is None:
        return session
    for grp in session.view_groups():
        grp.values = jnp.asarray(jax.device_get(grp.values))
        grp.deltas = jnp.asarray(jax.device_get(grp.deltas))
        grp.push_scale = jnp.asarray(jax.device_get(grp.push_scale))
        g = grp.graph
        g.tiles = jnp.asarray(jax.device_get(g.tiles))
        g.nbr_ids = jnp.asarray(jax.device_get(g.nbr_ids))
        g.nbr_mask = jnp.asarray(jax.device_get(g.nbr_mask))
        g.vertex_mask = jnp.asarray(jax.device_get(g.vertex_mask))
        if grp.overlay is not None:
            grp.overlay = dataclasses.replace(
                grp.overlay,
                src_u=jnp.asarray(jax.device_get(grp.overlay.src_u)),
                dst=jnp.asarray(jax.device_get(grp.overlay.dst)),
                w=jnp.asarray(jax.device_get(grp.overlay.w)),
                mask=jnp.asarray(jax.device_get(grp.overlay.mask)))
        grp.pair_shards = None
    session._mesh2d = None
    return session
