"""Fault tolerance: checkpoint-resume restart loop + straggler detection.

RestartManager wraps a training loop with the standard preemption contract:
periodic (async) checkpoints, and on ANY step failure the loop restores the
latest checkpoint and replays forward.  With deterministic data (data_fn is
keyed by step) the recovered run is bit-identical to an uninterrupted one.

StragglerWatchdog keeps a sliding window of step durations and reports a
step whose duration exceeds `threshold` x the window median — the signal the
launch layer uses to trigger an elastic reshard away from a slow host.
"""

from __future__ import annotations

import dataclasses
import statistics
import sys
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)


class RestartManager:
    """Run a step loop to completion across simulated/real preemptions."""

    def __init__(self, directory: str, *, save_every: int = 10,
                 max_restarts: int = 100):
        self.directory = directory
        self.save_every = max(1, int(save_every))
        self.max_restarts = max_restarts
        self._ckpt = AsyncCheckpointer(directory)

    def _restore(self, like: Any, shardings: Any) -> Tuple[Any, int]:
        state, step = restore_checkpoint(self.directory, like, shardings)
        return state, int(step)

    def run(self, init_state: Any,
            step_fn: Callable[[Any, Any], Tuple[Any, Any]],
            data_fn: Callable[[int], Any],
            total_steps: int, *,
            failure_hook: Optional[Callable[[int], None]] = None,
            shardings: Any = None) -> Tuple[Any, int, int]:
        """Returns (final_state, steps_completed, restarts).

        step_fn(state, batch) -> (state, metrics); data_fn(step) -> batch
        must be deterministic in `step` for exact recovery.  failure_hook
        (tests / chaos injection) runs before each step and may raise.
        Checkpoints land every `save_every` completed steps; a crash between
        checkpoints replays at most save_every - 1 steps.
        """
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
            init_state)
        if latest_step(self.directory) is None:
            # durable step-0 snapshot BEFORE the first step: callers donate
            # the state into their jitted step (train.py donate_argnums), so
            # init_state's buffers are dead after step 1 — a failure before
            # the first periodic checkpoint must restore from disk, never
            # from the (deleted) initial buffers
            save_checkpoint(self.directory, 0, init_state)
            state, step = init_state, 0   # still alive here; no reload
        else:
            state, step = self._restore(like, shardings)
        restarts = 0
        while step < total_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)
                batch = data_fn(step)
                state, _ = step_fn(state, batch)
                step += 1
                if step % self.save_every == 0 or step == total_steps:
                    self._ckpt.save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                # surface every failure: a deterministic step bug replays
                # identically and would otherwise burn max_restarts in silence
                print(f"[restart-manager] step {step} failed ({e!r}); "
                      f"restart {restarts}/{self.max_restarts}",
                      file=sys.stderr)
                self._ckpt.wait()  # never restore a half-written checkpoint
                state, step = self._restore(like, shardings)
        self._ckpt.wait()
        return state, step, restarts


def checkpoint_session(sess) -> dict:
    """Host snapshot of a GraphSession's resumable state: every view's
    values/deltas (the convergence state — adjacency is rebuilt from the
    session's own graph, never checkpointed) plus the scheduler's stream
    position, so a resumed run draws the SAME sampling keys it would have
    drawn uninterrupted.  Mesh-agnostic by construction: device_get
    gathers sharded state to host, so the snapshot restores onto any
    placement — including a SMALLER mesh after a shard loss."""
    groups = sess.view_groups()
    vals, dels = jax.device_get(([g.values for g in groups],
                                 [g.deltas for g in groups]))
    return {"keys": [g.key for g in groups],
            "values": [jnp.asarray(v) for v in vals],
            "deltas": [jnp.asarray(d) for d in dels],
            "step": int(sess.scheduler._step)}


def restore_session(sess, snapshot: dict, mesh=None, **shard_kwargs):
    """Elastic reshard after a (simulated) shard loss: load `snapshot`
    into `sess` and re-place on the survivor `mesh` (2D (jobs x blocks)
    when it has two named axes — see repro.dist.graph.shard_session — or
    single-device when None).  The resumed run picks up the scheduler
    stream where the snapshot left it, so a min-plus run restored onto a
    different block-shard count reaches the bit-identical fixpoint."""
    from repro.dist.mesh2d import unshard_session
    unshard_session(sess)
    by_key = {g.key: g for g in sess.view_groups()}
    if set(snapshot["keys"]) != set(by_key):
        raise ValueError(
            f"snapshot views {snapshot['keys']} do not match the "
            f"session's {list(by_key)}")
    for key, v, d in zip(snapshot["keys"], snapshot["values"],
                         snapshot["deltas"]):
        grp = by_key[key]
        grp.values = jnp.asarray(v)
        grp.deltas = jnp.asarray(d)
    sess.scheduler._step = int(snapshot["step"])
    if mesh is not None:
        from repro.dist.graph import shard_session
        shard_session(mesh, sess, **shard_kwargs)
    return sess


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerWatchdog:
    """Sliding-window step-duration monitor.

    observe(step, duration) returns a StragglerReport when `duration`
    exceeds threshold x the median of the last `window` durations, or None
    (including while the window is still filling)."""

    def __init__(self, window: int = 8, threshold: float = 2.0):
        self.window = max(1, int(window))
        self.threshold = threshold
        self._durations: list = []

    def observe(self, step: int, duration: float) -> Optional[StragglerReport]:
        report = None
        if len(self._durations) >= self.window:
            med = statistics.median(self._durations[-self.window:])
            if med > 0 and duration >= self.threshold * med:
                report = StragglerReport(step=step, duration=duration,
                                         median=med,
                                         ratio=duration / med)
        if report is None:
            # straggler steps stay out of the baseline window
            self._durations.append(float(duration))
            if len(self._durations) > self.window:
                self._durations = self._durations[-self.window:]
        return report
