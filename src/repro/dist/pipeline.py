"""Microbatched pipeline parallelism (GPipe schedule) over a mesh axis.

Each device on the pipeline axis owns one stage's parameters (leading stage
axis of the params tree, sharded over the axis).  The batch is split into
n_micro microbatches; each scan tick every device runs its stage on its
current activation and ppermutes the result to the next stage — the rotating
systolic schedule.  After n_micro + S - 1 ticks the last stage has produced
all microbatch outputs; the loss is computed on the reassembled batch so the
pipelined loss (and, through AD, its grads) matches the unpipelined
sequential reference exactly.

Stages must be shape-homogeneous (activation in == activation out), which is
exactly the transformer-block case.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipelined_loss(mesh: Mesh, stage_fn: Callable, loss_fn: Callable,
                        axis_name: str = "pod", n_micro: int = 1):
    """Build pipelined(params, x, y) -> scalar loss.

    params: tree whose leaves carry a leading stage axis of size S =
    mesh.shape[axis_name].  stage_fn(stage_params, h) -> h' applies ONE
    stage (no stage axis).  loss_fn(out, y) -> scalar on the full batch.
    """
    n_stages = mesh.shape[axis_name]

    def _body(params, xs, y):
        p = jax.tree.map(lambda a: a[0], params)       # this device's stage
        idx = jax.lax.axis_index(axis_name)
        n_ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        out_sd = jax.eval_shape(stage_fn, p, xs[0])
        if out_sd.shape != xs.shape[1:] :
            raise ValueError("pipeline stages must be shape-homogeneous: "
                             f"{xs.shape[1:]} -> {out_sd.shape}")

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (clipped duplicates past the end
            # never reach a valid output slot before the loop ends)
            inp = jnp.where(idx == 0,
                            xs[jnp.clip(t, 0, n_micro - 1)], state)
            out = stage_fn(p, inp)
            w = t - (n_stages - 1)       # microbatch leaving the last stage
            cw = jnp.clip(w, 0, n_micro - 1)
            write = (idx == n_stages - 1) & (w >= 0)
            outs = outs.at[cw].set(jnp.where(write, out, outs[cw]))
            state = jax.lax.ppermute(out, axis_name, perm)
            return (state, outs), None

        outs0 = jnp.zeros((n_micro,) + out_sd.shape, out_sd.dtype)
        (_, outs), _ = jax.lax.scan(
            tick, (jnp.zeros_like(xs[0]), outs0),
            jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them to all
        # devices so the (replicated) loss is computed identically everywhere
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis_name)
        full = outs.reshape((outs.shape[0] * outs.shape[1],) + outs.shape[2:])
        return loss_fn(full, y)

    sharded = shard_map(_body, mesh=mesh,
                        in_specs=(P(axis_name), P(), P()),
                        out_specs=P(),
                        check_rep=False)

    def pipelined(params, x, y):
        batch = x.shape[0]
        if batch % n_micro:
            raise ValueError(f"batch {batch} not divisible by "
                             f"n_micro={n_micro}")
        mb = batch // n_micro
        xs = x.reshape((n_micro, mb) + x.shape[1:])
        return sharded(params, xs, y)

    return pipelined
