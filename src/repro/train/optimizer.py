"""AdamW with global-norm clipping + LR schedules (WSD for minicpm).

Implemented from scratch (no optax in this container).  Moments are fp32
regardless of param dtype; the update is computed in fp32 and cast back.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"      # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 10000
    stable_frac: float = 0.8      # WSD: fraction of post-warmup in stable LR


def wsd_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395)."""
    warm = cfg.warmup_steps
    stable_end = warm + int((cfg.total_steps - warm) * cfg.stable_frac)
    s = step.astype(jnp.float32)
    warm_lr = cfg.peak_lr * s / max(warm, 1)
    decay_span = max(cfg.total_steps - stable_end, 1)
    # MiniCPM uses exponential-ish rapid decay; linear-to-10% then hold
    decay_lr = cfg.peak_lr * jnp.maximum(
        1.0 - (s - stable_end) / decay_span, 0.1)
    return jnp.where(s < warm, warm_lr,
                     jnp.where(s < stable_end, cfg.peak_lr, decay_lr))


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.warmup_steps
    s = step.astype(jnp.float32)
    warm_lr = cfg.peak_lr * s / max(warm, 1)
    t = jnp.clip((s - warm) / max(cfg.total_steps - warm, 1), 0.0, 1.0)
    cos_lr = cfg.peak_lr * 0.5 * (1.0 + jnp.cos(math.pi * t))
    return jnp.where(s < warm, warm_lr, cos_lr)


def schedule_fn(cfg: AdamWConfig) -> Callable:
    if cfg.schedule == "wsd":
        return lambda step: wsd_schedule(cfg, step)
    if cfg.schedule == "cosine":
        return lambda step: cosine_schedule(cfg, step)
    return lambda step: jnp.float32(cfg.peak_lr)


def adamw_init(params):
    def zeros32(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule_fn(cfg)(step)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
