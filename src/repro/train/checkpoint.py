"""Checkpointing with elastic re-shard on restore.

Format: <dir>/step_<N>/
  manifest.json   - tree structure, shapes, dtypes, step, mesh metadata
  data.msgpack    - flat list of raw little-endian buffers

Restore takes a *target* mesh/shardings that may differ from the mesh the
checkpoint was written under (elastic scaling): arrays are rebuilt as global
values and device_put with the new sharding.  Writes are atomic
(tmp dir + rename) and an optional background thread makes them async
(compute/IO overlap).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import msgpack
import numpy as np
import jax
import jax.numpy as jnp


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(k) for k in path) for path, _ in flat]


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "paths": _tree_paths(tree),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [a.dtype.name if a.dtype.name != "bfloat16" else "bfloat16"
                   for a in host],
        "extra": extra or {},
    }
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "data.msgpack"), "wb") as f:
        packer = msgpack.Packer()
        f.write(packer.pack(len(host)))
        for a in host:
            f.write(packer.pack(a.tobytes()))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune older checkpoints, keep last 3
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in steps[:-3]:
        shutil.rmtree(os.path.join(directory, old))
    return final


class AsyncCheckpointer:
    """Overlap checkpoint IO with compute: save on a background thread,
    never more than one outstanding write."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # materialize on host synchronously (cheap vs device step), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        tree_host = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_checkpoint(self.directory, step, tree_host, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       shardings: Any = None,
                       step: Optional[int] = None) -> tuple:
    """Restore onto a possibly DIFFERENT mesh (elastic re-shard).

    tree_like: pytree with the same structure (e.g. from eval_shape or a
    freshly-initialized state).  shardings: optional matching tree of
    NamedSharding for the *target* mesh; None leaves arrays on default
    placement.  Returns (tree, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "data.msgpack"), "rb") as f:
        unpacker = msgpack.Unpacker(f, max_buffer_size=2**31)
        n = unpacker.unpack()
        raw = [unpacker.unpack() for _ in range(n)]

    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == n, f"leaf count mismatch {len(leaves)} != {n}"
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * n)

    out = []
    for buf, shape, dtype_name, like, sh in zip(
            raw, manifest["shapes"], manifest["dtypes"], leaves,
            shard_leaves):
        dtype = jnp.bfloat16 if dtype_name == "bfloat16" else np.dtype(
            dtype_name)
        arr = np.frombuffer(buf, dtype=np.uint8).view(
            np.dtype("uint16") if dtype_name == "bfloat16" else dtype
        )
        if dtype_name == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        arr = arr.reshape(shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
