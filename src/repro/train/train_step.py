"""Train step: loss -> grads -> AdamW, with optional microbatch accumulation
and an optional int8-compressed gradient all-reduce (shard_map variant, see
repro.dist.compression)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


TrainState = Dict[str, Any]  # {"params": ..., "opt": ..., }


def make_init_state(model: LM, opt_cfg: AdamWConfig):
    def init_state(key) -> TrainState:
        params = model.init(key)
        return {"params": params, "opt": adamw_init(params)}
    return init_state


def make_train_step(model: LM, opt_cfg: AdamWConfig, *,
                    accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch) -> tuple:
        params = state["params"]
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch accumulation: scan over leading accum axis
            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                loss_sum, acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (loss_sum + l, acc), None

            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), micro)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state["opt"], params)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
