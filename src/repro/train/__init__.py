from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   wsd_schedule, cosine_schedule)
from repro.train.train_step import TrainState, make_train_step, make_init_state

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "wsd_schedule",
           "cosine_schedule", "TrainState", "make_train_step",
           "make_init_state"]
