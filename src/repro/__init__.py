"""repro: Two-Level Scheduling for Concurrent Graph Processing (CS.DC 2018) on TPU/JAX.

Layers:
  repro.core        - the paper's contribution: MPDS + CAJS two-level scheduling
  repro.graph       - blocked graph substrate
  repro.algorithms  - delta-based accumulative graph algorithms
  repro.kernels     - Pallas TPU kernels (multi-job block SpMM, priority pairs)
  repro.models      - assigned LM architecture zoo
  repro.configs     - architecture configs (full + smoke)
  repro.train       - optimizer / training loop / checkpoint substrate
  repro.serve       - prefill/decode engine + concurrent request scheduler
  repro.dist        - sharding rules, fault tolerance, compression, pipeline
  repro.launch      - production mesh, dry-run, drivers
"""

__version__ = "0.1.0"
