"""Edge update batches for evolving graphs.

An `UpdateBatch` is an ordered list of edge operations against the shared
CSR — the RAW graph, before any view's normalization/symmetrization:

  INSERT (u, v, w)  upsert: create the edge, or replace its weight if it
                    already exists (reweight == insert of an existing
                    edge).  In-batch duplicates of the same (u, v) keep
                    the MIN weight, matching CSRGraph.from_edges dedupe.
  DELETE (u, v)     remove the edge if present (no-op otherwise).

Ops apply IN ORDER: a delete followed by an insert of the same edge
re-creates it.  `apply_to_csr` is the exact host-side application — the
source of truth every view compacts against, so compaction is
bit-identical to a from-scratch build on the updated CSR by construction.

Vertices are fixed for the session's lifetime (n never changes): block
ids stay view-agnostic and job state shapes stay stable, which is what
lets update batches flow into the jitted superstep without retracing.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.structure import CSRGraph

INSERT, DELETE = 0, 1


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """One batch of edge operations (applied atomically between supersteps)."""

    src: np.ndarray   # [E] int64
    dst: np.ndarray   # [E] int64
    w: np.ndarray     # [E] float32 (ignored for deletes)
    op: np.ndarray    # [E] int8, INSERT or DELETE

    def __post_init__(self):
        object.__setattr__(self, "src", np.asarray(self.src, np.int64))
        object.__setattr__(self, "dst", np.asarray(self.dst, np.int64))
        object.__setattr__(self, "w", np.asarray(self.w, np.float32))
        object.__setattr__(self, "op", np.asarray(self.op, np.int8))
        if not (len(self.src) == len(self.dst) == len(self.w)
                == len(self.op)):
            raise ValueError("ragged update batch")

    def __len__(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_inserts(self) -> int:
        return int((self.op == INSERT).sum())

    @property
    def num_deletes(self) -> int:
        return int((self.op == DELETE).sum())

    @staticmethod
    def inserts(src, dst, w=None) -> "UpdateBatch":
        src = np.asarray(src, np.int64)
        w = np.ones(len(src), np.float32) if w is None else w
        return UpdateBatch(src, np.asarray(dst, np.int64), w,
                           np.full(len(src), INSERT, np.int8))

    @staticmethod
    def deletes(src, dst) -> "UpdateBatch":
        src = np.asarray(src, np.int64)
        return UpdateBatch(src, np.asarray(dst, np.int64),
                           np.zeros(len(src), np.float32),
                           np.full(len(src), DELETE, np.int8))

    @staticmethod
    def concat(batches: Sequence["UpdateBatch"]) -> "UpdateBatch":
        return UpdateBatch(
            np.concatenate([b.src for b in batches]) if batches else
            np.zeros(0, np.int64),
            np.concatenate([b.dst for b in batches]) if batches else
            np.zeros(0, np.int64),
            np.concatenate([b.w for b in batches]) if batches else
            np.zeros(0, np.float32),
            np.concatenate([b.op for b in batches]) if batches else
            np.zeros(0, np.int8))


def _edge_dict(csr: CSRGraph) -> dict:
    """{(u, v): w} of the whole CSR (host; fine at repo scales)."""
    src = np.repeat(np.arange(csr.n, dtype=np.int64), csr.out_degree)
    return {(int(u), int(v)): float(w)
            for u, v, w in zip(src, csr.indices, csr.weights)}


def apply_to_csr(csr: CSRGraph, batch: UpdateBatch) -> CSRGraph:
    """Exact, deterministic application of `batch` to a CSR (new object).

    In-batch duplicate INSERTs of one (u, v) keep the min weight (the
    from_edges dedupe rule); ops otherwise apply in order."""
    n = csr.n
    if len(batch) and (batch.src.min() < 0 or batch.src.max() >= n
                       or batch.dst.min() < 0 or batch.dst.max() >= n):
        raise ValueError(f"update endpoints out of range for n={n}")
    edges = _edge_dict(csr)
    seen_insert = set()
    for u, v, w, op in zip(batch.src, batch.dst, batch.w, batch.op):
        key = (int(u), int(v))
        if op == DELETE:
            edges.pop(key, None)
            seen_insert.discard(key)
        else:
            w = float(w)
            if key in seen_insert:     # in-batch duplicate: min-weight
                edges[key] = min(edges[key], w)
            else:
                edges[key] = w         # upsert (reweight == insert)
                seen_insert.add(key)
    if not edges:
        return CSRGraph.from_edges(n, np.zeros(0, np.int64),
                                   np.zeros(0, np.int64))
    items = np.array(sorted(edges), dtype=np.int64)
    w = np.array([edges[(int(u), int(v))] for u, v in items],
                 dtype=np.float32)
    return CSRGraph.from_edges(n, items[:, 0], items[:, 1], w)
