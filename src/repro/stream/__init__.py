"""repro.stream — evolving graphs: live edge updates under concurrent jobs.

The paper's jobs arrive continuously against a shared graph; in the real
scene the GRAPH mutates too.  This subsystem lets a running GraphSession
absorb edge insert/delete/reweight batches at any superstep
(`GraphSession.apply_updates`) with incremental recomputation instead of
restart: a CSR delta overlay staged alongside the base tiles
(graph.structure.TileOverlay), exact delta-invariant correction for
plus-times jobs, support-test re-seeding for min-plus jobs, and dirty
blocks injected as priorities into the existing two-level scheduler —
across all four policies, both backends, job meshes (overlay replicated,
job state sharded), and the serve layer (ConcurrentServeScheduler.
notify_group_update).

See docs/API.md, "Evolving graphs".
"""

from repro.stream.updates import (INSERT, DELETE, UpdateBatch, apply_to_csr)
from repro.stream.apply import (DIRTY_BOOST, StreamStats,
                                apply_updates_to_session, compact_group)
from repro.stream.invalidate import (adjust_plus_times,
                                     full_reseed_plus_times,
                                     reactivate_sources, reseed_min_plus)

__all__ = [
    "INSERT", "DELETE", "UpdateBatch", "apply_to_csr",
    "DIRTY_BOOST", "StreamStats", "apply_updates_to_session",
    "compact_group",
    "adjust_plus_times", "full_reseed_plus_times", "reactivate_sources",
    "reseed_min_plus",
]
