"""Session-level application of an UpdateBatch: the evolving-graph core.

One call absorbs a batch into EVERY layer of a running GraphSession:

  1. the shared CSR updates exactly (`updates.apply_to_csr` — the source
     of truth every compaction rebuilds from);
  2. every view group maps the batch into its own weight space
     (symmetrize mirror, normalization, degree rescale) and edits its
     device structure IN PLACE: dense-tile writes for block pairs that
     own a tile slot, the bounded per-block delta-COO overlay for
     structurally-new pairs.  A full overlay row triggers COMPACTION —
     the view's BlockedGraph is rebuilt from the updated CSR,
     bit-identical to a from-scratch build, and the overlay empties;
  3. every job's state is invalidated just enough to reconverge to the
     new graph's fixpoint (repro.stream.invalidate: exact delta
     correction for plus-times, monotone re-activation / support-test
     reseed for min-plus);
  4. update-affected blocks are recorded as a pending PRIORITY INJECTION:
     the next run()'s first superstep boosts their P_mean in every job's
     DO queue (host and device backends alike), so the two-level
     scheduler steers all concurrent jobs at the dirty region first.

Counters accumulate on the session and drain into the next run()'s
RunMetrics (`updates_applied`, `dirty_blocks`, `reseed_fraction`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.algorithms.base import PLUS_TIMES
from repro.graph.structure import build_blocked, empty_overlay
from repro.stream import invalidate as inval
from repro.stream.updates import UpdateBatch, apply_to_csr

# P_mean boost injected for dirty blocks (large enough to outrank any
# organic mean priority; only reorders blocks that already pend work)
DIRTY_BOOST = 1e6


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """What one apply_updates() call did (also drained into RunMetrics)."""

    updates_applied: int
    dirty_blocks: int
    reseed_fraction: float
    compacted_views: int


# ---------------------------------------------------------------------------
# view-space weights
# ---------------------------------------------------------------------------


def _raw_weight(csr, u: int, v: int, symmetrize: bool) -> Optional[float]:
    w = csr.edge_weight(u, v)
    if symmetrize:
        w2 = csr.edge_weight(v, u)
        w = w2 if w is None else (w if w2 is None else min(w, w2))
    return w


def _norm_weight(w: Optional[float], u: int, normalize: Optional[str],
                 deg: Optional[np.ndarray]) -> Optional[float]:
    if w is None:
        return None
    if normalize == "unit":
        return 1.0
    if normalize == "zero":
        return 0.0
    if normalize == "out_degree":
        return w / max(int(deg[u]), 1)
    return w


def _view_degrees(csr, symmetrize: bool) -> np.ndarray:
    return np.diff((csr.symmetrized() if symmetrize else csr).indptr)


def _view_edges(csr, normalize: Optional[str], symmetrize: bool):
    """(src, dst, w) arrays of the view graph (normalization applied)."""
    g = csr.symmetrized() if symmetrize else csr
    src = np.repeat(np.arange(g.n, dtype=np.int64), g.out_degree)
    w = g.weights.astype(np.float32).copy()
    if normalize == "out_degree":
        deg = np.maximum(g.out_degree, 1).astype(np.float32)
        w = w / deg[src]
    elif normalize == "unit":
        w = np.ones_like(w)
    elif normalize == "zero":
        w = np.zeros_like(w)
    return src, g.indices.astype(np.int64), w


def _csr_arrays(n: int, src, dst, w):
    """(indptr, indices, weights) from COO, sorted by (src, dst)."""
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=indptr[1:])
    return indptr, dst.astype(np.int32), w.astype(np.float32)


# ---------------------------------------------------------------------------
# per-group mirrors of the blocked structure (host side)
# ---------------------------------------------------------------------------


def _ensure_mirrors(grp) -> None:
    if grp.pair_slot is not None:
        return
    ids = np.asarray(grp.graph.nbr_ids)
    msk = np.asarray(grp.graph.nbr_mask)
    grp.pair_slot = {(b, int(ids[b, k])): k
                     for b in range(ids.shape[0])
                     for k in range(ids.shape[1]) if msk[b, k]}
    cap = grp.overlay.capacity
    grp.ov_used = np.zeros((ids.shape[0], cap), dtype=bool)
    grp.ov_entry = {}


def _grow_overlay(grp, capacity: int) -> None:
    ov = grp.overlay
    pad = capacity - ov.capacity
    grp.overlay = dataclasses.replace(
        ov, capacity=capacity,
        src_u=jnp.pad(ov.src_u, ((0, 0), (0, pad))),
        dst=jnp.pad(ov.dst, ((0, 0), (0, pad))),
        w=jnp.pad(ov.w, ((0, 0), (0, pad))),
        mask=jnp.pad(ov.mask, ((0, 0), (0, pad))))
    grp.ov_used = np.pad(grp.ov_used, ((0, 0), (0, pad)))


def compact_group(sess, grp) -> None:
    """Rebuild the view's BlockedGraph from the updated CSR — by
    construction bit-identical to a from-scratch build — and empty the
    overlay.  Job state is untouched (same logical operator)."""
    semiring, fill, normalize, symmetrize = grp.key
    csr_view = sess._csr.symmetrized() if symmetrize else sess._csr
    g = build_blocked(csr_view, sess.block_size, fill=fill,
                      normalize=normalize)
    if g.num_blocks != grp.graph.num_blocks:
        raise ValueError("compaction changed the block count")
    grp.graph = g
    grp.overlay = empty_overlay(g.num_blocks)
    grp.pair_slot = None
    grp.ov_used = None
    grp.ov_entry = None
    grp.pairs = None      # block-pair view follows the rebuilt tiles
    sess.trace.instant("compact", cat="stream", view=str(grp.key))


# ---------------------------------------------------------------------------
# per-group application
# ---------------------------------------------------------------------------


def _group_touched_pairs(batch: UpdateBatch,
                         symmetrize: bool) -> List[Tuple[int, int]]:
    pairs = []
    seen = set()
    for u, v in zip(batch.src, batch.dst):
        for a in (((int(u), int(v)), (int(v), int(u))) if symmetrize
                  else ((int(u), int(v)),)):
            if a not in seen:
                seen.add(a)
                pairs.append(a)
    return pairs


def _apply_structure(sess, grp, pairs, new_w: Dict,
                     deg_o: Optional[np.ndarray],
                     deg_n: Optional[np.ndarray]) -> bool:
    """Tile / overlay edits for the touched pairs; returns True when the
    group compacted instead (overlay row overflow)."""
    g = grp.graph
    vb = g.block_size
    normalize = grp.key[2]
    _ensure_mirrors(grp)

    # out-degree normalization: a changed degree rescales the source's
    # whole row (tiles + overlay); touched entries are overwritten with
    # exact values below, so drift only ever sits on untouched entries
    # until the next compaction makes the tiles bit-exact again
    if normalize == "out_degree":
        srcs = sorted({u for u, _ in pairs if deg_o[u] != deg_n[u]})
        if srcs:
            s = np.asarray(srcs, dtype=np.int64)
            ratio = (np.maximum(deg_o[s], 1)
                     / np.maximum(deg_n[s], 1)).astype(np.float32)
            g.tiles = g.tiles.at[s // vb, :, s % vb, :].multiply(
                jnp.asarray(ratio)[:, None, None])
            by_src = {int(x): float(r) for x, r in zip(s, ratio)}
            hits = [(b, col, by_src[eu])
                    for (eu, ev), (b, col) in grp.ov_entry.items()
                    if eu in by_src]
            if hits:
                ob, oc, orat = map(np.asarray, zip(*hits))
                grp.overlay = dataclasses.replace(
                    grp.overlay,
                    w=grp.overlay.w.at[ob, oc].multiply(
                        jnp.asarray(orat, jnp.float32)))

    t_b, t_s, t_u, t_v, t_w = [], [], [], [], []
    # pending overlay writes keyed on (block, col): a slot freed by a
    # delete can be reclaimed by a later insert in the SAME batch, and a
    # duplicate index in one scatter-set has unspecified order — last
    # logical write must win, so dedupe here
    ov_writes: Dict[Tuple[int, int], Tuple[int, int, float, float]] = {}
    for (u, v) in pairs:
        w = new_w[(u, v)]
        sb, uo = divmod(u, vb)
        db, vo = divmod(v, vb)
        ent = grp.ov_entry.get((u, v))
        if ent is not None:
            if w is None:                     # delete an overlay edge
                grp.ov_used[ent] = False
                del grp.ov_entry[(u, v)]
                ov_writes[ent] = (0, 0, 0.0, 0.0)
            else:                             # reweight in place
                ov_writes[ent] = (uo, v, w, 1.0)
            continue
        slot = grp.pair_slot.get((sb, db))
        if slot is not None:                  # dense-tile write
            t_b.append(sb)
            t_s.append(slot)
            t_u.append(uo)
            t_v.append(vo)
            t_w.append(g.fill if w is None else w)
            continue
        if w is None:                         # deleting a non-edge
            continue
        # structurally-new block pair: overlay append
        if grp.overlay.capacity == 0:
            _grow_overlay(grp, sess.overlay_capacity)
        free = np.nonzero(~grp.ov_used[sb])[0]
        if len(free) == 0:                    # bounded: compact instead
            compact_group(sess, grp)
            return True
        col = int(free[0])
        grp.ov_used[sb, col] = True
        grp.ov_entry[(u, v)] = (sb, col)
        ov_writes[(sb, col)] = (uo, v, w, 1.0)

    if t_b:
        g.tiles = g.tiles.at[
            np.asarray(t_b), np.asarray(t_s), np.asarray(t_u),
            np.asarray(t_v)].set(jnp.asarray(t_w, jnp.float32))
    if ov_writes:
        b, c = map(np.asarray, zip(*ov_writes))
        ov_su, ov_dst, ov_w, ov_m = map(list, zip(*ov_writes.values()))
        grp.overlay = dataclasses.replace(
            grp.overlay,
            src_u=grp.overlay.src_u.at[b, c].set(
                jnp.asarray(ov_su, jnp.int32)),
            dst=grp.overlay.dst.at[b, c].set(
                jnp.asarray(ov_dst, jnp.int32)),
            w=grp.overlay.w.at[b, c].set(jnp.asarray(ov_w, jnp.float32)),
            mask=grp.overlay.mask.at[b, c].set(
                jnp.asarray(ov_m, jnp.float32)))
    return False


def _apply_to_group(sess, grp, batch: UpdateBatch, csr_old, csr_new,
                    dirty: np.ndarray, stats: Dict) -> None:
    semiring, fill, normalize, symmetrize = grp.key
    # any batch may edit tiles (in place or via compaction): drop the
    # cached block-pair view so the next run rebuilds it from the edited
    # tiles (the pair tiles are a copy, not an alias)
    grp.pairs = None
    pairs = _group_touched_pairs(batch, symmetrize)
    deg_o = deg_n = None
    if normalize == "out_degree":
        deg_o = _view_degrees(csr_old, symmetrize)
        deg_n = _view_degrees(csr_new, symmetrize)
    old_w = {(u, v): _norm_weight(_raw_weight(csr_old, u, v, symmetrize),
                                  u, normalize, deg_o)
             for u, v in pairs}
    new_w = {(u, v): _norm_weight(_raw_weight(csr_new, u, v, symmetrize),
                                  u, normalize, deg_n)
             for u, v in pairs}
    if _apply_structure(sess, grp, pairs, new_w, deg_o, deg_n):
        stats["compacted"] += 1

    vb = grp.graph.block_size
    for u, v in pairs:
        if old_w[(u, v)] is not None or new_w[(u, v)] is not None:
            dirty[u // vb] = True
            dirty[v // vb] = True

    n = grp.graph.n_real
    if semiring == PLUS_TIMES:
        if symmetrize:
            # the view row of u is raw-out ∪ raw-in: no cheap row diff —
            # recompute the deltas exactly with one full matvec instead
            inval.full_reseed_plus_times(grp)
            stats["reseed_num"] += grp.num_active * n
        else:
            u_idx, dst_idx, dw = [], [], []
            for u in sorted({u for u, _ in pairs}):
                row: Dict[int, float] = {}
                for vv, ww in zip(*csr_old.row(u)):
                    w_o = _norm_weight(float(ww), u, normalize, deg_o)
                    row[int(vv)] = -w_o
                for vv, ww in zip(*csr_new.row(u)):
                    w_n = _norm_weight(float(ww), u, normalize, deg_n)
                    row[int(vv)] = row.get(int(vv), 0.0) + w_n
                for vv, d in row.items():
                    if d != 0.0:
                        u_idx.append(u)
                        dst_idx.append(vv)
                        dw.append(d)
                        dirty[vv // vb] = True
            inval.adjust_plus_times(grp, np.asarray(u_idx, np.int64),
                                    np.asarray(dst_idx, np.int64),
                                    np.asarray(dw, np.float32))
    else:
        relax, seeds = [], []
        for (u, v) in pairs:
            wo, wn = old_w[(u, v)], new_w[(u, v)]
            if wn is not None and (wo is None or wn <= wo):
                if wo is None or wn < wo:
                    relax.append(u)        # monotone: re-activate, no reseed
            elif wo is not None:
                seeds.append(v)            # break: support-test downstream
        inval.reactivate_sources(grp, relax)
        if seeds:
            src, dst, w = _view_edges(csr_new, normalize, symmetrize)
            fwd = _csr_arrays(n, src, dst, w)
            rev = _csr_arrays(n, dst, src, w)
            exact = bool(len(w) == 0 or w.min() > 0.0)
            reseeded, union = inval.reseed_min_plus(grp, fwd, rev, seeds,
                                                    exact)
            stats["reseed_num"] += reseeded
            for b in np.unique(np.nonzero(union)[0] // vb):
                dirty[b] = True
    stats["reseed_den"] += grp.num_active * n


# ---------------------------------------------------------------------------
# the session entry point
# ---------------------------------------------------------------------------


def apply_updates_to_session(sess, batch: UpdateBatch) -> StreamStats:
    if sess._csr is None:
        raise ValueError(
            "apply_updates needs the session-owned CSRGraph (sessions "
            "adopted from a legacy ConcurrentRun have none)")
    if not isinstance(batch, UpdateBatch):
        raise TypeError(f"expected an UpdateBatch, got {type(batch)}")
    if not sess.groups:
        # no views yet: just advance the CSR — the first submit builds
        # its view from the updated graph
        sess._csr = apply_to_csr(sess._csr, batch)
        sess._stream_pending["updates_applied"] += len(batch)
        return StreamStats(len(batch), 0, 0.0, 0)
    csr_old = sess._csr
    csr_new = apply_to_csr(csr_old, batch)
    sess._csr = csr_new
    bn = sess.scheduler.num_blocks
    dirty = np.zeros(bn, dtype=bool)
    stats = {"reseed_num": 0, "reseed_den": 0, "compacted": 0}
    with sess.trace.span("apply_updates", cat="stream", updates=len(batch)):
        for grp in sess.view_groups():
            _apply_to_group(sess, grp, batch, csr_old, csr_new, dirty, stats)

    boost = np.where(dirty, np.float32(DIRTY_BOOST), np.float32(0.0))
    if sess._dirty_boost is None:
        sess._dirty_boost = boost
    else:
        sess._dirty_boost = np.maximum(sess._dirty_boost, boost)
    p = sess._stream_pending
    p["updates_applied"] += len(batch)
    p["dirty_blocks"] += int(dirty.sum())
    p["reseed_num"] += stats["reseed_num"]
    p["reseed_den"] += stats["reseed_den"]
    den = stats["reseed_den"]
    return StreamStats(
        updates_applied=len(batch),
        dirty_blocks=int(dirty.sum()),
        reseed_fraction=stats["reseed_num"] / den if den else 0.0,
        compacted_views=stats["compacted"])
