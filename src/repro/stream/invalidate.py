"""Dirty-state invalidation: re-seed just enough job state after an
edge-update batch that every job converges to the NEW graph's fixpoint.

Per semiring (the structure-aware split the Si paper argues for —
delta-driven recomputation touches only the affected region):

PLUS_TIMES — the delta-accumulative iteration conserves the invariant
    phi = v + (I - A)^{-1} d
(one push moves mass from d into v and scatters A*d back into d; phi is
the job's final answer from step 0).  A matrix change A -> A' therefore
has an EXACT local correction: the new deltas must satisfy
    v + (I - A')^{-1} d' = (I - A')^{-1} b      (b = the init deltas)
    =>  d' = b - (I - A') v = d + (A' - A) v    (using the invariant)
so we adjust d by the sparse difference matrix (A' - A) applied to the
current values — nonzero only on the updated rows.  Near the old
fixpoint this leaves large deltas exactly at update-affected vertices:
the dirty region emerges from the arithmetic, and the existing priority
machinery schedules it first.  (Symmetrized plus-times views have no
cheap row diff; `full_reseed_plus_times` recomputes d' = b - v + A'v
with one matvec over all tiles + overlay — exact, but stages every
block once.)

MIN_PLUS — monotone fast path vs support-test reseed:
  * relaxations (insert / reweight-down) cannot invalidate any distance:
    re-activate the source vertex (deltas[u] = min(deltas[u], values[u]))
    and let the ordinary push relax the new edge — no reseed;
  * breaks (delete / reweight-up) may orphan distances downstream.  The
    affected set is computed per job with the classic support test
    (Ramalingam–Reps style): a vertex is affected iff it cannot justify
    its current distance by its init value or by an UNaffected in-
    neighbour under the new weights.  Strictly positive view weights make
    the test exact; views with zero-weight edges (WCC's label
    propagation) fall back to conservative reachability from the broken
    edges' heads — mutual zero-weight support cycles would otherwise
    under-invalidate.  Affected vertices re-seed to their init state and
    their unaffected in-neighbours re-activate, so the region reconverges
    from correct boundary values.
"""

from __future__ import annotations

from collections import deque
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# plus-times
# ---------------------------------------------------------------------------


def adjust_plus_times(grp, u_idx: np.ndarray, dst_idx: np.ndarray,
                      dw: np.ndarray) -> None:
    """d += (A' - A) v via the row-difference COO (padded flat indices).

    Free slots hold all-zero values, so the adjustment is a no-op there —
    the whole padded job axis is updated in one dispatch."""
    if len(u_idx) == 0:
        return
    cap = grp.values.shape[0]
    shape = grp.deltas.shape
    v_flat = grp.values.reshape(cap, -1)
    d_flat = grp.deltas.reshape(cap, -1)
    vals = (grp.push_scale[:, None] * v_flat[:, jnp.asarray(u_idx)]
            * jnp.asarray(dw, jnp.float32)[None, :])
    grp.deltas = d_flat.at[:, jnp.asarray(dst_idx)].add(vals).reshape(shape)


def full_reseed_plus_times(grp) -> None:
    """Exact d' = b - v + A'v for every active job (symmetrized-view
    fallback: stages all tiles + overlay once)."""
    g, ov = grp.graph, grp.overlay
    bn, vb = g.num_blocks, g.block_size

    def matvec(x, scale):
        xs = x * scale
        contrib = jnp.einsum("bv,bkvw->bkw", xs, g.tiles)
        out = jnp.zeros_like(x).at[g.nbr_ids.reshape(-1)].add(
            contrib.reshape(-1, vb))
        if ov.capacity:
            sel = xs[jnp.arange(bn)[:, None], ov.src_u] * ov.w * ov.mask
            out = out.reshape(-1).at[ov.dst.reshape(-1)].add(
                sel.reshape(-1)).reshape(out.shape)
        return out

    mv = jax.vmap(matvec)(grp.values, grp.push_scale[:, None, None])
    init_d = [grp.algs[j].init(g)[1] if grp.active[j]
              else jnp.zeros((bn, vb), jnp.float32)
              for j in range(grp.capacity)]
    d_new = jnp.stack(init_d) - grp.values + mv
    act = jnp.asarray(grp.active)[:, None, None]
    grp.deltas = jnp.where(act, d_new, grp.deltas)


# ---------------------------------------------------------------------------
# min-plus
# ---------------------------------------------------------------------------


def reactivate_sources(grp, sources: List[int]) -> None:
    """Monotone fast path: pending = min(pending, current) at `sources`
    (padded ids) for every job at once (inert slots stay inf)."""
    if not sources:
        return
    vb = grp.graph.block_size
    s = np.asarray(sorted(set(sources)), dtype=np.int64)
    bs, us = s // vb, s % vb
    grp.deltas = grp.deltas.at[:, bs, us].min(grp.values[:, bs, us])


def _affected_support(n: int, fwd, rev, dist: np.ndarray,
                      init_v: np.ndarray, seeds: List[int]) -> np.ndarray:
    """Support-test affected set (positive weights): [n] bool.

    fwd/rev are (indptr, indices, weights) CSR/CSC of the NEW view.  A
    candidate re-enters the worklist whenever one of its supporters falls,
    so the deque order never under-invalidates (the affected set grows
    monotonically to its fixpoint)."""
    f_ptr, f_idx, f_w = fwd
    r_ptr, r_idx, r_w = rev
    affected = np.zeros(n, dtype=bool)
    queued = np.zeros(n, dtype=bool)
    cand = deque()
    for s in seeds:
        if not queued[s]:
            queued[s] = True
            cand.append(s)
    while cand:
        x = cand.popleft()
        queued[x] = False
        if affected[x] or not np.isfinite(dist[x]):
            continue
        if init_v[x] == dist[x]:     # self-supported (source / own label)
            continue
        lo, hi = r_ptr[x], r_ptr[x + 1]
        ins, ws = r_idx[lo:hi], r_w[lo:hi]
        ok = (~affected[ins]) & np.isfinite(dist[ins]) \
            & (dist[ins] + ws == dist[x])
        if ok.any():
            continue
        affected[x] = True
        lo, hi = f_ptr[x], f_ptr[x + 1]
        outs, ws = f_idx[lo:hi], f_w[lo:hi]
        dep = (~affected[outs]) & np.isfinite(dist[outs]) \
            & (dist[outs] == dist[x] + ws)
        for y in outs[dep]:
            if not queued[y]:
                queued[y] = True
                cand.append(int(y))
    return affected


def _affected_reachable(n: int, fwd, seeds: List[int]) -> np.ndarray:
    """Conservative fallback (zero-weight views): everything reachable
    from the broken edges' heads in the new view."""
    f_ptr, f_idx, _ = fwd
    affected = np.zeros(n, dtype=bool)
    stack = sorted(set(seeds))  # RPA007: hash order must not reach state
    for s in stack:
        affected[s] = True
    while stack:
        x = stack.pop()
        nbrs = f_idx[f_ptr[x]:f_ptr[x + 1]]
        new = nbrs[~affected[nbrs]]
        affected[new] = True
        stack.extend(int(y) for y in new)
    return affected


def reseed_min_plus(grp, fwd, rev, seeds: List[int],
                    exact: bool) -> Tuple[int, np.ndarray]:
    """Per active job: compute the affected set, re-seed it to the job's
    init state, re-activate its unaffected in-neighbours.  Returns
    (#re-seeded (job, vertex) pairs, union of affected vertices)."""
    g = grp.graph
    n, vb = g.n_real, g.block_size
    r_ptr, r_idx, _ = rev
    reseeded = 0
    union = np.zeros(n, dtype=bool)
    # one batched sync for all jobs (RPA002: np.asarray(grp.values[j])
    # inside the loop was one blocking transfer per active job)
    values_h = np.asarray(jax.device_get(grp.values))
    for j in range(grp.capacity):
        if not grp.active[j]:
            continue
        dist = values_h[j].reshape(-1)[:n]
        init_v, init_d = grp.algs[j].init(g)
        iv = np.asarray(init_v).reshape(-1)[:n]
        if exact:
            aff = _affected_support(n, fwd, rev, dist, iv, seeds)
        else:
            aff = _affected_reachable(n, fwd, seeds)
            aff &= iv != dist    # self-supported state needs no reseed
        idx = np.nonzero(aff)[0]
        if len(idx) == 0:
            continue
        reseeded += len(idx)
        union |= aff
        id_ = np.asarray(init_d).reshape(-1)[:n]
        bs, us = idx // vb, idx % vb
        grp.values = grp.values.at[j, bs, us].set(jnp.asarray(iv[idx]))
        grp.deltas = grp.deltas.at[j, bs, us].set(jnp.asarray(id_[idx]))
        # boundary re-activation: unaffected in-neighbours of the region
        # re-push their (still-correct) values into it
        nbrs = np.unique(np.concatenate(
            [r_idx[r_ptr[x]:r_ptr[x + 1]] for x in idx]
            or [np.zeros(0, np.int32)]))
        nbrs = nbrs[~aff[nbrs]]
        if len(nbrs):
            nb, nu = nbrs // vb, nbrs % vb
            grp.deltas = grp.deltas.at[j, nb, nu].min(
                grp.values[j, nb, nu])
    return reseeded, union
