from repro.serve.engine import ServeEngine
from repro.serve.concurrent import ConcurrentServeScheduler, RequestStream

__all__ = ["ServeEngine", "ConcurrentServeScheduler", "RequestStream"]
