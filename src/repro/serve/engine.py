"""Serving engine: jit'd prefill + decode with donated KV caches."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.model import LM


class ServeEngine:
    def __init__(self, model: LM, params, *, max_len: int = 1024):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill, donate_argnums=(2,))
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def new_cache(self, batch: int):
        return self.model.init_cache(batch=batch, max_len=self.max_len)

    def prefill(self, tokens, cache, patch_embeds=None):
        # one cached jitted prefill serves both arities (separate trace
        # entries, same wrapper) — a fresh jax.jit here would retrace per
        # call (RPA005)
        if patch_embeds is not None:
            return self._prefill(self.params, tokens, cache, patch_embeds)
        return self._prefill(self.params, tokens, cache)

    def decode(self, tokens, cache):
        return self._decode(self.params, tokens, cache)

    def generate(self, prompt_tokens: jnp.ndarray, n_steps: int,
                 *, greedy: bool = True, rng: Optional[Any] = None):
        """prompt [B, S] -> generated [B, n_steps] (greedy or sampled)."""
        b = prompt_tokens.shape[0]
        cache = self.new_cache(b)
        logits, cache = self.prefill(prompt_tokens, cache)
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(n_steps):
            out.append(tok)
            logits, cache = self.decode(tok, cache)
            if greedy:
                tok = jnp.argmax(logits[:, -1:] if logits.ndim == 3
                                 else logits[:, -1:], axis=-1).astype(jnp.int32)
                tok = tok.reshape(b, 1)
            else:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(
                    k, logits[:, -1]).reshape(b, 1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)
