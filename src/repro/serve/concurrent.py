"""Concurrent request scheduling for LM serving — the paper's two-level
scheduling applied one level up (DESIGN.md §4).

Mapping:
  graph job        <-> request stream (a tenant's stream of decode requests)
  graph block      <-> request group (requests sharing a prefix/bucket)
  block priority   <-> <n_waiting, mean_urgency> pair (Eq. 1, verbatim)
  CAJS             <-> one weights pass serves every admitted stream
                       (continuous batching: weights are the shared data)
  MPDS/global queue<-> admission: per-stream DO queues -> De_Gl_Priority

The scheduler runs on the SAME TwoLevelScheduler object as the graph
engine (repro.core.scheduler) — the point of the paper's "interlayer"
design is exactly that the policy core is data-structure-agnostic.

Admission is deterministic (streams visited in sorted id order, not dict
insertion order) and linear in the number of waiting requests (per-group
FIFO cursors instead of repeated list scans/removals).

Streams are HETEROGENEOUS, mirroring GraphSession's mixed-semiring jobs:
a stream declares a `family` (the workload kind it decodes — e.g. a
"pagerank"-style analytics stream next to an "sssp"-style route-query
stream, or chat next to batch summarization).  Families never partition
admission: request groups are shared data, so ONE global queue is
synthesized across every stream's DO queue regardless of family and one
weights pass serves the whole admitted batch — the serve-layer analogue of
one tile staging serving both semiring pushes.  `schedule_step` reports
the per-family admitted mix so operators can see the sharing.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core.scheduler import TwoLevelScheduler
from repro.obs.serve import ServeMetrics


@dataclasses.dataclass
class Request:
    stream_id: int
    group: int              # bucket (e.g. shared-prefix / SLA class)
    urgency: float          # higher = more urgent (deadline-derived)
    tokens_left: int


class RequestStream:
    """One tenant's queue of requests ('job').

    `family` tags the workload kind (the serve analogue of a graph job's
    semiring family); mixed-family streams share one admission pass."""

    def __init__(self, stream_id: int, family: str = "default"):
        self.stream_id = stream_id
        self.family = family
        self.waiting: List[Request] = []

    def add(self, req: Request):
        self.waiting.append(req)


class ConcurrentServeScheduler:
    """Admission control for each decode step over shared weights."""

    def __init__(self, n_groups: int, batch_budget: int, *,
                 alpha: float = 0.8, seed: int = 0, backend: str = "host",
                 metrics: bool = True, trace=None, slo=None):
        """backend selects where the two-level policy core computes its
        selection ("host" numpy / "device" jnp) — the SAME pluggable
        TwoLevelScheduler core as the graph engine, so the serve layer
        inherits the device analogues without any code of its own.

        `metrics` (default on — recording is an appended float per event)
        drives a ServeMetrics with per-stream wait time, service time and
        per-family queue depth; `trace` optionally takes a
        repro.obs.TraceRecorder to share a GraphSession's trace timeline
        (admissions land as instant events on its clock); `slo` optionally
        takes a repro.obs.SLOTracker that rides the same hooks (and the
        same first-seen stamps) for sliding-window SLIs judged against
        declared SLOTargets."""
        self.n_groups = n_groups
        self.batch_budget = batch_budget
        self.scheduler = TwoLevelScheduler(
            n_groups, max(1, batch_budget // 4), alpha=alpha, seed=seed,
            backend=backend)
        self.streams: Dict[int, RequestStream] = {}
        # per-family admitted counts of the most recent schedule_step
        self.last_admitted_by_family: Dict[str, int] = {}
        # pending dirty-group priority injection (see notify_group_update)
        self._dirty_boost: np.ndarray | None = None
        self.metrics: Optional[ServeMetrics] = \
            ServeMetrics() if metrics else None
        self.trace = trace
        self.slo = slo
        self._step_idx = 0

    # batch_budget is mutable between steps (schedule_step recomputes q from
    # it); alpha lives canonically on the scheduler, delegated for the same
    # mutability
    @property
    def alpha(self) -> float:
        return self.scheduler.alpha

    @alpha.setter
    def alpha(self, value: float) -> None:
        self.scheduler.alpha = value

    def add_stream(self, stream: RequestStream):
        self.streams[stream.stream_id] = stream

    def notify_group_update(self, groups, boost: float = 1e6) -> None:
        """Shared-data mutation hook — the serve-layer analogue of the
        graph engine's dirty-block injection (repro.stream): when the data
        behind some request groups changes (a prefix cache invalidated, a
        bucket's snapshot refreshed), those groups' P_mean is boosted on
        the NEXT schedule_step only, so every stream's waiting requests on
        updated groups are admitted first.  Groups with no waiting
        requests are unaffected (the boost multiplies into pairs with
        n_waiting > 0 only); repeated calls between steps accumulate by
        max."""
        vec = np.zeros(self.n_groups, dtype=np.float32)
        for g in groups:
            if not 0 <= int(g) < self.n_groups:
                raise ValueError(f"group {g} out of range")
            vec[int(g)] = boost
        self._dirty_boost = (vec if self._dirty_boost is None
                             else np.maximum(self._dirty_boost, vec))

    def _pairs(self, stream: RequestStream):
        """<Node_un, P_mean> per group for one stream (paper Eq. 1)."""
        n_un = np.zeros(self.n_groups, dtype=np.float32)
        p_sum = np.zeros(self.n_groups, dtype=np.float32)
        for r in stream.waiting:
            n_un[r.group] += 1
            p_sum[r.group] += r.urgency
        p_mean = np.where(n_un > 0, p_sum / np.maximum(n_un, 1), 0.0)
        return n_un, p_mean

    def schedule_step(self) -> List[Request]:
        """Pick request groups via the two-level policy, then admit requests
        from selected groups (all streams share them — CAJS) up to budget."""
        streams = [self.streams[sid] for sid in sorted(self.streams)]
        step = self._step_idx
        if self.metrics is not None or self.slo is not None:
            stamp = (self.metrics or self.slo).on_seen
            for stream in streams:          # stamp first-seen (wait clock)
                for r in stream.waiting:
                    stamp(r, step)
        node_un = np.zeros((len(streams), self.n_groups), dtype=np.float32)
        p_mean = np.zeros((len(streams), self.n_groups), dtype=np.float32)
        for i, stream in enumerate(streams):
            node_un[i], p_mean[i] = self._pairs(stream)
        if self._dirty_boost is not None:   # dirty-group injection, one step
            p_mean = p_mean + self._dirty_boost[None, :] * (node_un > 0)
            self._dirty_boost = None
        _, gq = self.scheduler.select(node_un, p_mean,
                                      q=max(1, self.batch_budget // 4))

        # one pass builds per-(stream, group) FIFO cursors; admission below
        # is O(total waiting), no list.remove scans
        buckets = [dict() for _ in streams]
        for si, stream in enumerate(streams):
            for i, r in enumerate(stream.waiting):
                buckets[si].setdefault(r.group, deque()).append(i)
        taken = [set() for _ in streams]
        admitted: List[Request] = []

        def admit(si: int, i: int) -> bool:
            """Admit waiting[i] unless the budget is already spent; returns
            True once the batch is full (a full batch never admits)."""
            if len(admitted) >= self.batch_budget:
                return True
            req = streams[si].waiting[i]
            admitted.append(req)
            taken[si].add(i)
            if self.metrics is not None:
                self.metrics.on_admit(req, step)
            if self.slo is not None:
                self.slo.on_admit(req, streams[si].family, step)
            return len(admitted) >= self.batch_budget

        full = False
        # round-robin across streams within selected groups (fair sharing)
        for g in gq:
            if full:
                break
            for si in range(len(streams)):
                fifo = buckets[si].get(int(g))
                if not fifo:
                    continue
                full = admit(si, fifo.popleft())
                if full:
                    break
        # fill remaining budget from any group (paper: finished jobs keep
        # computing low-priority blocks instead of idling)
        for si, stream in enumerate(streams):
            if full:
                break
            for i in range(len(stream.waiting)):
                if i in taken[si]:
                    continue
                full = admit(si, i)
                if full:
                    break
        by_family: Dict[str, int] = {}
        for si, stream in enumerate(streams):
            if taken[si]:
                stream.waiting = [r for i, r in enumerate(stream.waiting)
                                  if i not in taken[si]]
                by_family[stream.family] = (by_family.get(stream.family, 0)
                                            + len(taken[si]))
        self.last_admitted_by_family = by_family
        self._step_idx += 1
        if self.metrics is not None or self.slo is not None:
            depth: Dict[str, int] = {}      # queue pressure AFTER admission
            for stream in streams:
                depth[stream.family] = (depth.get(stream.family, 0)
                                        + len(stream.waiting))
            if self.metrics is not None:
                self.metrics.on_step(len(admitted), depth,
                                     self.scheduler.last_occupancy)
            if self.slo is not None:
                self.slo.on_step(step, depth)
        if self.trace is not None:
            self.trace.instant("serve.admit", cat="serve", tid=3,
                               step=step, admitted=len(admitted),
                               by_family=dict(by_family))
        return admitted

    def complete(self, req: Request, service_s: Optional[float] = None
                 ) -> None:
        """Report a request finished decoding; records service time (wall
        seconds since admission, or an explicit duration)."""
        if self.metrics is not None:
            self.metrics.on_complete(req, service_s)
        if self.slo is not None:
            stream = self.streams.get(req.stream_id)
            family = stream.family if stream is not None else "default"
            self.slo.on_complete(req, family, self._step_idx)
