"""Concurrent request scheduling for LM serving — the paper's two-level
scheduling applied one level up (DESIGN.md §4).

Mapping:
  graph job        <-> request stream (a tenant's stream of decode requests)
  graph block      <-> request group (requests sharing a prefix/bucket)
  block priority   <-> <n_waiting, mean_urgency> pair (Eq. 1, verbatim)
  CAJS             <-> one weights pass serves every admitted stream
                       (continuous batching: weights are the shared data)
  MPDS/global queue<-> admission: per-stream DO queues -> De_Gl_Priority

The scheduler reuses repro.core's CBP comparator, Function-2 selection and
global-queue synthesis unchanged — the point of the paper's "interlayer"
design is exactly that the policy is data-structure-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.do_select import do_select
from repro.core.global_q import global_queue


@dataclasses.dataclass
class Request:
    stream_id: int
    group: int              # bucket (e.g. shared-prefix / SLA class)
    urgency: float          # higher = more urgent (deadline-derived)
    tokens_left: int


class RequestStream:
    """One tenant's queue of requests ('job')."""

    def __init__(self, stream_id: int):
        self.stream_id = stream_id
        self.waiting: List[Request] = []

    def add(self, req: Request):
        self.waiting.append(req)


class ConcurrentServeScheduler:
    """Admission control for each decode step over shared weights."""

    def __init__(self, n_groups: int, batch_budget: int, *,
                 alpha: float = 0.8, seed: int = 0):
        self.n_groups = n_groups
        self.batch_budget = batch_budget
        self.alpha = alpha
        self.rng = np.random.default_rng(seed)
        self.streams: Dict[int, RequestStream] = {}

    def add_stream(self, stream: RequestStream):
        self.streams[stream.stream_id] = stream

    def _pairs(self, stream: RequestStream):
        """<Node_un, P_mean> per group for one stream (paper Eq. 1)."""
        n_un = np.zeros(self.n_groups)
        p_sum = np.zeros(self.n_groups)
        for r in stream.waiting:
            n_un[r.group] += 1
            p_sum[r.group] += r.urgency
        p_mean = np.where(n_un > 0, p_sum / np.maximum(n_un, 1), 0.0)
        return n_un, p_mean

    def schedule_step(self) -> List[Request]:
        """Pick request groups via the two-level policy, then admit requests
        from selected groups (all streams share them — CAJS) up to budget."""
        q = max(1, self.batch_budget // 4)
        queues = []
        for stream in self.streams.values():
            n_un, p_mean = self._pairs(stream)
            queues.append(do_select(n_un, p_mean, q, self.rng))
        gq = global_queue(queues, self.n_groups, q, self.alpha)

        admitted: List[Request] = []
        # round-robin across streams within selected groups (fair sharing)
        for g in gq:
            for stream in self.streams.values():
                if len(admitted) >= self.batch_budget:
                    return admitted
                for r in list(stream.waiting):
                    if r.group == int(g):
                        admitted.append(r)
                        stream.waiting.remove(r)
                        break
        # fill remaining budget from any group (paper: finished jobs keep
        # computing low-priority blocks instead of idling)
        for stream in self.streams.values():
            for r in list(stream.waiting):
                if len(admitted) >= self.batch_budget:
                    return admitted
                admitted.append(r)
                stream.waiting.remove(r)
        return admitted
