"""Data pipeline: deterministic, step-indexed, resumable.

SyntheticTokens    - step-seeded token stream (restart at step k reproduces
                     exactly the batch k; required by RestartManager).
PackedFileDataset  - memmap-backed binary token shards with sequence packing.
Prefetcher         - background-thread host->device prefetch (overlap input
                     pipeline with compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import numpy as np
import jax.numpy as jnp


class SyntheticTokens:
    """Deterministic synthetic LM batches; batch k depends only on (seed, k)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 n_codebooks: int = 0, patch_prefix: int = 0,
                 d_model: int = 0, seed: int = 0):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.n_codebooks = n_codebooks
        self.patch_prefix = patch_prefix
        self.d_model = d_model
        self.seed = seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        text = self.seq_len - self.patch_prefix
        if self.n_codebooks:
            tok = rng.integers(0, self.vocab_size,
                               (self.batch, text, self.n_codebooks))
        else:
            tok = rng.integers(0, self.vocab_size, (self.batch, text))
        out = {"tokens": jnp.asarray(tok, jnp.int32)}
        if self.patch_prefix:
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((self.batch, self.patch_prefix,
                                     self.d_model)), jnp.bfloat16)
        return out


class PackedFileDataset:
    """Binary uint16/uint32 token shards, packed into fixed-length sequences.

    File layout: flat token stream; sequence k = tokens[k*S : (k+1)*S].
    Deterministic shuffling by step-seeded permutation over sequence index.
    """

    def __init__(self, path: str, batch: int, seq_len: int, *,
                 dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.batch = batch
        self.seq_len = seq_len
        self.n_seqs = len(self.tokens) // seq_len
        self.seed = seed
        if self.n_seqs < batch:
            raise ValueError("dataset smaller than one batch")

    @staticmethod
    def write(path: str, tokens: np.ndarray, dtype=np.uint16):
        tokens.astype(dtype).tofile(path)

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        idx = rng.choice(self.n_seqs, size=self.batch, replace=False)
        seqs = np.stack([
            self.tokens[i * self.seq_len:(i + 1) * self.seq_len]
            for i in idx])
        return {"tokens": jnp.asarray(seqs.astype(np.int32))}


class Prefetcher:
    """Wraps a step-indexed data fn with a background prefetch thread."""

    def __init__(self, data_fn: Callable[[int], dict], depth: int = 2):
        self.data_fn = data_fn
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next_submit = 0
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = False

    def start(self, from_step: int = 0):
        self._next_submit = from_step
        self._stop = False

        def work():
            while not self._stop:
                step = self._next_submit
                batch = self.data_fn(step)
                self.q.put((step, batch))
                with self._lock:
                    self._next_submit += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return self

    def get(self, step: int) -> dict:
        while True:
            got_step, batch = self.q.get()
            if got_step == step:
                return batch
            # restart skew: drop stale prefetches

    def stop(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
