from repro.data.pipeline import SyntheticTokens, PackedFileDataset, Prefetcher

__all__ = ["SyntheticTokens", "PackedFileDataset", "Prefetcher"]
