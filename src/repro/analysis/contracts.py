"""Compiled-artifact contract checker.

Lowers the REAL device superstep (`core.policy.build_device_step`) for a
policy on a live session — the same program `_run_device` dispatches —
and asserts contracts on the compiled HLO and the run it drives:

  one-sync        the inf-cadence program is one fused while-loop with no
                  host callbacks (infeed/outfeed/send/recv, host
                  custom-calls): a whole run costs exactly ONE blocking
                  device->host transfer, and a real run's
                  RunMetrics.host_syncs confirms it.
  no-f64          nothing in the program (or the host-backend pairs/counts
                  reductions) promotes to f64 — x64 is off, so an f64 in
                  the HLO means someone flipped it on and doubled traffic.
  vmem-budget     the Pallas per-grid-cell footprints (`mj_spmm`: tile +
                  temp + 2 job stripes; `fused_superstep`: pair tile +
                  per-job state stripes + pair counters;
                  `priority_pairs`: one Vb stripe + counters) fit the
                  shared `kernels.common.VMEM_BUDGET` and the ~16 MB/core
                  hardware ceiling for every view's Vb.
  tile-bytes      a measured run's `RunMetrics.tile_pair_loads` — real
                  nonzero (src, dst) block pairs moved, priced at Vb^2
                  fp32 each — never exceeds the HBM traffic the compiled
                  artifact can account for: the static body estimate
                  (hlo_analysis.estimate_hbm_bytes) scaled by supersteps
                  executed, since the convergence loop's trip count is a
                  runtime argument the estimate cannot see.
  push-flops      the plus-times push is MXU-shaped: the lowered program
                  carries real dot flops (parse_dot_flops > 0), i.e. the
                  semiring product did not degrade to scalar gathers.

`check_all()` builds a small canonical session (one plus-times + one
min-plus view, the same shape the regression tests pin) and sweeps the
policy matrix; the CLI exposes it as ``python -m repro.analysis
--contracts`` and tests/test_analysis_contracts.py locks the checker
itself (including that a deliberately broken 1-sync program is flagged).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import List

from repro.launch import hlo_analysis as H

_HOST_CALLBACK_RE = re.compile(
    r"\b(infeed|outfeed|send(?:-done)?|recv(?:-done)?)\(|"
    r"custom-call[^\n]*(?:xla_python_cpu_callback|HostCompute|"
    r"annotate_device_placement[^\n]*host)")

#: hardware ceiling per core (pallas guide: ~16 MB VMEM on current TPUs)
VMEM_HW_LIMIT = 16 * 2**20


@dataclasses.dataclass
class ContractResult:
    name: str
    ok: bool
    detail: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def lower_device_superstep(sess, policy, max_steps: int = 1024):
    """Lower the exact program `_run_device` would dispatch for `policy`
    on `sess`; returns (compiled, hlo_text).  Mirrors the driver's state
    construction — if the driver grows a carry element this must grow
    with it (tests pin the argument shapes)."""
    import jax
    import jax.numpy as jnp
    from repro.core.policy import build_device_step
    from repro.obs.telemetry import device_buffers

    groups = sess.view_groups()
    step_fn = build_device_step(policy, sess)
    bn = sess.scheduler.num_blocks
    tel_cfg = getattr(sess, "telemetry", None)
    tel_cap = int(tel_cfg.capacity) if tel_cfg is not None else 0
    state = (jnp.int32(0),
             tuple(g.values for g in groups),
             tuple(g.deltas for g in groups),
             jnp.float32(0), jnp.float32(0), jnp.float32(0),
             tuple(jnp.zeros(g.capacity, jnp.int32) for g in groups),
             jnp.zeros(bn, jnp.float32),
             device_buffers(tel_cap, len(groups)) if tel_cap else ())
    scales = tuple(g.push_scale for g in groups)
    tiles = tuple(g.graph.tiles for g in groups)
    nbrs = tuple(g.graph.nbr_ids for g in groups)
    ovs = tuple(g.overlay for g in groups)
    prs = tuple(sess._pair_data(g) for g in groups)
    key = jax.random.PRNGKey(sess.seed)
    lowered = step_fn.lower(state, scales, tiles, nbrs, ovs, prs,
                            jnp.int32(max_steps), key)
    compiled = lowered.compile()
    return compiled, compiled.as_text()


def host_callback_sites(hlo: str) -> List[str]:
    return [m.group(0) for m in _HOST_CALLBACK_RE.finditer(hlo)]


def check_one_sync(hlo: str, *, expect_while: bool = True
                   ) -> ContractResult:
    """Static half of the 1-sync invariant: the inf-cadence program keeps
    the whole run inside one while-loop and surfaces NO mid-flight host
    hops — the only transfer left is the driver's single device_get of
    the result tuple."""
    sites = host_callback_sites(hlo)
    has_while = " while(" in hlo or "=while(" in hlo.replace(" ", "")
    if sites:
        return ContractResult(
            "one-sync", False,
            f"{len(sites)} host-callback site(s) in the superstep HLO "
            f"(first: {sites[0][:60]!r}) — each is a hidden sync")
    if expect_while and not has_while:
        return ContractResult(
            "one-sync", False,
            "inf-cadence program lowered without a while-loop: the "
            "convergence loop fell back to the host (one sync per "
            "superstep)")
    return ContractResult(
        "one-sync", True,
        "single fused while-loop, zero host callbacks" if expect_while
        else "zero host callbacks")


def check_no_f64(hlo: str, label: str = "superstep") -> ContractResult:
    n = hlo.count("f64[")
    if n:
        line = next(ln for ln in hlo.splitlines() if "f64[" in ln)
        return ContractResult(
            "no-f64", False,
            f"{n} f64 tensor(s) in the {label} HLO (first: "
            f"{line.strip()[:80]!r})")
    return ContractResult("no-f64", True, f"no f64 tensors in {label}")


def mj_spmm_vmem_bytes(capacity: int, vb: int) -> int:
    """Per-grid-cell VMEM for the mj_spmm kernel at job count `capacity`:
    tile [Vb,Vb] + min-plus temp [Vb,Vb] + in/out job stripes [Jb,Vb],
    fp32 — the same arithmetic `_pick_job_block` budgets against."""
    from repro.kernels.mj_spmm.ops import _pick_job_block
    jb = _pick_job_block(capacity, vb)
    return 2 * vb * vb * 4 + 2 * jb * vb * 4


def mj_spmm_hbm_fetch_bytes(q: int, k: int, capacity: int, vb: int) -> int:
    """Input HBM bytes one mj_spmm dispatch actually fetches, counted per
    grid step.  Grid (q, K, J/Jb) with jt INNERMOST: the adjacency tile's
    index (i, kk) is unchanged across the inner jt sweep (one fetch per
    (i, k) — the CAJS revisit), but the d-chunk's index (i, jt) changes
    at every grid step, so d is re-fetched K times per job chunk — q * K
    * (J/Jb) fetches, NOT one per (i, jt).  Only the J/Jb == 1 degenerate
    grid keeps d resident across k (its index is then constant per i)."""
    from repro.kernels.mj_spmm.ops import _pick_job_block
    jb = _pick_job_block(capacity, vb)
    jt = capacity // jb
    d_fetches = q * jt if jt == 1 else q * k * jt
    tile_fetches = q * k
    return d_fetches * jb * vb * 4 + tile_fetches * vb * vb * 4


def fused_superstep_vmem_bytes(capacity: int, vb: int,
                               semiring: str) -> int:
    """Per-grid-cell VMEM for the fused megakernel: pair tile [Vb,Vb] +
    the per-job [Jb,Vb] state stripes (plus-times: d/base/accumulator;
    min-plus adds values in+out and the candidate scratch) + the two
    [Jb] pair counters, fp32 — the same arithmetic its `_pick_job_block`
    budgets against."""
    from repro.kernels.fused_superstep.ops import _pick_job_block
    jb = _pick_job_block(capacity, vb, semiring)
    stripes = 3 if semiring == "plus_times" else 6
    return vb * vb * 4 + jb * (stripes * vb + 2) * 4


def priority_pairs_vmem_bytes(vb: int) -> int:
    """Per-cell footprint of the priority_pairs kernel: one [Vb] priority
    stripe plus the (node_un, p_sum) accumulator pair, fp32."""
    return (vb + 2) * 4


def check_vmem_budget(sess) -> List[ContractResult]:
    from repro.kernels.common import VMEM_BUDGET
    out: List[ContractResult] = []
    for g in sess.view_groups():
        vb = g.graph.block_size
        spmm = mj_spmm_vmem_bytes(g.capacity, vb)
        pairs = priority_pairs_vmem_bytes(vb)
        sem = getattr(g, "semiring", None) or "plus_times"
        if sem not in ("plus_times", "min_plus"):
            sem = "plus_times"
        fused = fused_superstep_vmem_bytes(g.capacity, vb, sem)
        budget = min(VMEM_BUDGET, VMEM_HW_LIMIT)
        ok = spmm <= budget and pairs <= budget and fused <= budget
        out.append(ContractResult(
            "vmem-budget", ok,
            f"view {g.key!r} Vb={vb}: mj_spmm {spmm} B, fused_superstep "
            f"{fused} B, priority_pairs {pairs} B vs budget {budget} B"))
    return out


def check_tile_bytes(hlo: str, metrics, vb: int) -> ContractResult:
    """Cross-check the measured schedule against the compiled artifact:
    the real adjacency bytes the run moved — RunMetrics.tile_pair_loads
    nonzero (src, dst) block pairs at Vb^2 fp32 each, the sparse
    BlockPairs accounting — must fit within the HBM traffic the HLO can
    generate.  The lowered superstep's convergence while-loop has a
    DYNAMIC trip count (max_steps is a runtime argument), so the static
    estimate counts the loop body — one superstep — once; the program's
    accountable traffic is therefore body-estimate x supersteps
    executed.  (For finite-K cadences whose constant-trip scan is
    already folded into the estimate this over-allows by K, which only
    loosens an upper bound.)  Falls back to the coarser tile_loads /
    host_syncs for metrics predating the pair accounting."""
    n = int(getattr(metrics, "tile_pair_loads", 0) or metrics.tile_loads)
    staged = n * vb * vb * 4
    steps = int(getattr(metrics, "supersteps", 0) or metrics.host_syncs)
    capacity = H.estimate_hbm_bytes(hlo) * max(1, steps)
    ok = staged <= capacity
    return ContractResult(
        "tile-bytes", ok,
        f"measured pair loads={n} -> {staged} B real adjacency bytes "
        f"staged vs {capacity} B HLO-accountable HBM traffic "
        f"({steps} supersteps)")


def check_push_flops(hlo: str) -> ContractResult:
    flops = H.parse_dot_flops(hlo)
    ok = flops > 0
    return ContractResult(
        "push-flops", ok,
        f"{flops:.3g} dot flops in the lowered superstep"
        + ("" if ok else " — the plus-times push lost its dot (gather/"
                         "scalar fallback)"))


def _canonical_session(seed: int = 0, use_pallas: bool = False):
    """Small two-view session (plus-times PageRank + min-plus SSSP) — the
    same canonical shape the regression suites pin.  use_pallas=True
    routes the push through the fused superstep megakernel (interpret
    mode off-TPU), lowering the Pallas path into the checked program."""
    from repro.algorithms import PageRank, SSSP
    from repro.core import GraphSession
    from repro.graph import rmat_graph
    sess = GraphSession(rmat_graph(200, 5, seed=7), 32, capacity=2,
                        seed=seed, use_pallas=use_pallas)
    sess.submit(PageRank())
    sess.submit(SSSP(source=0))
    return sess


def check_device_contracts(sess=None, policy=None,
                           run_budget: int = 2000) -> List[ContractResult]:
    """The inf-cadence device contract bundle for one session/policy."""
    from repro.core import TwoLevel
    if sess is None:
        sess = _canonical_session()
    if policy is None:
        policy = TwoLevel(backend="device", steps_per_sync=math.inf)
    expect_while = policy.steps_per_sync == math.inf
    _, hlo = lower_device_superstep(sess, policy)
    results = [check_one_sync(hlo, expect_while=expect_while),
               check_no_f64(hlo)]
    results.extend(check_vmem_budget(sess))
    results.append(check_push_flops(hlo))
    m = sess.run(policy, run_budget)
    vb = sess.view_groups()[0].graph.block_size
    results.append(check_tile_bytes(hlo, m, vb))
    if expect_while:
        ok = m.converged and m.host_syncs == 1
        results.append(ContractResult(
            "one-sync-runtime", ok,
            f"run: converged={m.converged} host_syncs={m.host_syncs} "
            f"(contract: converged with exactly 1)"))
    return results


def check_host_programs(sess=None) -> List[ContractResult]:
    """Host-backend contracts: the per-group pairs/counts reductions the
    host driver dispatches each superstep carry no f64 and no host
    callbacks (they are pure device reductions; the driver's device_get
    of their outputs is the one sanctioned sync)."""
    if sess is None:
        sess = _canonical_session()
    out: List[ContractResult] = []
    for g in sess.view_groups():
        for label, fn in (("pairs", sess._pairs_fn(g)),
                          ("counts", sess._counts_fn(g))):
            hlo = fn.lower(g.values, g.deltas).compile().as_text()
            out.append(check_no_f64(hlo, f"{label}[{g.key!r}]"))
            sites = host_callback_sites(hlo)
            out.append(ContractResult(
                f"host-{label}-pure", not sites,
                f"view {g.key!r}: {len(sites)} host-callback site(s)"))
    return out


def check_all() -> List[ContractResult]:
    """The CI sweep: device inf-cadence + K=4 cadence + host programs,
    then the same inf-cadence bundle with use_pallas=True — the fused
    superstep megakernel lowered into the one-while-loop program (VMEM
    budget, zero host callbacks, pair-based tile bytes)."""
    from repro.core import TwoLevel
    results: List[ContractResult] = []
    sess = _canonical_session()
    results += check_device_contracts(
        sess, TwoLevel(backend="device", steps_per_sync=math.inf))
    sess2 = _canonical_session()
    results += check_device_contracts(
        sess2, TwoLevel(backend="device", steps_per_sync=4))
    results += check_host_programs(_canonical_session())
    results += check_device_contracts(
        _canonical_session(use_pallas=True),
        TwoLevel(backend="device", steps_per_sync=math.inf))
    return results
