"""Baseline suppression for the lint CLI.

A baseline is a JSON file of finding fingerprints the team has accepted
(grandfathered debt, deliberate exceptions too broad for `# noqa`). The CI
gate runs with an *empty* baseline — the file exists so a future PR that
must land with a known finding can do so without weakening a rule.

Fingerprints are stable under reformatting and line churn:

    "<rule>:<relpath>:<sha1(normalized snippet)[:12]>#<occurrence>"

The normalized snippet is the finding's source line with whitespace
collapsed; the occurrence index disambiguates identical lines in one file.
Line numbers deliberately do not participate.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Dict, Iterable, List, Tuple

from repro.analysis.lint import Finding

_WS = re.compile(r"\s+")


def _relpath(path: str) -> str:
    rel = os.path.relpath(path)
    return rel.replace(os.sep, "/")


def fingerprint(finding: Finding, occurrence: int = 0) -> str:
    normalized = _WS.sub(" ", finding.snippet).strip()
    digest = hashlib.sha1(normalized.encode("utf-8")).hexdigest()[:12]
    return (f"{finding.rule}:{_relpath(finding.path)}:{digest}"
            f"#{occurrence}")


def fingerprints(findings: Iterable[Finding]) -> List[Tuple[Finding, str]]:
    """Pair each finding with its occurrence-indexed fingerprint."""
    seen: Dict[str, int] = {}
    out: List[Tuple[Finding, str]] = []
    for f in findings:
        base = fingerprint(f, 0).rsplit("#", 1)[0]
        idx = seen.get(base, 0)
        seen[base] = idx + 1
        out.append((f, f"{base}#{idx}"))
    return out


def load(path: str) -> frozenset:
    """Read a baseline file; tolerates the two shapes we ever wrote:
    a bare JSON list of fingerprints, or {"fingerprints": [...]}."""
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if isinstance(data, dict):
        data = data.get("fingerprints", [])
    if not isinstance(data, list):
        raise ValueError(f"baseline {path}: expected a JSON list or "
                         f"{{'fingerprints': [...]}}, got {type(data).__name__}")
    return frozenset(str(x) for x in data)


def write(path: str, findings: Iterable[Finding]) -> int:
    """Snapshot current findings as the new baseline; returns the count."""
    fps = sorted(fp for _, fp in fingerprints(findings))
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"fingerprints": fps}, fh, indent=2)
        fh.write("\n")
    return len(fps)


def filter_findings(findings: Iterable[Finding],
                    baseline: frozenset) -> List[Finding]:
    """Drop findings whose fingerprint appears in the baseline."""
    return [f for f, fp in fingerprints(findings) if fp not in baseline]
