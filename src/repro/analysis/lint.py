"""AST lint engine for the repro codebase's JAX invariants.

The runtime can only observe a broken contract after the fact (a retrace,
a silent host sync, an f64 program); these rules check them at the SOURCE
level — the same "interlayer between data and systems" stance the paper
takes for scheduling, applied to correctness contracts.  The engine is
stdlib-`ast` only (no new dependencies): one parse per file, one shared
`FileContext` carrying the facts every rule needs (which functions are
jit-traced, which names hold device values, where `# noqa` comments sit),
and a registry of small single-invariant rules (repro.analysis.rules).

Suppression is two-level:
  * inline  — ``# noqa`` or ``# noqa: RPA002[,RPA004]`` on the flagged line
              (for intentional violations, e.g. a sanctioned host sync);
  * baseline — a committed JSON file of accepted fingerprints
              (repro.analysis.baseline) so the CI gate can be adopted
              before every legacy finding is fixed.  The acceptance bar
              for this repo is an EMPTY baseline.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: attribute names that hold device-resident arrays in this codebase
#: (ViewGroup / BlockedGraph / TileOverlay fields).  Rules use them to
#: recognize device values behind host-side containers, where pure
#: dataflow analysis cannot see a dtype.
DEVICE_ATTRS = frozenset({
    "values", "deltas", "tiles", "nbr_ids", "push_scale", "overlay",
})

#: module roots whose calls produce device values / trace.
JAX_ROOTS = frozenset({"jnp", "jax", "lax", "pl", "pltpu"})

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z]{3}\d{3}"
                      r"(?:\s*,\s*[A-Z]{3}\d{3})*))?", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # rule id, e.g. "RPA002"
    path: str          # as given to the engine (normalized to "/")
    line: int          # 1-indexed
    col: int           # 0-indexed
    message: str
    snippet: str = ""  # the stripped source line (fingerprint input)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"{self.rule} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class LintRule:
    """Base rule: subclasses set `rule_id`/`name`/`invariant` and implement
    `check(ctx) -> Iterable[Finding]`."""

    rule_id = "RPA000"
    name = "abstract"
    #: one-line statement of the invariant the rule protects (docs + CLI)
    invariant = ""

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "FileContext", node: ast.AST,
                message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = (ctx.lines[line - 1].strip()
                   if 0 < line <= len(ctx.lines) else "")
        return Finding(rule=self.rule_id, path=ctx.path, line=line,
                       col=getattr(node, "col_offset", 0),
                       message=message, snippet=snippet)


# ---------------------------------------------------------------------------
# shared AST facts
# ---------------------------------------------------------------------------


def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ("np.random.seed"), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a call's callee, else None."""
    return attr_chain(node.func) if isinstance(node, ast.Call) else None


def _chain_root(chain: Optional[str]) -> Optional[str]:
    return chain.split(".", 1)[0] if chain else None


def is_jax_rooted(node: ast.AST) -> bool:
    """True when the expression is a call/attribute rooted at jnp/jax/lax."""
    chain = attr_chain(node.func if isinstance(node, ast.Call) else node)
    return _chain_root(chain) in JAX_ROOTS


def mentions_device_value(node: ast.AST, device_names: Set[str]) -> bool:
    """True when any sub-expression reads a known device value: a call or
    attribute rooted at jnp/jax/lax, an attribute in DEVICE_ATTRS, or a
    name locally assigned from such an expression."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            if sub.attr in DEVICE_ATTRS:
                return True
            if _chain_root(attr_chain(sub)) in JAX_ROOTS:
                return True
        elif isinstance(sub, ast.Name) and sub.id in device_names:
            return True
    return False


class _ParentAnnotator(ast.NodeVisitor):
    def visit(self, node):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]
        super().generic_visit(node)


def parents(node: ast.AST) -> Iterable[ast.AST]:
    while True:
        node = getattr(node, "_parent", None)
        if node is None:
            return
        yield node


_TRACE_TAKERS = {
    # callables whose function-valued arguments are traced
    "jax.jit", "jit", "pjit",
    "jax.lax.scan", "lax.scan",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.map", "lax.map",
    "jax.vmap", "vmap", "jax.pmap",
    "jax.grad", "jax.value_and_grad", "jax.checkpoint", "jax.remat",
    "jax.lax.switch", "lax.switch",
}


def _jit_seeds(tree: ast.Module) -> Set[str]:
    """Function names that enter a trace: jit-decorated, or passed by name
    into jax.jit / lax control flow / vmap / grad anywhere in the module."""
    seeds: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                chain = attr_chain(dec) or call_chain(dec) or ""
                if chain in ("jax.jit", "jit", "pjit"):
                    seeds.add(node.name)
                elif (isinstance(dec, ast.Call)
                      and call_chain(dec) in ("functools.partial", "partial")
                      and dec.args
                      and attr_chain(dec.args[0]) in ("jax.jit", "jit")):
                    seeds.add(node.name)
        elif isinstance(node, ast.Call):
            chain = call_chain(node)
            if chain in _TRACE_TAKERS:
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        seeds.add(arg.id)
    return seeds


def _local_call_graph(tree: ast.Module) -> Dict[str, Set[str]]:
    """function name -> names of module/nested functions it calls."""
    defs = {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    graph: Dict[str, Set[str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        callees: Set[str] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id in defs:
                callees.add(sub.func.id)
            # a nested def referenced by bare name (e.g. handed to a
            # control-flow primitive) counts as reached from its encloser
            elif isinstance(sub, ast.Name) and sub.id in defs:
                callees.add(sub.id)
        graph[node.name] = callees - {node.name}
    return graph


def jitted_functions(tree: ast.Module) -> Set[str]:
    """Names of functions whose bodies are jit-traced: the decorated /
    trace-taker-passed seeds plus everything they reach through local
    calls (a helper called from a jitted body is traced too)."""
    seeds = _jit_seeds(tree)
    graph = _local_call_graph(tree)
    reached, work = set(seeds), list(seeds)
    while work:
        for callee in graph.get(work.pop(), ()):
            if callee not in reached:
                reached.add(callee)
                work.append(callee)
    return reached


class FileContext:
    """Everything rules need about one source file, computed once."""

    def __init__(self, path: str, source: str):
        self.path = path.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        _ParentAnnotator().visit(self.tree)
        self.jitted: Set[str] = jitted_functions(self.tree)
        self._noqa: Dict[int, Optional[Set[str]]] = {}
        for i, line in enumerate(self.lines, 1):
            m = _NOQA_RE.search(line)
            if m:
                codes = m.group("codes")
                self._noqa[i] = (None if codes is None else
                                 {c.strip().upper()
                                  for c in codes.split(",")})

    # -- helpers -------------------------------------------------------------

    def functions(self) -> List[ast.FunctionDef]:
        return [n for n in ast.walk(self.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    def in_jitted_function(self, node: ast.AST) -> bool:
        for p in parents(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return p.name in self.jitted
        return False

    def enclosing_loop(self, node: ast.AST) -> Optional[ast.AST]:
        """Nearest enclosing host loop (incl. comprehensions — a per-
        element sync in a listcomp is the same bug as in a for loop),
        stopping at a function boundary."""
        for p in parents(node):
            if isinstance(p, (ast.For, ast.While, ast.ListComp,
                              ast.SetComp, ast.DictComp,
                              ast.GeneratorExp)):
                return p
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return None
        return None

    def local_device_names(self, fn: ast.AST) -> Set[str]:
        """Names assigned (anywhere in `fn`) from a jnp/jax/lax-rooted call
        or from a DEVICE_ATTRS attribute read."""
        names: Set[str] = set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                tgt, val = sub.targets[0], sub.value
                src = val
                while isinstance(src, ast.Subscript):
                    src = src.value
                # jax.device_get produces HOST values: its targets are
                # the sanctioned sync results, not device values
                if isinstance(src, ast.Call) and call_chain(src) in (
                        "jax.device_get", "device_get"):
                    continue
                hit = (is_jax_rooted(src)
                       or (isinstance(src, ast.Attribute)
                           and src.attr in DEVICE_ATTRS))
                if hit:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
                    elif isinstance(tgt, ast.Tuple):
                        names.update(e.id for e in tgt.elts
                                     if isinstance(e, ast.Name))
        return names

    def suppressed(self, finding: Finding) -> bool:
        codes = self._noqa.get(finding.line, False)
        if codes is False:
            return False
        return codes is None or finding.rule in codes


# ---------------------------------------------------------------------------
# running
# ---------------------------------------------------------------------------


def lint_source(path: str, source: str,
                rules: Sequence[LintRule]) -> List[Finding]:
    """All (non-inline-suppressed) findings for one file."""
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [Finding(rule="RPA999", path=path, line=e.lineno or 1,
                        col=(e.offset or 1) - 1,
                        message=f"syntax error: {e.msg}")]
    out: List[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            if not ctx.suppressed(f):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def lint_paths(paths: Sequence[str],
               rules: Optional[Sequence[LintRule]] = None) -> List[Finding]:
    """Lint every .py file under `paths` (files or directories)."""
    import os

    from repro.analysis.rules import default_rules
    rules = list(rules) if rules is not None else default_rules()
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    findings: List[Finding] = []
    for fp in files:
        with open(fp, "r", encoding="utf-8") as fh:
            findings.extend(lint_source(fp, fh.read(), rules))
    return findings
