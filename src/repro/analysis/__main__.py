"""``python -m repro.analysis`` — the static-analysis CI gate.

Usage:
    python -m repro.analysis src/                 # lint, human output
    python -m repro.analysis src/ --json report.json
    python -m repro.analysis src/ --baseline .analysis-baseline.json
    python -m repro.analysis src/ --write-baseline .analysis-baseline.json
    python -m repro.analysis --list-rules
    python -m repro.analysis --contracts          # lower + check HLO

Exit status: 0 when no unbaselined findings (and, with ``--contracts``,
all compiled-artifact contracts hold); 1 otherwise; 2 on usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.analysis import baseline as baseline_mod
from repro.analysis.lint import Finding, lint_paths
from repro.analysis.rules import default_rules


def _report(findings: List[Finding], rules, contracts=None) -> dict:
    return {
        "tool": "repro.analysis",
        "rules": [{"id": r.rule_id, "name": r.name,
                   "invariant": r.invariant} for r in rules],
        "findings": [
            dict(f.to_dict(), fingerprint=fp)
            for f, fp in baseline_mod.fingerprints(findings)
        ],
        "counts": {r.rule_id: sum(1 for f in findings
                                  if f.rule == r.rule_id)
                   for r in rules},
        **({"contracts": contracts} if contracts is not None else {}),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static lint + compiled-artifact contract "
                    "checker (rules RPA001-RPA007; see docs/API.md)")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--baseline", metavar="FILE",
                    help="JSON baseline of accepted fingerprints to "
                         "suppress")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="snapshot current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--json", metavar="FILE",
                    help="write a JSON report ('-' for stdout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--contracts", action="store_true",
                    help="also lower the device superstep and check "
                         "compiled-artifact contracts (needs jax)")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.rule_id}  {r.name:<16} {r.invariant}")
        return 0
    if not args.paths and not args.contracts:
        ap.print_usage(sys.stderr)
        print("error: no paths given (or use --list-rules/--contracts)",
              file=sys.stderr)
        return 2

    findings = lint_paths(args.paths, rules) if args.paths else []

    if args.write_baseline:
        n = baseline_mod.write(args.write_baseline, findings)
        print(f"wrote {n} fingerprint(s) to {args.write_baseline}")
        return 0

    if args.baseline:
        accepted = baseline_mod.load(args.baseline)
        findings = baseline_mod.filter_findings(findings, accepted)

    contracts = None
    contract_failures = 0
    if args.contracts:
        # deferred import: the lint path must not require jax
        from repro.analysis.contracts import check_all
        contracts = [c.to_dict() for c in check_all()]
        contract_failures = sum(1 for c in contracts if not c["ok"])

    if args.json:
        payload = json.dumps(_report(findings, rules, contracts), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    for f in findings:
        print(f.format())
    if contracts is not None:
        for c in contracts:
            status = "ok" if c["ok"] else "FAIL"
            print(f"[contract] {c['name']}: {status} — {c['detail']}")

    n = len(findings)
    if n or contract_failures:
        print(f"\n{n} finding(s), {contract_failures} contract "
              f"failure(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
