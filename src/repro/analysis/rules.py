"""Codebase-specific lint rules over the shared FileContext.

Every rule protects ONE invariant the runtime layers pinned by hand-written
regression tests in earlier PRs; the ids are stable (baseline fingerprints
and `# noqa: RPAxxx` suppressions reference them):

  RPA001 tracer-leak      Python control flow / scalar coercion of traced
                          values inside jit-traced functions.
  RPA002 loop-host-sync   Implicit device->host materialization inside a
                          host loop (one blocking sync per iteration);
                          `jax.device_get` is the sanctioned explicit form.
  RPA003 select-dtype     The PR 6 selection dtype contract: device
                          selection state is int32, host selection python
                          ints; array creation in scheduling modules names
                          its dtype (numpy defaults to float64/int64 and
                          drifts across the host/device boundary).
  RPA004 nondeterminism   Wall-clock / global-RNG entropy in library code
                          (schedules must replay from a threaded seed).
  RPA005 jit-cache-key    Per-call `jax.jit` of ephemeral callables
                          (retrace per call) and unhashable objects inside
                          cache-key tuples.
  RPA006 f64-promotion    Explicit 64-bit dtypes on device arrays (x64 is
                          off: silently truncates today, doubles memory and
                          forfeits the MXU the day someone flips it on).
  RPA007 set-iteration    Iterating a set in scheduling code: hash-order
                          reaches the schedule (PYTHONHASHSEED-dependent
                          for strings) — sort before iterating.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from repro.analysis.lint import (DEVICE_ATTRS, FileContext, Finding,
                                 LintRule, attr_chain, call_chain,
                                 is_jax_rooted, mentions_device_value,
                                 parents)

#: modules whose array creations participate in scheduling decisions —
#: the selection dtype contract (RPA003) applies to them
SELECTION_MODULES = ("core/do_select.py", "core/global_q.py",
                     "core/policy.py", "core/scheduler.py",
                     "core/priority.py", "serve/concurrent.py")

_COERCIONS = ("float", "int", "bool", "complex")
_NP_MATERIALIZE = ("np.asarray", "np.array", "numpy.asarray", "numpy.array")


def _in_selection_module(ctx: FileContext) -> bool:
    return any(ctx.path.endswith(m) for m in SELECTION_MODULES)


def _dtype_of_call(node: ast.Call) -> Optional[ast.AST]:
    """The dtype argument of an array-creation call, positional or kw."""
    for kw in node.keywords:
        if kw.arg == "dtype":
            return kw.value
    chain = call_chain(node) or ""
    leaf = chain.rsplit(".", 1)[-1]
    # np.zeros(shape, dtype) / jnp.full(shape, fill, dtype) positional slots
    pos = {"zeros": 1, "ones": 1, "empty": 1, "arange": None,
           "full": 2, "asarray": 1, "array": 1}.get(leaf)
    if pos is not None and len(node.args) > pos:
        return node.args[pos]
    return None


def _names_64bit(node: ast.AST) -> bool:
    chain = attr_chain(node)
    if chain and chain.rsplit(".", 1)[-1] in ("float64", "int64", "uint64"):
        return True
    return (isinstance(node, ast.Constant)
            and node.value in ("float64", "int64", "uint64"))


class TracerLeakRule(LintRule):
    rule_id = "RPA001"
    name = "tracer-leak"
    invariant = ("jit-traced code never branches on / coerces a traced "
                 "value with Python `if`/`while`/`bool()`/`int()`/`float()`"
                 " — use lax.cond/select, jnp.where, or hoist to host")

    @staticmethod
    def _traced_test(test: ast.AST, device) -> bool:
        """A traced value reaches `test` in a VALUE position.

        Seeds: bare names of tracers (jitted-fn params and jnp-derived
        locals), DEVICE_ATTRS attribute reads, jnp/lax-rooted calls.
        A seed is discounted when, climbing toward the test root, it
        passes through structure that makes the branch static at trace
        time: an attribute read (``x.shape``, ``cfg.flag``,
        ``ov.capacity`` — array value-attrs live in DEVICE_ATTRS, so
        anything else is metadata/config), an ``is``/``is not``
        comparison, a comparison against a string constant (dict keys,
        mode switches), or membership in an all-constant collection
        (``kind in ("attn", "swa")``)."""
        def _static_compare(cmp: ast.Compare) -> bool:
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in cmp.ops):
                return True
            operands = [cmp.left] + list(cmp.comparators)
            if any(isinstance(o, ast.Constant) and isinstance(o.value, str)
                   for o in operands):
                return True
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in cmp.ops):
                return all(
                    isinstance(c, (ast.Tuple, ast.List, ast.Set))
                    and all(isinstance(e, ast.Constant) for e in c.elts)
                    for c in cmp.comparators)
            return False

        seeds = []
        for sub in ast.walk(test):
            if isinstance(sub, ast.Name) and sub.id in device:
                seeds.append(sub)
            elif isinstance(sub, ast.Attribute) \
                    and sub.attr in DEVICE_ATTRS:
                seeds.append(sub)
            elif isinstance(sub, ast.Call) and is_jax_rooted(sub):
                seeds.append(sub)
        for seed in seeds:
            static = False
            for p in parents(seed):
                if isinstance(p, ast.Attribute):
                    static = True   # metadata read off the value
                    break
                if isinstance(p, ast.Compare) and _static_compare(p):
                    static = True
                    break
                if p is test:
                    break
            if not static:
                return True
        return False

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for fn in ctx.functions():
            if fn.name not in ctx.jitted:
                continue
            device = set(ctx.local_device_names(fn))
            for a in fn.args.args + fn.args.kwonlyargs:
                device.add(a.arg)  # params of a jitted fn are tracers
            for sub in ast.walk(fn):
                if isinstance(sub, (ast.If, ast.While)):
                    if self._traced_test(sub.test, device):
                        out.append(self.finding(
                            ctx, sub,
                            f"Python `{type(sub).__name__.lower()}` on a "
                            f"traced value inside jitted `{fn.name}` "
                            f"(ConcretizationError at trace time, or a "
                            f"silently baked-in branch)"))
                elif isinstance(sub, ast.Assert):
                    if self._traced_test(sub.test, device):
                        out.append(self.finding(
                            ctx, sub,
                            f"assert on a traced value inside jitted "
                            f"`{fn.name}` — use checkify or move the "
                            f"check to host"))
                elif isinstance(sub, ast.Call):
                    chain = call_chain(sub)
                    if chain in _COERCIONS and sub.args and \
                            self._traced_test(sub.args[0], device):
                        out.append(self.finding(
                            ctx, sub,
                            f"`{chain}()` of a traced value inside jitted "
                            f"`{fn.name}` forces a concrete value at "
                            f"trace time"))
                    elif chain in _NP_MATERIALIZE and sub.args and \
                            self._traced_test(sub.args[0], device):
                        out.append(self.finding(
                            ctx, sub,
                            f"`{chain}()` of a traced value inside jitted "
                            f"`{fn.name}` breaks the trace (use jnp)"))
        return out


class LoopHostSyncRule(LintRule):
    rule_id = "RPA002"
    name = "loop-host-sync"
    invariant = ("host loops never implicitly materialize device values "
                 "per iteration — hoist one batched `jax.device_get` (or "
                 "np.asarray) above the loop; intentional syncs are "
                 "explicit `jax.device_get` calls")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for fn in ctx.functions():
            if fn.name in ctx.jitted:
                continue  # traced bodies are RPA001's territory
            device = ctx.local_device_names(fn)
            for sub in ast.walk(fn):
                if not isinstance(sub, ast.Call):
                    continue
                if ctx.enclosing_loop(sub) is None:
                    continue
                chain = call_chain(sub)
                is_item = (isinstance(sub.func, ast.Attribute)
                           and sub.func.attr == "item")
                arg0 = (sub.func.value if is_item
                        else sub.args[0] if sub.args else None)
                if arg0 is None:
                    continue
                if is_item or chain in _COERCIONS \
                        or chain in _NP_MATERIALIZE:
                    if mentions_device_value(arg0, device) \
                            and not self._already_explicit(arg0):
                        label = "`.item()`" if is_item else f"`{chain}()`"
                        out.append(self.finding(
                            ctx, sub,
                            f"{label} on a device value inside a loop: one "
                            f"blocking device->host sync per iteration — "
                            f"hoist a single batched jax.device_get above "
                            f"the loop"))
        return out

    @staticmethod
    def _already_explicit(node: ast.AST) -> bool:
        """The argument is itself a device_get result: sanctioned."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and call_chain(sub) in (
                    "jax.device_get", "device_get"):
                return True
        return False


class SelectDtypeRule(LintRule):
    rule_id = "RPA003"
    name = "select-dtype"
    invariant = ("selection state keeps the PR 6 dtype contract: device "
                 "selections are int32 scalars/arrays, host selections "
                 "python ints; arrays created in scheduling modules name "
                 "their dtype explicitly (numpy's float64/int64 defaults "
                 "drift across the host/device boundary)")

    _CREATORS = ("np.zeros", "np.ones", "np.empty", "np.full", "np.arange",
                 "numpy.zeros", "numpy.ones", "numpy.empty", "numpy.full",
                 "numpy.arange")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if not _in_selection_module(ctx):
            return []
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = call_chain(node)
            if chain in self._CREATORS:
                if _dtype_of_call(node) is None:
                    out.append(self.finding(
                        ctx, node,
                        f"`{chain}` without an explicit dtype in a "
                        f"scheduling module defaults to float64/int64 and "
                        f"drifts when it crosses to the device backend "
                        f"(weak f64 -> silent f32 downcast)"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args:
                tgt = node.args[0]
                if _names_64bit(tgt) or (isinstance(tgt, ast.Name)
                                         and tgt.id == "int"):
                    if mentions_device_value(node.func.value, set()) \
                            or is_jax_rooted(node.func.value):
                        out.append(self.finding(
                            ctx, node,
                            "64-bit astype on a device value breaks the "
                            "int32 selection contract (x64 is off: this "
                            "is a silent downcast today and a retrace "
                            "hazard the day it isn't)"))
        return out


class NondeterminismRule(LintRule):
    rule_id = "RPA004"
    name = "nondeterminism"
    invariant = ("library code draws no entropy outside the threaded seed: "
                 "no wall-clock seeds, no global numpy RNG, no stdlib "
                 "random — schedules must replay bit-identically")

    _NP_GLOBAL = {"seed", "rand", "randn", "randint", "random", "choice",
                  "shuffle", "permutation", "uniform", "normal",
                  "standard_normal", "integers"}
    _STDLIB = {"random.random", "random.randint", "random.choice",
               "random.shuffle", "random.seed", "random.sample",
               "random.uniform", "random.randrange", "random.getrandbits"}

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = call_chain(node) or ""
            if chain in ("time.time", "time.time_ns"):
                out.append(self.finding(
                    ctx, node,
                    "`time.time()` in library code: wall-clock values leak "
                    "into behaviour (use time.perf_counter for durations, "
                    "a threaded seed for randomness)"))
            elif chain in ("datetime.datetime.now", "datetime.now",
                           "datetime.datetime.utcnow"):
                out.append(self.finding(
                    ctx, node, f"`{chain}()` in library code is "
                    f"nondeterministic"))
            elif chain in ("np.random.default_rng",
                           "numpy.random.default_rng"):
                if not node.args and not node.keywords:
                    out.append(self.finding(
                        ctx, node,
                        "`np.random.default_rng()` without a seed draws OS "
                        "entropy — thread an explicit seed"))
            elif chain.startswith(("np.random.", "numpy.random.")) \
                    and chain.rsplit(".", 1)[-1] in self._NP_GLOBAL:
                out.append(self.finding(
                    ctx, node,
                    f"global numpy RNG `{chain}` — shared mutable state, "
                    f"not replayable; use np.random.default_rng(seed)"))
            elif chain in self._STDLIB:
                out.append(self.finding(
                    ctx, node,
                    f"stdlib `{chain}` — global RNG in library code"))
            elif chain in ("os.urandom", "uuid.uuid4", "secrets.token_hex"):
                out.append(self.finding(
                    ctx, node, f"`{chain}` draws OS entropy in library "
                    f"code"))
        return out


class JitCacheKeyRule(LintRule):
    rule_id = "RPA005"
    name = "jit-cache-key"
    invariant = ("compiled callables are cached: no per-call `jax.jit` of "
                 "an ephemeral lambda/closure (every call re-traces), and "
                 "cache-key tuples hold only hashable, stable components")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and call_chain(node) in ("jax.jit", "jit") and node.args:
                f = self._check_jit_site(ctx, node)
                if f is not None:
                    out.append(f)
            elif isinstance(node, ast.Assign):
                out.extend(self._check_key_tuple(ctx, node))
        return out

    def _check_jit_site(self, ctx: FileContext,
                        node: ast.Call) -> Optional[Finding]:
        in_function = any(isinstance(p, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                          for p in parents(node))
        if not in_function:
            return None  # module-level jit compiles once per process
        # immediately-called jit is always a fresh trace: jax.jit(f)(x)
        parent = next(iter(parents(node)), None)
        if isinstance(parent, ast.Call) and parent.func is node:
            return self.finding(
                ctx, node,
                "`jax.jit(...)(...)` called inline: the wrapper (and its "
                "trace cache) dies with the expression — every call "
                "re-traces; hoist the jitted callable")
        guarded = cached = returned = in_loop = False
        for p in parents(node):
            if isinstance(p, ast.If) and any(
                    isinstance(op, ast.NotIn)
                    for cmp in ast.walk(p.test)
                    if isinstance(cmp, ast.Compare)
                    for op in cmp.ops):
                guarded = True
            if isinstance(p, ast.Assign) and any(
                    isinstance(t, ast.Subscript) for t in p.targets):
                cached = True
            if isinstance(p, ast.Return):
                returned = True
            if isinstance(p, (ast.For, ast.While)):
                in_loop = True
        if guarded or cached:
            return None
        if returned and not in_loop:
            return None  # factory: the caller owns caching (session cache)
        if isinstance(node.args[0], ast.Lambda) or in_loop:
            return self.finding(
                ctx, node,
                "per-call `jax.jit` of an ephemeral callable without a "
                "cache guard: a fresh lambda/closure hashes differently "
                "every call, so every call re-traces — store it in a "
                "keyed cache (see GraphSession._jit_cache)")
        return None

    def _check_key_tuple(self, ctx: FileContext,
                         node: ast.Assign) -> Iterable[Finding]:
        tgt = node.targets[0] if len(node.targets) == 1 else None
        if not (isinstance(tgt, ast.Name) and "key" in tgt.id.lower()):
            return []
        if not isinstance(node.value, ast.Tuple):
            return []
        out = []
        for elt in node.value.elts:
            if isinstance(elt, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                ast.DictComp, ast.SetComp)):
                out.append(self.finding(
                    ctx, elt,
                    f"unhashable {type(elt).__name__} inside the cache-key "
                    f"tuple `{tgt.id}`: the cache lookup raises TypeError "
                    f"(or silently never hits) — use a tuple"))
        return out


class F64PromotionRule(LintRule):
    rule_id = "RPA006"
    name = "f64-promotion"
    invariant = ("device arrays never name 64-bit dtypes: with x64 off the "
                 "request is silently truncated to 32-bit; with x64 on it "
                 "doubles HBM traffic and forfeits the MXU")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute):
                chain = attr_chain(node) or ""
                if chain in ("jnp.float64", "jnp.int64", "jnp.uint64"):
                    out.append(self.finding(
                        ctx, node,
                        f"`{chain}` names a 64-bit device dtype"))
            elif isinstance(node, ast.Call):
                chain = call_chain(node) or ""
                if chain.startswith("jnp."):
                    dt = _dtype_of_call(node)
                    if dt is not None and _names_64bit(dt) \
                            and (attr_chain(dt) or "").split(".")[0] != \
                            "jnp":
                        out.append(self.finding(
                            ctx, node,
                            f"64-bit dtype in `{chain}`: x64 is off, the "
                            f"array silently lands as 32-bit"))
                elif chain in ("jax.config.update",):
                    if (node.args
                            and isinstance(node.args[0], ast.Constant)
                            and node.args[0].value == "jax_enable_x64"):
                        out.append(self.finding(
                            ctx, node,
                            "library code must not flip jax_enable_x64: "
                            "it is process-global and retraces every "
                            "cached program"))
        return out


class SetIterationRule(LintRule):
    rule_id = "RPA007"
    name = "set-iteration"
    invariant = ("scheduling code never iterates a set directly: hash "
                 "order (PYTHONHASHSEED-dependent for strings) would reach "
                 "the schedule — wrap in sorted()")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        iters = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append((node, node.iter))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend((node, gen.iter) for gen in node.generators)
        for node, it in iters:
            if self._is_set_expr(it):
                out.append(self.finding(
                    ctx, node,
                    "iterating a set: order is hash-dependent and can "
                    "reach scheduling decisions — iterate sorted(...) "
                    "instead"))
        return out

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and call_chain(node) in ("set",
                                                               "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub)):
            return (SetIterationRule._is_set_expr(node.left)
                    or SetIterationRule._is_set_expr(node.right))
        return False


def default_rules() -> List[LintRule]:
    """The registry, id-ordered (stable for docs, CLI and reports)."""
    return [TracerLeakRule(), LoopHostSyncRule(), SelectDtypeRule(),
            NondeterminismRule(), JitCacheKeyRule(), F64PromotionRule(),
            SetIterationRule()]
