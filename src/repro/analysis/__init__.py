"""repro.analysis — JAX-aware static lint + compiled-artifact contracts.

Three layers, one invariant surface:

- :mod:`repro.analysis.lint` / :mod:`repro.analysis.rules` — stdlib-``ast``
  lint engine with codebase-specific rules (RPA001–RPA007) over tracer
  leaks, implicit host syncs, the selection dtype contract,
  nondeterminism, jit-cache-key hazards, f64 promotion and set iteration.
- :mod:`repro.analysis.contracts` — lowers the real device superstep per
  policy and asserts contracts on the compiled HLO: one host sync on the
  inf-cadence path, no f64, Pallas tile VMEM within budget, flops/bytes
  cross-checked against the analytic cost model.
- :mod:`repro.analysis.sentinels` — runtime guards packaged for pytest:
  a ``jax.transfer_guard`` wrapper and a retrace sentinel pinning a
  session's jit-cache size.

CLI: ``python -m repro.analysis src/`` (see ``--help``); exits non-zero on
any unbaselined finding, which is the CI gate.
"""

from repro.analysis.lint import (Finding, LintRule, lint_paths,
                                 lint_source)
from repro.analysis.rules import default_rules
from repro.analysis.sentinels import (RetraceError, no_implicit_transfers,
                                      retrace_sentinel)

__all__ = ["Finding", "LintRule", "lint_paths", "lint_source",
           "default_rules", "RetraceError", "no_implicit_transfers",
           "retrace_sentinel"]
