"""Runtime sentinels: the dynamic half of the analysis layer.

Two guards, both packaged as context managers so tests (via the fixtures
in tests/conftest.py) can wrap existing scenarios without restructuring:

- :func:`no_implicit_transfers` — ``jax.transfer_guard("disallow")``
  around a block.  The drivers perform their intentional syncs through
  explicit ``jax.device_get`` (which the guard permits), so ANY guard trip
  inside a run is an unintended implicit transfer — exactly the class of
  regression RPA001/RPA002 catch statically.

- :func:`retrace_sentinel` — pins a ``GraphSession``'s jit cache.  On
  exit it fails if the cache grew past the pinned size: new keys mean the
  cache key leaked an ephemeral component (RPA005); a grown per-entry
  trace count (``_cache_size``) means an argument changed its
  shape/dtype/weak-type between calls and silently re-traced.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Iterable, Optional, Tuple


class RetraceError(AssertionError):
    """A pinned jit cache grew — some call re-traced or re-keyed."""


def _trace_count(fn) -> Optional[int]:
    size = getattr(fn, "_cache_size", None)
    if callable(size):
        try:
            return int(size())
        except Exception:
            return None
    return None


def snapshot_jit_cache(sess) -> Dict[Tuple, Optional[int]]:
    """{cache key: per-entry trace count (None if unavailable)}."""
    return {k: _trace_count(fn) for k, fn in sess._jit_cache.items()}


@contextlib.contextmanager
def no_implicit_transfers():
    """All device->host movement inside the block must be explicit
    ``jax.device_get``; implicit coercions (`float()`, `.item()`,
    `np.asarray` forcing a copy) raise.  Host->device setup transfers
    (`jnp.int32(0)` seeding a carry, argument staging) are deliberately
    NOT guarded — they are cheap, non-blocking, and every driver performs
    them; the invariant the paper's speedups rest on is the *sync*
    direction."""
    import jax
    with jax.transfer_guard_device_to_host("disallow"):
        yield


@contextlib.contextmanager
def retrace_sentinel(sess, allow_new: Iterable[str] = ()):
    """Fail on exit if `sess`'s jit cache grew past its pinned size.

    ``allow_new`` whitelists cache-key *kinds* (the key tuple's first
    element, e.g. ``"superstep"``) that the block is expected to compile
    for the first time — growth of an already-pinned entry is never
    allowed.
    """
    before = snapshot_jit_cache(sess)
    allowed = frozenset(allow_new)
    yield
    after = snapshot_jit_cache(sess)
    new_keys = [k for k in after if k not in before]
    bad_new = [k for k in new_keys
               if not (isinstance(k, tuple) and k and k[0] in allowed)]
    if bad_new:
        raise RetraceError(
            f"jit cache gained {len(bad_new)} unexpected key(s): "
            f"{bad_new[:3]!r} — an ephemeral component reached the cache "
            f"key (every such key is a full re-trace)")
    grown = [(k, before[k], after[k]) for k in before
             if before[k] is not None and after[k] is not None
             and after[k] > before[k]]
    if grown:
        k, b, a = grown[0]
        raise RetraceError(
            f"{len(grown)} pinned jit entr(y/ies) re-traced "
            f"(first: key={k!r} traces {b} -> {a}) — an argument changed "
            f"shape/dtype/weak-type between calls")
