"""Deterministic graph generators (host-side numpy)."""

from __future__ import annotations

import numpy as np

from repro.graph.structure import CSRGraph


def _finish(n: int, src: np.ndarray, dst: np.ndarray, rng: np.random.Generator,
            weighted: bool, w_max: float) -> CSRGraph:
    # drop self loops
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # guarantee no dangling vertices (every vertex has >=1 out-edge): append a
    # ring edge for any vertex with out-degree 0.  Keeps PageRank comparable
    # to networkx (which redistributes dangling mass differently).
    deg = np.bincount(src, minlength=n)
    lonely = np.nonzero(deg == 0)[0]
    if len(lonely):
        src = np.concatenate([src, lonely])
        dst = np.concatenate([dst, (lonely + 1) % n])
    if weighted:
        w = rng.uniform(1.0, w_max, size=len(src)).astype(np.float32)
    else:
        w = np.ones(len(src), dtype=np.float32)
    return CSRGraph.from_edges(n, src.astype(np.int64), dst.astype(np.int64), w)


def rmat_graph(n: int, avg_degree: int = 8, *, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               weighted: bool = False, w_max: float = 10.0) -> CSRGraph:
    """R-MAT power-law generator (Chakrabarti et al.); n rounded up to 2^k."""
    rng = np.random.default_rng(seed)
    levels = int(np.ceil(np.log2(max(n, 2))))
    m = n * avg_degree
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for lvl in range(levels):
        r = rng.random(m)
        go_b = (r >= a) & (r < a + b)
        go_c = (r >= a + b) & (r < a + b + c)
        go_d = r >= a + b + c
        src += ((go_c | go_d) << lvl)
        dst += ((go_b | go_d) << lvl)
    keep = (src < n) & (dst < n)
    return _finish(n, src[keep], dst[keep], rng, weighted, w_max)


def uniform_graph(n: int, avg_degree: int = 8, *, seed: int = 0,
                  weighted: bool = False, w_max: float = 10.0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = n * avg_degree
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return _finish(n, src, dst, rng, weighted, w_max)


def chain_graph(n: int, *, weighted: bool = False, w_max: float = 10.0,
                seed: int = 0) -> CSRGraph:
    """Directed ring 0->1->...->n-1->0 (worst case for prioritized iteration)."""
    rng = np.random.default_rng(seed)
    src = np.arange(n, dtype=np.int64)
    dst = (src + 1) % n
    return _finish(n, src, dst, rng, weighted, w_max)


def mutation_stream(csr: CSRGraph, n_batches: int, *,
                    inserts_per_batch: int = 8, deletes_per_batch: int = 4,
                    seed: int = 0, weighted: bool = False,
                    w_max: float = 10.0):
    """Deterministic edge-update stream for evolving-graph workloads.

    Each batch mixes PREFERENTIAL-ATTACHMENT inserts (destination sampled
    proportionally to current in-degree + 1, source uniform — the organic
    growth model of social/web graphs, which keeps feeding the hub blocks
    the two-level scheduler already prioritizes) with UNIFORM deletes of
    existing edges.  The stream is degree-safe: a source's last out-edge
    is never deleted (no vertex goes dangling, keeping out-degree
    normalized plus-times views well defined), self-loops are skipped,
    and an insert that collides with an existing edge becomes a reweight.
    Batches evolve the edge set as they are generated, so batch k+1
    mutates the graph AS LEFT by batch k.

    Returns a list of `repro.stream.UpdateBatch` (apply in order).
    """
    from repro.stream.updates import UpdateBatch, _edge_dict

    rng = np.random.default_rng(seed)
    n = csr.n
    edges = _edge_dict(csr)
    out_deg = np.diff(csr.indptr).astype(np.int64)
    in_deg = np.bincount(csr.indices, minlength=n).astype(np.int64)

    batches = []
    for _ in range(n_batches):
        ins_s, ins_d, ins_w = [], [], []
        for _ in range(inserts_per_batch):
            p = (in_deg + 1) / float((in_deg + 1).sum())
            for _attempt in range(8):
                u = int(rng.integers(n))
                v = int(rng.choice(n, p=p))
                if u != v:
                    break
            else:
                continue
            w = float(rng.uniform(1.0, w_max)) if weighted else 1.0
            ins_s.append(u)
            ins_d.append(v)
            ins_w.append(w)
            if (u, v) not in edges:
                out_deg[u] += 1
                in_deg[v] += 1
            edges[(u, v)] = w
        del_s, del_d = [], []
        if edges and deletes_per_batch:
            keys = sorted(edges)
            order = rng.permutation(len(keys))
            for i in order:
                if len(del_s) >= deletes_per_batch:
                    break
                u, v = keys[i]
                if out_deg[u] <= 1 or (u, v) not in edges:
                    continue            # never orphan a source vertex
                del edges[(u, v)]
                out_deg[u] -= 1
                in_deg[v] -= 1
                del_s.append(u)
                del_d.append(v)
        batches.append(UpdateBatch.concat([
            UpdateBatch.inserts(np.asarray(ins_s, np.int64),
                                np.asarray(ins_d, np.int64),
                                np.asarray(ins_w, np.float32)),
            UpdateBatch.deletes(np.asarray(del_s, np.int64),
                                np.asarray(del_d, np.int64))]))
    return batches


def grid_graph(side: int, *, weighted: bool = False, w_max: float = 10.0,
               seed: int = 0) -> CSRGraph:
    """side x side 4-neighbour grid, edges in +x/+y and -x/-y directions."""
    rng = np.random.default_rng(seed)
    n = side * side
    ids = np.arange(n).reshape(side, side)
    srcs, dsts = [], []
    for (dy, dx) in ((0, 1), (1, 0), (0, -1), (-1, 0)):
        ys, xs = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
        ny, nx_ = ys + dy, xs + dx
        ok = (ny >= 0) & (ny < side) & (nx_ >= 0) & (nx_ < side)
        srcs.append(ids[ys[ok], xs[ok]])
        dsts.append(ids[ny[ok], nx_[ok]])
    return _finish(n, np.concatenate(srcs), np.concatenate(dsts), rng,
                   weighted, w_max)
