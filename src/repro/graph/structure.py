"""Graph structures: host CSR + device block-ELL dense tiles.

The paper schedules graph data in *blocks* ("a block can be placed in the
Cache", §3).  On TPU the cache is VMEM, and the natural VMEM-resident unit is
a dense [Vb, Vb] adjacency tile (MXU-friendly), stored block-sparse: for each
source block we keep up to K neighbouring destination blocks (block-ELL).

tiles[b, k, u, v] = weight of edge  (b*Vb + u)  ->  (nbr_ids[b, k]*Vb + v)
with `fill` (0.0 for plus-times, +inf for min-plus) where no edge exists.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR over out-edges (numpy)."""

    n: int
    indptr: np.ndarray  # [n+1] int64
    indices: np.ndarray  # [nnz] int32 destination vertex
    weights: np.ndarray  # [nnz] float32

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   weights: Optional[np.ndarray] = None) -> "CSRGraph":
        """Build CSR from an edge list; duplicate edges keep the min weight.

        Accepts any array-like input (lists, float arrays) and the empty
        edge list — evolving-graph mutation batches produce both (a batch
        of pure deletions leaves rows empty), so these are first-class
        inputs, not error cases.  The min-weight dedupe is idempotent:
        re-applying a batch that re-inserts an existing edge with a higher
        weight never raises the stored weight.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if weights is None:
            weights = np.ones(len(src), dtype=np.float32)
        weights = np.asarray(weights, dtype=np.float32)
        if not (len(src) == len(dst) == len(weights)):
            raise ValueError(
                f"ragged edge list: {len(src)}/{len(dst)}/{len(weights)}")
        if len(src) and (src.min() < 0 or src.max() >= n
                         or dst.min() < 0 or dst.max() >= n):
            raise ValueError(f"edge endpoints out of range for n={n}")
        # dedupe (src, dst), keep min weight (matters for SSSP correctness)
        key = src * n + dst
        order = np.lexsort((weights, key))
        key, src, dst, weights = key[order], src[order], dst[order], weights[order]
        keep = np.ones(len(key), dtype=bool)
        keep[1:] = key[1:] != key[:-1]
        src, dst, weights = src[keep], dst[keep], weights[keep]
        counts = np.bincount(src, minlength=n)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(n=n, indptr=indptr, indices=dst.astype(np.int32),
                        weights=weights.astype(np.float32))

    def symmetrized(self) -> "CSRGraph":
        """Union of edges and reverse edges (for WCC-style algorithms).

        Antiparallel pairs (u->v and v->u) collapse to min weight on both
        directions (from_edges dedupe), so the result is a valid weighted
        undirected graph even after asymmetric reweights."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.out_degree)
        all_src = np.concatenate([src, self.indices])
        all_dst = np.concatenate([self.indices, src])
        all_w = np.concatenate([self.weights, self.weights])
        return CSRGraph.from_edges(self.n, all_src, all_dst, all_w)

    def row(self, u: int) -> tuple:
        """(dst indices, weights) of u's out-row, dst-ascending."""
        lo, hi = int(self.indptr[u]), int(self.indptr[u + 1])
        return self.indices[lo:hi], self.weights[lo:hi]

    def edge_weight(self, u: int, v: int) -> Optional[float]:
        """Weight of edge (u, v), or None when absent.  O(log deg(u)) —
        rows are dst-sorted by construction (from_edges sorts by
        src * n + dst)."""
        lo, hi = int(self.indptr[u]), int(self.indptr[u + 1])
        i = lo + int(np.searchsorted(self.indices[lo:hi], v))
        if i < hi and int(self.indices[i]) == v:
            return float(self.weights[i])
        return None


@dataclasses.dataclass
class BlockedGraph:
    """Device-side block-ELL dense-tile layout (see module docstring)."""

    n_real: int          # number of real vertices
    block_size: int      # Vb (MXU-aligned, multiple of 128 on real TPU)
    num_blocks: int      # B_N
    max_nbr_blocks: int  # K
    fill: float          # 0.0 (plus-times) or +inf (min-plus)
    nbr_ids: jnp.ndarray   # [B_N, K] int32, padded entries point at block 0
    nbr_mask: jnp.ndarray  # [B_N, K] bool, True where the tile is real
    tiles: jnp.ndarray     # [B_N, K, Vb, Vb] float32
    vertex_mask: jnp.ndarray  # [B_N, Vb] bool, True for real vertices

    @property
    def n_padded(self) -> int:
        return self.num_blocks * self.block_size

    def tree_flatten(self):
        leaves = (self.nbr_ids, self.nbr_mask, self.tiles, self.vertex_mask)
        aux = (self.n_real, self.block_size, self.num_blocks,
               self.max_nbr_blocks, self.fill)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_real, block_size, num_blocks, max_nbr_blocks, fill = aux
        nbr_ids, nbr_mask, tiles, vertex_mask = leaves
        return cls(n_real, block_size, num_blocks, max_nbr_blocks, fill,
                   nbr_ids, nbr_mask, tiles, vertex_mask)


@dataclasses.dataclass
class BlockPairs:
    """Destination-sorted sparse block-pair view of a BlockedGraph.

    The block-ELL layout pads every source block to K neighbour slots, so
    a dense sweep stages zero-tiles as real bytes.  This view materializes
    ONLY the nonzero (src_block, dst_block) pairs, sorted by destination
    (NXgraph's destination-sorted sub-shards): consecutive pairs share a
    destination block, so a kernel sweeping the list in order revisits
    each output block while it is still VMEM-resident — one accumulation
    run and ONE flush per destination block (kernels.fused_superstep).

      src   [P] int32    source block of each pair
      dst   [P] int32    destination block, NON-DECREASING (dst-sorted)
      slot  [P] int32    the pair's ELL slot k (tiles[src, slot] is its tile)
      first [P] int32    1 at the first pair of each dst run (init point)
      last  [P] int32    1 at the last pair of each dst run (flush point)
      src_nnz [B_N] int32   real pairs per SOURCE block — staging block b
                            moves src_nnz[b] * Vb^2 * 4 real adjacency
                            bytes, the quantity `tile_pair_loads` accounts
      dst_touched [B_N] bool  blocks that appear as a destination (pairs
                              never write the others; callers pass state
                              through for them)
      tiles [P, Vb, Vb] f32   contiguous dst-sorted copy of the pair tiles
      dense_op  [B_N*Vb, B_N*Vb] f32 or None — the full adjacency operator
                (row u, col v = weight of edge u->v), built only for
                plus-times views (fill == 0.0) dense enough to fit the
                byte cap.  A REFERENCE view for tests and contract
                checks: the engine pushes through the pair einsum /
                scatter (a [J, N] @ [N, N] matmul would let XLA pick a
                J-dependent contraction blocking, breaking the bit-for-
                bit job-axis sharding invariance dist.graph pins), and
                dist.graph drops it under a mesh.

    An edgeless graph keeps P >= 1 with one inert pad pair (src=dst=0,
    all-`fill` tile — an exact no-op in both semirings, src_nnz all 0).
    """

    num_pairs: int
    block_size: int
    num_blocks: int
    src: jnp.ndarray
    dst: jnp.ndarray
    slot: jnp.ndarray
    first: jnp.ndarray
    last: jnp.ndarray
    src_nnz: jnp.ndarray
    dst_touched: jnp.ndarray
    tiles: jnp.ndarray
    dense_op: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        leaves = (self.src, self.dst, self.slot, self.first, self.last,
                  self.src_nnz, self.dst_touched, self.tiles, self.dense_op)
        aux = (self.num_pairs, self.block_size, self.num_blocks)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux, *leaves)


#: build_block_pairs materializes `dense_op` only when the block graph is
#: at least this dense (P / B_N^2) AND the operator stays under the byte
#: cap — past either bound the pair list is the only materialization
DENSE_OP_MIN_DENSITY = 0.25
DENSE_OP_MAX_BYTES = 64 * 2**20


def build_block_pairs(g: BlockedGraph, *,
                      dense_min_density: float = DENSE_OP_MIN_DENSITY,
                      dense_max_bytes: int = DENSE_OP_MAX_BYTES
                      ) -> BlockPairs:
    """Destination-sorted real-pair view of `g` (see BlockPairs).

    Pure function of the CURRENT tiles: evolving views (repro.stream)
    rebuild it after in-place tile edits / compaction — the pair tiles
    are a copy, not an alias."""
    import jax
    # explicit device_get: pair building is host-side enumeration and may
    # run under the transfer sentinel (analysis.sentinels)
    ids, msk = map(np.asarray, jax.device_get((g.nbr_ids, g.nbr_mask)))
    bn, vb = g.num_blocks, g.block_size
    sb, slot = np.nonzero(msk)
    db = ids[sb, slot]
    src_nnz = np.bincount(sb, minlength=bn).astype(np.int32)
    if len(sb) == 0:
        # inert pad pair: an all-fill tile is an exact no-op (plus-times
        # adds 0.0, min-plus mins +inf), so P stays >= 1 for fixed shapes
        return BlockPairs(
            num_pairs=1, block_size=vb, num_blocks=bn,
            src=jnp.zeros(1, jnp.int32), dst=jnp.zeros(1, jnp.int32),
            slot=jnp.zeros(1, jnp.int32), first=jnp.ones(1, jnp.int32),
            last=jnp.ones(1, jnp.int32),
            src_nnz=jnp.asarray(src_nnz),
            dst_touched=jnp.zeros(bn, bool),
            tiles=jnp.full((1, vb, vb), g.fill, jnp.float32))
    order = np.lexsort((sb, db))          # dst-major, src ascending within
    sb, db, slot = sb[order], db[order], slot[order]
    first = np.ones(len(sb), np.int32)
    first[1:] = (db[1:] != db[:-1]).astype(np.int32)
    last = np.ones(len(sb), np.int32)
    last[:-1] = first[1:]
    touched = np.zeros(bn, bool)
    touched[db] = True
    tiles = g.tiles[jnp.asarray(sb), jnp.asarray(slot)]   # [P, Vb, Vb] copy
    dense_op = None
    density = len(sb) / float(bn * bn)
    if (g.fill == 0.0 and density >= dense_min_density
            and (bn * vb) ** 2 * 4 <= dense_max_bytes):
        op = jnp.zeros((bn, vb, bn, vb), jnp.float32)
        op = op.at[jnp.asarray(sb), :, jnp.asarray(db), :].set(tiles)
        dense_op = op.reshape(bn * vb, bn * vb)
    return BlockPairs(
        num_pairs=len(sb), block_size=vb, num_blocks=bn,
        src=jnp.asarray(sb, jnp.int32), dst=jnp.asarray(db, jnp.int32),
        slot=jnp.asarray(slot, jnp.int32),
        first=jnp.asarray(first), last=jnp.asarray(last),
        src_nnz=jnp.asarray(src_nnz), dst_touched=jnp.asarray(touched),
        tiles=tiles, dense_op=dense_op)


@dataclasses.dataclass
class TileOverlay:
    """Bounded per-block delta-COO staged alongside the base tiles.

    Evolving graphs mutate while jobs run (repro.stream).  Most edge
    updates edit the dense base tile in place (the (src block, dst block)
    pair already owns a tile slot); an insert that creates a NEW block
    pair has nowhere to land in the block-ELL layout, so it goes into
    this overlay: for each source block, up to `capacity` explicit COO
    edges.  Staging block b stages its tile row AND its overlay row
    together (one `tile_loads` unit — the overlay rides along, it is not
    a second staging), and every push consumes both.  When a block's
    overlay row fills up, the owning view COMPACTS: the BlockedGraph is
    rebuilt from the updated CSR (bit-identical to a from-scratch build)
    and the overlay empties.

    Entries with mask 0 are inert by construction: plus-times adds an
    exact 0.0, min-plus mins an inf — so a capacity-0 overlay (the state
    of every never-updated view) leaves all pre-existing runs bitwise
    unchanged.

      src_u [B_N, C] int32   source vertex offset within the block
      dst   [B_N, C] int32   destination vertex, global padded index
      w     [B_N, C] float32 edge weight in the VIEW's weight space
                             (normalization already applied)
      mask  [B_N, C] float32 1.0 where the entry is a real edge
    """

    capacity: int
    src_u: jnp.ndarray
    dst: jnp.ndarray
    w: jnp.ndarray
    mask: jnp.ndarray

    def tree_flatten(self):
        return (self.src_u, self.dst, self.w, self.mask), (self.capacity,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux[0], *leaves)


def empty_overlay(num_blocks: int, capacity: int = 0) -> TileOverlay:
    """All-inert overlay; capacity 0 is the no-updates-yet default."""
    shape = (num_blocks, capacity)
    return TileOverlay(
        capacity=capacity,
        src_u=jnp.zeros(shape, dtype=jnp.int32),
        dst=jnp.zeros(shape, dtype=jnp.int32),
        w=jnp.zeros(shape, dtype=jnp.float32),
        mask=jnp.zeros(shape, dtype=jnp.float32))


import jax.tree_util  # noqa: E402

jax.tree_util.register_pytree_node(
    BlockedGraph, BlockedGraph.tree_flatten, BlockedGraph.tree_unflatten)
jax.tree_util.register_pytree_node(
    TileOverlay, TileOverlay.tree_flatten, TileOverlay.tree_unflatten)
jax.tree_util.register_pytree_node(
    BlockPairs, BlockPairs.tree_flatten, BlockPairs.tree_unflatten)


def build_blocked(csr: CSRGraph, block_size: int, *,
                  fill: float = 0.0,
                  normalize: Optional[str] = None) -> BlockedGraph:
    """Partition a CSR graph into dense [Vb, Vb] tiles, block-ELL layout.

    normalize:
      None          - raw edge weights
      "out_degree"  - weight / out_degree(src)   (PageRank-style stochastic)
      "unit"        - every present edge gets weight 1.0
      "zero"        - every present edge gets weight 0.0 (min-plus label prop)
    """
    n = csr.n
    vb = block_size
    bn = -(-n // vb)  # ceil

    src = np.repeat(np.arange(n, dtype=np.int64), csr.out_degree)
    dst = csr.indices.astype(np.int64)
    w = csr.weights.astype(np.float32).copy()
    if normalize == "out_degree":
        deg = np.maximum(csr.out_degree, 1).astype(np.float32)
        w = w / deg[src]
    elif normalize == "unit":
        w = np.ones_like(w)
    elif normalize == "zero":
        w = np.zeros_like(w)
    elif normalize is not None:
        raise ValueError(f"unknown normalize={normalize!r}")

    sb, db = src // vb, dst // vb
    su, dv = src % vb, dst % vb

    # enumerate distinct (src block, dst block) tile pairs
    pair_key = sb * bn + db
    order = np.argsort(pair_key, kind="stable")
    pair_key_s = pair_key[order]
    uniq_keys, first_idx = np.unique(pair_key_s, return_index=True)
    tile_sb = (uniq_keys // bn).astype(np.int32)
    tile_db = (uniq_keys % bn).astype(np.int32)

    # per-src-block neighbour count -> K
    counts = np.bincount(tile_sb, minlength=bn)
    k_max = max(int(counts.max(initial=0)), 1)

    nbr_ids = np.zeros((bn, k_max), dtype=np.int32)
    nbr_mask = np.zeros((bn, k_max), dtype=bool)
    tiles = np.full((bn, k_max, vb, vb), fill, dtype=np.float32)

    # slot index of each tile within its src block row
    slot_of_key = {}
    next_slot = np.zeros(bn, dtype=np.int64)
    for tkey, tsb, tdb in zip(uniq_keys, tile_sb, tile_db):
        s = next_slot[tsb]
        slot_of_key[int(tkey)] = int(s)
        nbr_ids[tsb, s] = tdb
        nbr_mask[tsb, s] = True
        next_slot[tsb] += 1

    slots = np.fromiter((slot_of_key[int(k)] for k in pair_key),
                        dtype=np.int64, count=len(pair_key))
    tiles[sb, slots, su, dv] = w

    vmask = np.zeros((bn, vb), dtype=bool)
    vmask.reshape(-1)[:n] = True

    return BlockedGraph(
        n_real=n, block_size=vb, num_blocks=bn, max_nbr_blocks=k_max,
        fill=float(fill),
        nbr_ids=jnp.asarray(nbr_ids), nbr_mask=jnp.asarray(nbr_mask),
        tiles=jnp.asarray(tiles), vertex_mask=jnp.asarray(vmask))
