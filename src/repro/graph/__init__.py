from repro.graph.structure import (CSRGraph, BlockedGraph, TileOverlay,
                                   build_blocked, empty_overlay)
from repro.graph.generators import (rmat_graph, uniform_graph, chain_graph,
                                    grid_graph, mutation_stream)

__all__ = [
    "CSRGraph",
    "BlockedGraph",
    "TileOverlay",
    "build_blocked",
    "empty_overlay",
    "rmat_graph",
    "uniform_graph",
    "chain_graph",
    "grid_graph",
    "mutation_stream",
]
