from repro.graph.structure import CSRGraph, BlockedGraph, build_blocked
from repro.graph.generators import rmat_graph, uniform_graph, chain_graph, grid_graph

__all__ = [
    "CSRGraph",
    "BlockedGraph",
    "build_blocked",
    "rmat_graph",
    "uniform_graph",
    "chain_graph",
    "grid_graph",
]
