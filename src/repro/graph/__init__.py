from repro.graph.structure import (CSRGraph, BlockedGraph, BlockPairs,
                                   TileOverlay, build_blocked,
                                   build_block_pairs, empty_overlay)
from repro.graph.generators import (rmat_graph, uniform_graph, chain_graph,
                                    grid_graph, mutation_stream)

__all__ = [
    "CSRGraph",
    "BlockedGraph",
    "BlockPairs",
    "TileOverlay",
    "build_blocked",
    "build_block_pairs",
    "empty_overlay",
    "rmat_graph",
    "uniform_graph",
    "chain_graph",
    "grid_graph",
    "mutation_stream",
]
