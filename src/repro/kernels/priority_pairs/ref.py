"""Pure-jnp oracle for the priority_pairs kernel (== core.priority.block_pairs)."""

from __future__ import annotations

import jax.numpy as jnp


def priority_pairs_ref(vertex_priority: jnp.ndarray):
    un = vertex_priority > 0.0
    node_un = jnp.sum(un, axis=-1).astype(jnp.float32)
    p_sum = jnp.sum(jnp.where(un, vertex_priority, 0.0), axis=-1)
    p_mean = p_sum / jnp.maximum(node_un, 1.0)
    return node_un, p_mean
