"""Fused <Node_un, P_mean> pair reduction (paper Eq. 1) as a Pallas kernel.

One pass over the per-vertex priority array produces both halves of the pair
for every (job, block) — the MPDS bookkeeping the paper worries about keeping
"inexpensive".  Grid (J, B_N); each step reduces one [Vb] stripe in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _pairs_kernel(p_ref, n_ref, m_ref):
    p = p_ref[0]                         # [1, Vb] (2D for TPU vector units)
    un = (p > 0.0).astype(jnp.float32)
    n = jnp.sum(un)
    s = jnp.sum(p * un)
    n_ref[0, 0] = n
    m_ref[0, 0] = s / jnp.maximum(n, 1.0)


def priority_pairs_call(vertex_priority: jnp.ndarray, *,
                        interpret: bool | None = None):
    """[J, B_N, Vb] f32 -> (node_un [J, B_N], p_mean [J, B_N]).

    ``interpret=None`` resolves through `kernels.common.resolve_interpret`
    — same one-source-of-truth rule as mj_spmm_call."""
    return _pairs_jit(vertex_priority,
                      interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _pairs_jit(vertex_priority: jnp.ndarray, *, interpret: bool):
    j, bn, vb = vertex_priority.shape
    return pl.pallas_call(
        _pairs_kernel,
        grid=(j, bn),
        in_specs=[pl.BlockSpec((1, 1, vb), lambda i, b: (i, b, 0))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, b: (i, b)),
            pl.BlockSpec((1, 1), lambda i, b: (i, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, bn), jnp.float32),
            jax.ShapeDtypeStruct((j, bn), jnp.float32),
        ],
        interpret=interpret,
    )(vertex_priority)
