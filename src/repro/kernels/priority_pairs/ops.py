"""Jit wrapper for the priority_pairs kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret  # noqa: F401  (re-export)
from repro.kernels.priority_pairs.kernel import priority_pairs_call


def priority_pairs(vertex_priority: jnp.ndarray,
                   interpret: bool | None = None):
    """[J, B_N, Vb] -> (node_un, p_mean), both [J, B_N] float32."""
    return priority_pairs_call(vertex_priority.astype(jnp.float32),
                               interpret=interpret)
