from repro.kernels.priority_pairs.ops import priority_pairs
from repro.kernels.priority_pairs.ref import priority_pairs_ref

__all__ = ["priority_pairs", "priority_pairs_ref"]
