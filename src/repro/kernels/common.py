"""Kernel dispatch policy shared by every Pallas kernel in the repo.

One source of truth for two decisions each kernel wrapper must make:

  interpret   Pallas kernels compile for TPU; everywhere else they run in
              interpreter mode (pure jax ops, still jittable).  Callers
              pass ``interpret=None`` and get `default_interpret()` — a
              literal ``interpret=True`` default would silently pin the
              interpreter even on TPU (the bug ISSUE 8 fixes).
  VMEM        the per-grid-cell footprint budget all job-chunk pickers
              (`_pick_job_block` style) size against.  Kept below the
              ~16 MB/core hardware ceiling so the pipelined double
              buffers of two consecutive grid cells coexist.
"""

from __future__ import annotations

from typing import Optional

import jax

#: per-grid-cell VMEM budget (bytes) for job-chunk sizing
VMEM_BUDGET = 12 * 2**20


def default_interpret() -> bool:
    """True unless we are actually on TPU: Mosaic lowering exists only
    there, every other backend runs the Pallas interpreter."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument: None defers to
    backend detection, an explicit bool wins (tests force True)."""
    return default_interpret() if interpret is None else bool(interpret)
