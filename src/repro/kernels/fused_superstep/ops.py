"""Jit wrappers around the fused superstep megakernel.

`fused_push` mirrors the engine shared-mode push exactly (consume the
selected blocks' pending deltas, push for every job, fold values), with
the whole select→stage→push→priority chain lowered into ONE Pallas
program over the view's destination-sorted `BlockPairs`.  The fold /
consume bookkeeping stays in jnp (bandwidth-bound on state vectors, not
adjacency); selection enters the kernel only as identity-masked operand
rows, so padded selection slots aliasing block 0 cannot re-push it.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.common import resolve_interpret
from repro.kernels.fused_superstep.kernel import fused_superstep_call


def _pick_job_block(j: int, vb: int, semiring: str) -> int:
    """Largest job chunk whose per-grid-cell footprint fits the budget:
    tile (Vb^2) + per-job [Jb, Vb] state stripes (plus-times: d/base/out;
    min-plus: d/values-in+out/deltas-in+out/cand) + 2 pair counters,
    fp32 — falling back through divisors of J (prime J degrades to 1)."""
    stripes = 3 if semiring == "plus_times" else 6
    fixed = vb * vb * 4
    per_job = (stripes * vb + 2) * 4
    budget = max(common.VMEM_BUDGET - fixed, per_job)
    jb = max(1, min(j, budget // per_job))
    while j % jb:
        jb -= 1
    return jb


def fused_push(values: jnp.ndarray, deltas: jnp.ndarray, pairs,
               sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
               push_scale: jnp.ndarray, *, semiring: str = "plus_times",
               tolerance: float = 1e-6, interpret: bool | None = None,
               with_pairs: bool = False):
    """Megakernel-backed CAJS push. values/deltas [J, B_N, Vb].

    `pairs` is the view's `graph.structure.BlockPairs`.  Returns updated
    (values, deltas); with_pairs=True additionally returns the fused
    priority-pair outputs (node_un, p_sum) [J, B_N] of the POST-push
    state, zeroed on untouched destination blocks.  ``interpret=None``
    resolves through `kernels.common.resolve_interpret`."""
    j, bn, vb = values.shape
    interpret = resolve_interpret(interpret)
    jb = _pick_job_block(j, vb, semiring)
    selb = jnp.zeros((bn,), jnp.bool_).at[sel_ids].max(sel_mask > 0)
    selb = selb[None, :, None]
    touched = pairs.dst_touched[None, :, None]
    if semiring == "plus_times":
        raw = jnp.where(selb, deltas, 0.0)
        d = raw * push_scale[:, None, None]
        base = deltas - raw
        out, nu, ps = fused_superstep_call(
            pairs.src, pairs.dst, pairs.first, pairs.last, d, base,
            pairs.tiles, semiring=semiring, tolerance=tolerance,
            job_block=jb, interpret=interpret)
        values = values + raw
        deltas = jnp.where(touched, out, base)
    else:
        pend = jnp.where(selb, deltas, jnp.inf)
        base = jnp.where(selb, jnp.inf, deltas)
        vout, dout, nu, ps = fused_superstep_call(
            pairs.src, pairs.dst, pairs.first, pairs.last, pend, base,
            pairs.tiles, values=values, semiring=semiring,
            tolerance=tolerance, job_block=jb, interpret=interpret)
        values = jnp.where(touched, vout, values)
        deltas = jnp.where(touched, dout, base)
    if with_pairs:
        tz = pairs.dst_touched[None, :]
        return (values, deltas, jnp.where(tz, nu, 0.0),
                jnp.where(tz, ps, 0.0))
    return values, deltas
