from repro.kernels.fused_superstep.kernel import fused_superstep_call
from repro.kernels.fused_superstep.ops import fused_push, _pick_job_block
from repro.kernels.fused_superstep.ref import fused_superstep_ref

__all__ = ["fused_superstep_call", "fused_push", "fused_superstep_ref",
           "_pick_job_block"]
