"""Fused CAJS superstep megakernel over sparse block pairs.

One Pallas program per (job-chunk, block-pair) fuses the paper's whole
inner loop — stage the staged-selection deltas, push them for EVERY
concurrent job, and update the block priority pairs — without HBM
round-trips between the stages:

  grid (J/Jb, P)   p innermost over `BlockPairs` order: pairs are
                   DESTINATION-sorted, so consecutive p share the output
                   block.  Pallas keeps a block resident while its
                   index_map output is unchanged, so the accumulator for
                   a destination block lives in VMEM across its whole
                   run of pairs and is flushed to HBM exactly once.
                   The grid pipeline double-buffers the next pair's
                   adjacency tile fetch behind the current dot/min.
  scalar prefetch  (src, dst, first, last) pair metadata is prefetched
                   as scalars (PrefetchScalarGridSpec) and drives the
                   data-dependent index_maps.
  @pl.when(first)  initialize the accumulator from the consumed base
                   (plus-times) / reset the min candidate (min-plus).
  @pl.when(last)   flush: final deltas (min-plus also values), plus the
                   fused priority update — per-(job, dst-block)
                   <Node_un, P_sum> from the post-push deltas, the exact
                   quantities `core.priority.block_pairs` reduces.

Selection is encoded entirely in the operand: the wrapper masks
non-selected source rows to the semiring identity (0 / +inf), so their
contributions vanish EXACTLY and no validity flags enter the kernel —
padded selection slots can alias block 0 without re-pushing it.

plus-times accumulates on the MXU ([Jb, Vb] @ [Vb, Vb]); min-plus has no
MXU analogue and min-folds on the VPU with a per-job row loop bounding
temporaries at Vb*Vb.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_plus_kernel(tolerance: float):
    # tolerance is a STATIC factory arg (jit static_argnames upstream), so
    # float() runs at trace time on a python scalar — the literal inlines
    # into the kernel jaxpr instead of becoming a rejected captured constant
    tol = float(tolerance)  # noqa: RPA001

    def kernel(psrc, pdst, pfirst, plast,        # scalar-prefetch refs
               d_ref, base_ref, t_ref,           # [Jb,1,Vb] x2, [1,Vb,Vb]
               o_ref, nu_ref, ps_ref):           # [Jb,1,Vb], [Jb,1] x2
        p = pl.program_id(1)

        @pl.when(pfirst[p] == 1)
        def _init():
            o_ref[:, 0, :] = base_ref[:, 0, :]

        o_ref[:, 0, :] += jnp.dot(d_ref[:, 0, :], t_ref[0],
                                  preferred_element_type=jnp.float32)

        @pl.when(plast[p] == 1)
        def _flush():
            a = jnp.abs(o_ref[:, 0, :])
            pr = jnp.where(a >= tol, a, 0.0)     # Algorithm.vertex_priority
            un = (pr > 0.0).astype(jnp.float32)
            nu_ref[:, 0] = jnp.sum(un, axis=1)
            ps_ref[:, 0] = jnp.sum(pr, axis=1)

    return kernel


def _make_min_kernel(tolerance: float):
    del tolerance                                # min-plus priority is tol-free

    def kernel(psrc, pdst, pfirst, plast,
               d_ref, vbase_ref, dbase_ref, t_ref,
               vo_ref, do_ref, nu_ref, ps_ref,
               cand):                            # VMEM scratch [Jb, Vb]
        p = pl.program_id(1)

        @pl.when(pfirst[p] == 1)
        def _init():
            cand[...] = jnp.full(cand.shape, jnp.inf, jnp.float32)

        t = t_ref[0]
        jb = d_ref.shape[0]

        def body(jj, _):
            row = d_ref[jj, 0, :]                                 # [Vb]
            cand[jj, :] = jnp.minimum(cand[jj, :],
                                      jnp.min(row[:, None] + t, axis=0))
            return 0

        jax.lax.fori_loop(0, jb, body, 0)

        @pl.when(plast[p] == 1)
        def _flush():
            v_old = vbase_ref[:, 0, :]
            v_new = jnp.minimum(v_old, cand[...])
            vo_ref[:, 0, :] = v_new
            d_new = jnp.minimum(dbase_ref[:, 0, :],
                                jnp.where(v_new < v_old, v_new, jnp.inf))
            do_ref[:, 0, :] = d_new
            pr = jnp.where(jnp.isfinite(d_new), 1.0 / (1.0 + d_new), 0.0)
            nu_ref[:, 0] = jnp.sum((pr > 0.0).astype(jnp.float32), axis=1)
            ps_ref[:, 0] = jnp.sum(pr, axis=1)

    return kernel


def fused_superstep_call(src, dst, first, last, d, base, tiles, *,
                         values=None, semiring: str = "plus_times",
                         tolerance: float = 1e-6,
                         job_block: int | None = None,
                         interpret: bool = False):
    """One fused push + priority update over destination-sorted pairs.

    src/dst/first/last [P] int32 (`BlockPairs` metadata, dst-sorted);
    d [J, B_N, Vb] consumed pending deltas with NON-selected source rows
    already masked to the semiring identity (0 / +inf), pre-scaled for
    plus-times; base [J, B_N, Vb] post-consume deltas; tiles [P, Vb, Vb].

    plus-times  -> (delta_out, node_un, p_sum)            each dst-indexed
    min-plus    -> (values_out, delta_out, node_un, p_sum)  (`values`
                   required: [J, B_N, Vb] current values)

    Outputs are only defined for blocks that appear as a destination —
    callers pass `BlockPairs.dst_touched` state through for the rest.
    Output width follows `base`: a 2D-mesh block shard (repro.dist.mesh2d)
    passes d at the GLOBAL source width [J, B_N, Vb] with base/values (and
    dst entries) at its LOCAL dst width [J, B_loc, Vb]; unsharded callers
    pass both at B_N and nothing changes.
    node_un/p_sum [J, B_N] are the un-normalized `<Node_un, P_mean>`
    reduction of the POST-push state (p_mean = p_sum / max(node_un, 1)).
    """
    return _fused_jit(src, dst, first, last, d, base, tiles, values,
                      semiring=semiring, tolerance=float(tolerance),
                      job_block=job_block, interpret=bool(interpret))


@functools.partial(jax.jit, static_argnames=("semiring", "tolerance",
                                             "job_block", "interpret"))
def _fused_jit(src, dst, first, last, d, base, tiles, values, *,
               semiring, tolerance, job_block, interpret):
    # output width follows BASE, not d: a 2D-mesh shard passes the full
    # global-source-indexed operand d [J, B_N, Vb] (what src[pp] indexes)
    # with base/values/outputs at its LOCAL dst width [J, B_loc, Vb]
    # (what dst[pp] indexes) — identical shapes in the unsharded call
    j, _, vb = d.shape
    bn = base.shape[1]
    p = src.shape[0]
    jb = job_block or j
    assert j % jb == 0, f"J={j} not divisible by job_block={jb}"
    grid = (j // jb, p)

    def dmap(jt, pp, src, dst, first, last):
        return (jt, src[pp], 0)

    def omap(jt, pp, src, dst, first, last):
        return (jt, dst[pp], 0)

    def tmap(jt, pp, src, dst, first, last):
        return (pp, 0, 0)

    def pairmap(jt, pp, src, dst, first, last):
        return (jt, dst[pp])

    state_spec = pl.BlockSpec((jb, 1, vb), omap)
    pair_spec = pl.BlockSpec((jb, 1), pairmap)
    tile_spec = pl.BlockSpec((1, vb, vb), tmap)
    d_spec = pl.BlockSpec((jb, 1, vb), dmap)

    if semiring == "plus_times":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4, grid=grid,
            in_specs=[d_spec, state_spec, tile_spec],
            out_specs=[state_spec, pair_spec, pair_spec])
        return pl.pallas_call(
            _make_plus_kernel(tolerance),
            grid_spec=grid_spec,
            out_shape=[jax.ShapeDtypeStruct((j, bn, vb), jnp.float32),
                       jax.ShapeDtypeStruct((j, bn), jnp.float32),
                       jax.ShapeDtypeStruct((j, bn), jnp.float32)],
            interpret=interpret,
        )(src, dst, first, last, d, base, tiles)

    assert values is not None, "min-plus fused call needs `values`"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4, grid=grid,
        in_specs=[d_spec, state_spec, state_spec, tile_spec],
        out_specs=[state_spec, state_spec, pair_spec, pair_spec],
        scratch_shapes=[pltpu.VMEM((jb, vb), jnp.float32)])
    return pl.pallas_call(
        _make_min_kernel(tolerance),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((j, bn, vb), jnp.float32),
                   jax.ShapeDtypeStruct((j, bn, vb), jnp.float32),
                   jax.ShapeDtypeStruct((j, bn), jnp.float32),
                   jax.ShapeDtypeStruct((j, bn), jnp.float32)],
        interpret=interpret,
    )(src, dst, first, last, d, values, base, tiles)
