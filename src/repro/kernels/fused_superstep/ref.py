"""jnp reference oracle for the fused superstep megakernel.

Same contract as `kernel.fused_superstep_call`, written as plain gather /
scatter reductions — the parity target for the kernel tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_superstep_ref(src, dst, first, last, d, base, tiles, *,
                        values=None, semiring: str = "plus_times",
                        tolerance: float = 1e-6):
    del first, last
    j, bn, vb = d.shape
    if semiring == "plus_times":
        contrib = jnp.einsum("jpv,pvw->jpw", d[:, src, :], tiles)
        out = base.at[:, dst, :].add(contrib, mode="drop")
        a = jnp.abs(out)
        pr = jnp.where(a >= tolerance, a, 0.0)
        nu = jnp.sum(pr > 0.0, axis=-1).astype(jnp.float32)
        ps = jnp.sum(pr, axis=-1)
        return out, nu, ps
    assert values is not None
    cand_p = jnp.min(d[:, src, :, None] + tiles[None], axis=2)  # [J, P, Vb]
    cand = jnp.full((j, bn, vb), jnp.inf).at[:, dst, :].min(
        cand_p, mode="drop")
    v_new = jnp.minimum(values, cand)
    d_new = jnp.minimum(base, jnp.where(v_new < values, v_new, jnp.inf))
    pr = jnp.where(jnp.isfinite(d_new), 1.0 / (1.0 + d_new), 0.0)
    nu = jnp.sum(pr > 0.0, axis=-1).astype(jnp.float32)
    ps = jnp.sum(pr, axis=-1)
    return v_new, d_new, nu, ps
