# Pallas TPU kernels for the paper's compute hot-spots:
#   mj_spmm        - multi-job block SpMM (CAJS in hardware: one VMEM-staged
#                    adjacency tile serves all J jobs; plus-times on the MXU,
#                    min-plus on the VPU)
#   priority_pairs - fused <Node_un, P_mean> pair reduction per (job, block)
# Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle).
