# Pallas TPU kernels for the paper's compute hot-spots:
#   mj_spmm         - multi-job block SpMM (CAJS in hardware: one VMEM-staged
#                     adjacency tile serves all J jobs; plus-times on the MXU,
#                     min-plus on the VPU)
#   priority_pairs  - fused <Node_un, P_mean> pair reduction per (job, block)
#   fused_superstep - the whole shared push as ONE megakernel over the
#                     destination-sorted sparse block-pair list
#                     (graph.BlockPairs): select -> stage -> multi-job push ->
#                     priority-pair update, double-buffered tile prefetch via
#                     the Pallas grid pipeline, output-block revisit residency
#   common          - shared VMEM budget + the ONE interpret-resolution rule
#                     (interpret=None -> interpret iff backend != "tpu")
# Each kernel dir has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit
# wrapper), ref.py (pure-jnp oracle).
