"""Jit wrappers around the mj_spmm kernel + a kernel-backed engine push.

`push_shared` mirrors `repro.core.engine` shared-mode push exactly, but the
contribution compute (the hot loop) goes through the Pallas kernel; the
fold/consume/scatter bookkeeping stays in jnp (cheap, bandwidth-bound on
state vectors, not on adjacency tiles).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.common import default_interpret  # noqa: F401  (re-export)
from repro.kernels.mj_spmm.kernel import mj_spmm_call

# VMEM budget (bytes) used to pick the job-chunk size on real TPU; in
# interpret mode it only shapes the grid.  Alias of the shared budget in
# kernels.common so every kernel sizes against the same ceiling.
_VMEM_BUDGET = common.VMEM_BUDGET


def _pick_job_block(j: int, vb: int) -> int:
    # tile (Vb^2) + temp (Vb^2, min-plus) + 2 * job chunk (Jb*Vb), fp32
    fixed = 2 * vb * vb * 4
    per_job = 2 * vb * 4
    budget = max(_VMEM_BUDGET - fixed, per_job)
    jb = max(1, min(j, budget // per_job))
    while j % jb:
        jb -= 1
    return jb


def mj_spmm(d_sel: jnp.ndarray, tiles_sel: jnp.ndarray,
            semiring: str = "plus_times",
            interpret: bool | None = None) -> jnp.ndarray:
    """d_sel [q, J, Vb], tiles_sel [q, K, Vb, Vb] -> contribs [q, K, J, Vb]."""
    q, j, vb = d_sel.shape
    jb = _pick_job_block(j, vb)
    # interpret=None flows through: mj_spmm_call resolves it via
    # kernels.common (the one source of truth for backend detection)
    return mj_spmm_call(d_sel.astype(jnp.float32),
                        tiles_sel.astype(jnp.float32),
                        semiring=semiring, job_block=jb, interpret=interpret)


def push_shared(values: jnp.ndarray, deltas: jnp.ndarray,
                tiles: jnp.ndarray, nbr_ids: jnp.ndarray,
                sel_ids: jnp.ndarray, sel_mask: jnp.ndarray,
                push_scale: jnp.ndarray, *, semiring: str = "plus_times",
                interpret: bool | None = None):
    """Kernel-backed CAJS push. values/deltas [J, B_N, Vb]; returns updated."""
    j, bn, vb = values.shape
    consumed = jnp.zeros((bn,), jnp.bool_).at[sel_ids].max(sel_mask > 0)
    consumed = consumed[None, :, None]
    t_sel = tiles[sel_ids]                       # [q, K, Vb, Vb]
    nbr_sel = nbr_ids[sel_ids]                   # [q, K]

    if semiring == "plus_times":
        raw = jnp.where(consumed, deltas, 0.0)
        d_sel = (raw[:, sel_ids, :] * push_scale[:, None, None]
                 * sel_mask[None, :, None])      # [J, q, Vb]
        contrib = mj_spmm(jnp.swapaxes(d_sel, 0, 1), t_sel,
                          semiring, interpret)   # [q, K, J, Vb]
        values = values + raw
        deltas = deltas - raw
        dst = nbr_sel.reshape(-1)
        upd = jnp.transpose(contrib, (2, 0, 1, 3)).reshape(j, -1, vb)
        # mode="drop" matches core.push.push_plus_one: out-of-range
        # neighbour sentinels are DROPPED, not left to unspecified OOB
        # scatter behavior (clamping would credit the last block).
        deltas = deltas.at[:, dst, :].add(upd, mode="drop")
        return values, deltas

    # min-plus
    d_sel = jnp.where(consumed, deltas, jnp.inf)[:, sel_ids, :]
    d_sel = jnp.where(sel_mask[None, :, None] > 0, d_sel, jnp.inf)
    deltas = jnp.where(consumed, jnp.inf, deltas)
    contrib = mj_spmm(jnp.swapaxes(d_sel, 0, 1), t_sel,
                      semiring, interpret)       # [q, K, J, Vb]

    def body(carry, inp):
        values, deltas = carry
        c_k, dst_k = inp                          # [q, J, Vb], [q]
        c_k = jnp.swapaxes(c_k, 0, 1)             # [J, q, Vb]
        old = values[:, dst_k, :]
        values = values.at[:, dst_k, :].min(c_k, mode="drop")
        new = values[:, dst_k, :]
        improved = new < old
        deltas = deltas.at[:, dst_k, :].min(
            jnp.where(improved, new, jnp.inf), mode="drop")
        return (values, deltas), None

    (values, deltas), _ = jax.lax.scan(
        body, (values, deltas),
        (jnp.swapaxes(contrib, 0, 1), jnp.swapaxes(nbr_sel, 0, 1)))
    return values, deltas
