from repro.kernels.mj_spmm.ops import mj_spmm, push_shared
from repro.kernels.mj_spmm.ref import mj_spmm_ref

__all__ = ["mj_spmm", "mj_spmm_ref", "push_shared"]
