"""Pure-jnp oracle for the multi-job block SpMM kernel."""

from __future__ import annotations

import jax.numpy as jnp


def mj_spmm_ref(d_sel: jnp.ndarray, tiles_sel: jnp.ndarray,
                semiring: str = "plus_times") -> jnp.ndarray:
    """d_sel [q, J, Vb], tiles_sel [q, K, Vb, Vb] -> [q, K, J, Vb]."""
    if semiring == "plus_times":
        return jnp.einsum("qjv,qkvw->qkjw", d_sel, tiles_sel,
                          preferred_element_type=jnp.float32)
    # min-plus
    return jnp.min(d_sel[:, None, :, :, None] + tiles_sel[:, :, None, :, :],
                   axis=3)
