"""Multi-job block SpMM Pallas kernel (the paper's CAJS, in hardware).

Semantics (plus-times):   out[i, k, j, w] = sum_v d[i, j, v] * t[i, k, v, w]
Semantics (min-plus):     out[i, k, j, w] = min_v (d[i, j, v] + t[i, k, v, w])

Grid: (q, K, J/Jb).  The adjacency tile t[i, k] (Vb x Vb) is staged into VMEM
once per (i, k) and *revisited* across the inner j-grid dimension — Pallas
keeps a block resident when its index_map output is unchanged, so the tile is
fetched from HBM exactly once while every job chunk streams against it.
That is the paper's "jobs access the same data in Cache simultaneously",
restated for the HBM->VMEM hierarchy.

plus-times runs on the MXU ([Jb, Vb] @ [Vb, Vb] matmul); min-plus has no MXU
analogue (no min-plus systolic array) and runs on the VPU with an explicit
per-job row loop to bound VMEM temporaries at Vb*Vb.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _plus_kernel(d_ref, t_ref, o_ref):
    d = d_ref[0]                    # [Jb, Vb]
    t = t_ref[0, 0]                 # [Vb, Vb]
    o_ref[0, 0] = jnp.dot(d, t, preferred_element_type=jnp.float32)


def _min_kernel(d_ref, t_ref, o_ref):
    t = t_ref[0, 0]                 # [Vb, Vb]
    jb = d_ref.shape[1]

    def body(j, _):
        row = d_ref[0, j, :]                          # [Vb]
        o_ref[0, 0, j, :] = jnp.min(row[:, None] + t, axis=0)
        return 0

    jax.lax.fori_loop(0, jb, body, 0)


def mj_spmm_call(d_sel: jnp.ndarray, tiles_sel: jnp.ndarray, *,
                 semiring: str = "plus_times",
                 job_block: int | None = None,
                 interpret: bool | None = None) -> jnp.ndarray:
    """d_sel [q, J, Vb] f32, tiles_sel [q, K, Vb, Vb] f32 -> [q, K, J, Vb].

    ``interpret=None`` resolves through `kernels.common.resolve_interpret`
    (interpreter everywhere except TPU) — backend detection has one source
    of truth and callers bypassing `ops.mj_spmm` get the same rule."""
    return _mj_spmm_jit(d_sel, tiles_sel, semiring=semiring,
                        job_block=job_block,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("semiring", "job_block",
                                             "interpret"))
def _mj_spmm_jit(d_sel: jnp.ndarray, tiles_sel: jnp.ndarray, *,
                 semiring: str, job_block: int | None,
                 interpret: bool) -> jnp.ndarray:
    q, j, vb = d_sel.shape
    _, k, vb2, vb3 = tiles_sel.shape
    assert vb == vb2 == vb3, (d_sel.shape, tiles_sel.shape)
    jb = job_block or j
    assert j % jb == 0, f"J={j} not divisible by job_block={jb}"
    kernel = _plus_kernel if semiring == "plus_times" else _min_kernel

    grid = (q, k, j // jb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # delta rows: jt is the INNERMOST grid dim, so the d-chunk
            # index (i, jt) changes at (almost) every grid step — d is
            # re-fetched k times per job chunk (q*k*(j/jb) fetches; only
            # the j/jb == 1 degenerate grid keeps it resident across k).
            # Only the adjacency tile below enjoys inner-revisit residency.
            pl.BlockSpec((1, jb, vb), lambda i, kk, jt: (i, jt, 0)),
            # adjacency tile: one HBM fetch per (i, k), shared by all jobs
            pl.BlockSpec((1, 1, vb, vb), lambda i, kk, jt: (i, kk, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, jb, vb),
                               lambda i, kk, jt: (i, kk, jt, 0)),
        out_shape=jax.ShapeDtypeStruct((q, k, j, vb), jnp.float32),
        interpret=interpret,
    )(d_sel, tiles_sel)
