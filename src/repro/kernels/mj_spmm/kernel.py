"""Multi-job block SpMM Pallas kernel (the paper's CAJS, in hardware).

Semantics (plus-times):   out[i, k, j, w] = sum_v d[i, j, v] * t[i, k, v, w]
Semantics (min-plus):     out[i, k, j, w] = min_v (d[i, j, v] + t[i, k, v, w])

Grid: (q, K, J/Jb).  The adjacency tile t[i, k] (Vb x Vb) is staged into VMEM
once per (i, k) and *revisited* across the inner j-grid dimension — Pallas
keeps a block resident when its index_map output is unchanged, so the tile is
fetched from HBM exactly once while every job chunk streams against it.
That is the paper's "jobs access the same data in Cache simultaneously",
restated for the HBM->VMEM hierarchy.

plus-times runs on the MXU ([Jb, Vb] @ [Vb, Vb] matmul); min-plus has no MXU
analogue (no min-plus systolic array) and runs on the VPU with an explicit
per-job row loop to bound VMEM temporaries at Vb*Vb.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plus_kernel(d_ref, t_ref, o_ref):
    d = d_ref[0]                    # [Jb, Vb]
    t = t_ref[0, 0]                 # [Vb, Vb]
    o_ref[0, 0] = jnp.dot(d, t, preferred_element_type=jnp.float32)


def _min_kernel(d_ref, t_ref, o_ref):
    t = t_ref[0, 0]                 # [Vb, Vb]
    jb = d_ref.shape[1]

    def body(j, _):
        row = d_ref[0, j, :]                          # [Vb]
        o_ref[0, 0, j, :] = jnp.min(row[:, None] + t, axis=0)
        return 0

    jax.lax.fori_loop(0, jb, body, 0)


@functools.partial(jax.jit, static_argnames=("semiring", "job_block",
                                             "interpret"))
def mj_spmm_call(d_sel: jnp.ndarray, tiles_sel: jnp.ndarray, *,
                 semiring: str = "plus_times",
                 job_block: int | None = None,
                 interpret: bool = True) -> jnp.ndarray:
    """d_sel [q, J, Vb] f32, tiles_sel [q, K, Vb, Vb] f32 -> [q, K, J, Vb]."""
    q, j, vb = d_sel.shape
    _, k, vb2, vb3 = tiles_sel.shape
    assert vb == vb2 == vb3, (d_sel.shape, tiles_sel.shape)
    jb = job_block or j
    assert j % jb == 0, f"J={j} not divisible by job_block={jb}"
    kernel = _plus_kernel if semiring == "plus_times" else _min_kernel

    grid = (q, k, j // jb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # delta rows: resident per (i, jt); constant across k (inner
            # revisit) — one HBM fetch per job chunk per selected block
            pl.BlockSpec((1, jb, vb), lambda i, kk, jt: (i, jt, 0)),
            # adjacency tile: one HBM fetch per (i, k), shared by all jobs
            pl.BlockSpec((1, 1, vb, vb), lambda i, kk, jt: (i, kk, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, jb, vb),
                               lambda i, kk, jt: (i, kk, jt, 0)),
        out_shape=jax.ShapeDtypeStruct((q, k, j, vb), jnp.float32),
        interpret=interpret,
    )(d_sel, tiles_sel)
